"""Chrome trace-event / Perfetto JSON export of a span buffer.

:func:`to_chrome_trace` turns a :class:`~repro.obs.trace.Tracer` (or a
plain span list) into the Trace Event Format dict that
https://ui.perfetto.dev and ``chrome://tracing`` load directly: every
span becomes a complete ("ph": "X") event, request trees get one track
(tid) per request grouped under their node's process (pid), decision
spans share a per-node "decisions" track, and metadata events name the
tracks.  Timestamps are rebased to the earliest span so virtual-time
traces (which start near t=0 anyway) and wall-clock traces (which start
at an arbitrary perf_counter origin) render identically.

``json.loads(json.dumps(to_chrome_trace(tracer)))`` round-trips by
construction — the export tests assert it, and ``launch/serve.py
--trace-out`` writes exactly this object.

The span→event conversion lives in :class:`EventBuilder`, which keeps
its pid/tid naming state across calls — the streaming exporter
(:class:`repro.obs.stream.TraceStreamer`) feeds it one retired request
at a time and appends the events incrementally in the **JSON Array
Format** (``[`` then one ``{event},`` per line): the trace-event spec
allows the closing ``]`` to be absent, so a truncated or still-growing
stream file loads in Perfetto as-is.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Union

from repro.obs.trace import DECISION_SPANS, Span, Tracer

_DECISION_TID = 0          # per-process track for decision spans
_REQUEST_TID_BASE = 1      # request tracks start above it


def _spans_of(source: Union[Tracer, Iterable[Span]]) -> List[Span]:
    if isinstance(source, Tracer):
        return source.spans()
    return list(source)


class EventBuilder:
    """Incremental span → trace-event conversion.

    Sticky state (process ids per node, thread ids per trace, the time
    base) lives here so the one-shot exporter and the incremental
    streamer emit identical events: metadata events are interleaved
    exactly where a pid/tid is first seen.
    """

    def __init__(self, t_base: float = 0.0):
        self.t_base = t_base
        self.pids: Dict[str, int] = {}
        self.tids: Dict[int, int] = {}

    def _pid_of(self, node, out: List[dict]) -> int:
        name = node or "node"
        if name not in self.pids:
            self.pids[name] = len(self.pids) + 1
            out.append({"ph": "M", "name": "process_name",
                        "pid": self.pids[name], "tid": 0,
                        "args": {"name": name}})
            out.append({"ph": "M", "name": "thread_name",
                        "pid": self.pids[name], "tid": _DECISION_TID,
                        "args": {"name": "decisions"}})
        return self.pids[name]

    def _tid_of(self, span: Span, out: List[dict]) -> int:
        if span.name in DECISION_SPANS or span.trace_id < 0:
            return _DECISION_TID
        if span.trace_id not in self.tids:
            self.tids[span.trace_id] = _REQUEST_TID_BASE + len(self.tids)
            out.append({"ph": "M", "name": "thread_name",
                        "pid": self._pid_of(span.node, out),
                        "tid": self.tids[span.trace_id],
                        "args": {"name": f"req {span.trace_id}"
                                         f" [{span.cls}]"}})
        return self.tids[span.trace_id]

    def events_for(self, span: Span,
                   links: Sequence[int] = ()) -> List[dict]:
        """The events one span contributes: any first-seen pid/tid
        metadata, then the complete ("X") event itself."""
        out: List[dict] = []
        args = {"cls": span.cls, "trace_id": span.trace_id}
        if links:
            args["links"] = list(links)
        args.update(span.attrs)
        out.append({
            "ph": "X",
            "name": span.name,
            "cat": ("decision"
                    if span.name in DECISION_SPANS or span.trace_id < 0
                    else "request"),
            "pid": self._pid_of(span.node, out),
            "tid": self._tid_of(span, out),
            # trace-event timestamps are microseconds
            "ts": round((span.t0 - self.t_base) * 1e6, 3),
            "dur": round(max(span.t1 - span.t0, 0.0) * 1e6, 3),
            "args": {k: v for k, v in args.items() if v is not None},
        })
        return out


def to_chrome_trace(source: Union[Tracer, Iterable[Span]]) -> dict:
    """Trace-event dict (``{"traceEvents": [...], ...}``) for a span
    buffer.  Pure data in, pure data out — callers json.dump it."""
    spans = _spans_of(source)
    # span links (retry/hedge/preemption second attempts): carried on
    # every event of the linked trace so Perfetto shows which attempt
    # it follows
    links: Dict[int, List[int]] = {}
    if isinstance(source, Tracer):
        links = {tr.trace_id: list(tr.links)
                 for tr in source.requests() if tr.links}
    builder = EventBuilder(t_base=min((s.t0 for s in spans), default=0.0))
    events: List[dict] = []
    for s in spans:
        events.extend(builder.events_for(s, links=links.get(s.trace_id, ())))
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"source": "repro.obs",
                          "span_count": len(spans)}}


def write_chrome_trace(source: Union[Tracer, Iterable[Span]],
                       path: str, *, ndjson: bool = False) -> int:
    """Write the Perfetto-loadable JSON to ``path``; returns the event
    count (``serve.py --trace-out`` logs it).

    ``ndjson=True`` writes the incremental JSON Array Format instead —
    ``[`` then one event per line with a trailing comma, no closing
    ``]`` — byte-identical to what :class:`~repro.obs.stream.
    TraceStreamer` appends live, and equally loadable in Perfetto."""
    doc = to_chrome_trace(source)
    with open(path, "w") as f:
        if ndjson:
            f.write("[\n")
            for ev in doc["traceEvents"]:
                f.write(json.dumps(ev, indent=None,
                                   separators=(",", ":")) + ",\n")
        else:
            json.dump(doc, f, indent=None, separators=(",", ":"))
    return len(doc["traceEvents"])


def iter_trace_events(path: str) -> Iterator[dict]:
    """Parse either export format back into events: the one-shot JSON
    object or the incremental array format (possibly truncated) — the
    streaming tests and offline tools read through this."""
    with open(path) as f:
        head = f.read(1)
        rest = f.read()
    text = head + rest
    if head == "{":
        for ev in json.loads(text)["traceEvents"]:
            yield ev
        return
    for line in text.splitlines():
        line = line.strip().rstrip(",")
        if not line or line in "[]":
            continue
        yield json.loads(line)
