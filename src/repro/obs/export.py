"""Chrome trace-event / Perfetto JSON export of a span buffer.

:func:`to_chrome_trace` turns a :class:`~repro.obs.trace.Tracer` (or a
plain span list) into the Trace Event Format dict that
https://ui.perfetto.dev and ``chrome://tracing`` load directly: every
span becomes a complete ("ph": "X") event, request trees get one track
(tid) per request grouped under their node's process (pid), decision
spans share a per-node "decisions" track, and metadata events name the
tracks.  Timestamps are rebased to the earliest span so virtual-time
traces (which start near t=0 anyway) and wall-clock traces (which start
at an arbitrary perf_counter origin) render identically.

``json.loads(json.dumps(to_chrome_trace(tracer)))`` round-trips by
construction — the export tests assert it, and ``launch/serve.py
--trace-out`` writes exactly this object.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Union

from repro.obs.trace import DECISION_SPANS, Span, Tracer

_DECISION_TID = 0          # per-process track for decision spans
_REQUEST_TID_BASE = 1      # request tracks start above it


def _spans_of(source: Union[Tracer, Iterable[Span]]) -> List[Span]:
    if isinstance(source, Tracer):
        return source.spans()
    return list(source)


def to_chrome_trace(source: Union[Tracer, Iterable[Span]]) -> dict:
    """Trace-event dict (``{"traceEvents": [...], ...}``) for a span
    buffer.  Pure data in, pure data out — callers json.dump it."""
    spans = _spans_of(source)
    # span links (retry/hedge second attempts): carried on every event
    # of the linked trace so Perfetto shows which attempt it follows
    links: Dict[int, List[int]] = {}
    if isinstance(source, Tracer):
        links = {tr.trace_id: list(tr.links)
                 for tr in source.requests() if tr.links}
    t_base = min((s.t0 for s in spans), default=0.0)
    pids: Dict[str, int] = {}
    tids: Dict[int, int] = {}
    events: List[dict] = []

    def pid_of(node) -> int:
        name = node or "node"
        if name not in pids:
            pids[name] = len(pids) + 1
            events.append({"ph": "M", "name": "process_name",
                           "pid": pids[name], "tid": 0,
                           "args": {"name": name}})
            events.append({"ph": "M", "name": "thread_name",
                           "pid": pids[name], "tid": _DECISION_TID,
                           "args": {"name": "decisions"}})
        return pids[name]

    def tid_of(span: Span) -> int:
        if span.name in DECISION_SPANS or span.trace_id < 0:
            return _DECISION_TID
        if span.trace_id not in tids:
            tids[span.trace_id] = _REQUEST_TID_BASE + len(tids)
            events.append({"ph": "M", "name": "thread_name",
                           "pid": pid_of(span.node),
                           "tid": tids[span.trace_id],
                           "args": {"name": f"req {span.trace_id}"
                                            f" [{span.cls}]"}})
        return tids[span.trace_id]

    for s in spans:
        args = {"cls": s.cls, "trace_id": s.trace_id}
        if s.trace_id in links:
            args["links"] = links[s.trace_id]
        args.update(s.attrs)
        events.append({
            "ph": "X",
            "name": s.name,
            "cat": ("decision" if s.name in DECISION_SPANS or s.trace_id < 0
                    else "request"),
            "pid": pid_of(s.node),
            "tid": tid_of(s),
            # trace-event timestamps are microseconds
            "ts": round((s.t0 - t_base) * 1e6, 3),
            "dur": round(max(s.t1 - s.t0, 0.0) * 1e6, 3),
            "args": {k: v for k, v in args.items() if v is not None},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"source": "repro.obs",
                          "span_count": len(spans)}}


def write_chrome_trace(source: Union[Tracer, Iterable[Span]],
                       path: str) -> int:
    """Write the Perfetto-loadable JSON to ``path``; returns the event
    count (``serve.py --trace-out`` logs it)."""
    doc = to_chrome_trace(source)
    with open(path, "w") as f:
        json.dump(doc, f, indent=None, separators=(",", ":"))
    return len(doc["traceEvents"])
