"""SLO watchtower: burn-rate alerts, regression attribution, exemplars.

PR 7 records (spans/metrics) and PR 8 reacts to hard failures
(retries/brownout on failure pressure); this module WATCHES: it holds
per-class error-budget accounting, fires multi-window burn-rate alerts
the way an SRE pager would, and — because the span pipeline proves
where each request's latency went — every alert is *attributed*: the
regressed pipeline component is named by diffing the firing window's
component decomposition against a rolling baseline, and probable causes
are ranked by correlating the window against active chaos injections
and retained decision spans.  The same :class:`Watchtower` instance is
fed by the virtual-time simulator and the wall-clock live driver, so an
alert means the same thing in both worlds.

Burn rate follows the multi-window multi-burn-rate recipe: with
objective ``o`` (fraction of requests that must be good), the budget is
``1 - o`` and the burn over a window is ``bad_fraction / (1 - o)``.  A
window alert fires only when BOTH its short and long windows exceed the
threshold — the short window makes it fast to clear, the long window
keeps a blip from paging.  ``time_scale`` maps the canonical real-time
windows (5m/1h fast, 6h/3d slow) onto a compressed virtual day.

Stdlib-only (like the rest of ``repro.obs``): chaos kinds arrive as
plain strings via :meth:`Watchtower.note_injection`, so this module
never imports ``repro.chaos``.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.obs.trace import (COMPONENTS, HEALTH_FAIL, MIGRATE, PREEMPT,
                             REBALANCE, SCALE, Tracer)

FAST = "fast"
SLOW = "slow"
PAGE = "page"
TICKET = "ticket"

# Which pipeline component each chaos kind is expected to inflate:
# throttles/stragglers slow the device itself; everything that kills or
# hides capacity shows up as queueing on the survivors.
EXPECTED_COMPONENT: Dict[str, str] = {
    "thermal": "device",
    "straggler": "device",
    "fail_stop": "queue",
    "rack_fail": "queue",
    "spot_preempt": "queue",
    "wedge": "queue",
    "partition": "queue",
}
# Injections that end on their own vs. ones that leave the node dead
# until something (scale/readmit) intervenes.
_TRANSIENT_KINDS = ("thermal", "straggler", "partition")

# Decision spans worth naming as probable causes (ARBITRATE fires every
# epoch and BROWNOUT is the *response* — both would be noise).
_DECISION_COMPONENT: Dict[str, str] = {
    HEALTH_FAIL: "queue",
    PREEMPT: "queue",
    SCALE: "queue",
    REBALANCE: "queue",
    MIGRATE: "warming",
}


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """Per-class objective: fraction of requests that must be good."""
    cls: str
    objective: float = 0.999

    def __post_init__(self):
        if not 0.0 < self.objective < 1.0:
            raise ValueError(f"objective must be in (0,1): "
                             f"{self.objective}")


@dataclasses.dataclass(frozen=True)
class BurnWindow:
    """One multi-window burn-rate rule: fires when burn exceeds
    ``burn`` over BOTH ``short_s`` and ``long_s``."""
    name: str
    short_s: float
    long_s: float
    burn: float
    severity: str


def default_windows(time_scale: float = 1.0) -> Tuple[BurnWindow, ...]:
    """The canonical fast(5m/1h, 14.4x, page) + slow(6h/3d, 1x, ticket)
    pairs, scaled so a real SLO day maps onto a compressed virtual
    horizon (``time_scale = horizon_s / 86400`` makes the run one
    virtual day)."""
    ts = float(time_scale)
    return (BurnWindow(FAST, 300.0 * ts, 3600.0 * ts, 14.4, PAGE),
            BurnWindow(SLOW, 21600.0 * ts, 259200.0 * ts, 1.0, TICKET))


@dataclasses.dataclass(frozen=True)
class Cause:
    """One ranked probable cause of a regression."""
    label: str            # "chaos:thermal" / "decision:health_fail"
    score: float
    detail: str = ""


@dataclasses.dataclass(frozen=True)
class Attribution:
    """Which component regressed, by how much, and why (ranked)."""
    component: str
    delta_ms: float
    baseline_ms: float
    causes: Tuple[Cause, ...] = ()

    @property
    def cause(self) -> str:
        return self.causes[0].label if self.causes else "unknown"


@dataclasses.dataclass(frozen=True)
class Alert:
    """One fired burn-rate alert (rising edge only)."""
    t: float
    cls: str
    window: str           # FAST / SLOW
    severity: str         # PAGE / TICKET
    burn_short: float
    burn_long: float
    budget_remaining: float   # fraction of the slow-long error budget
    exemplars: Tuple[int, ...] = ()
    attribution: Optional[Attribution] = None


class Watchtower:
    """Per-class error-budget accounting + burn-rate alerting.

    Feed it outcome counts with :meth:`observe` (cumulative
    time-series, virtual or wall seconds), then call :meth:`evaluate`
    periodically; it returns newly-fired :class:`Alert`\\ s, keeps
    ``active`` state per (class, window), and exposes
    :meth:`pressure` — the actuation signal the arbiter/rebalancer
    consume.  With ``tracer``/``registry`` wired it also attributes
    each alert and attaches histogram-bucket exemplars.
    """

    def __init__(self, targets: Union[Dict[str, float],
                                      Iterable[SLOTarget]], *,
                 windows: Optional[Sequence[BurnWindow]] = None,
                 time_scale: float = 1.0,
                 tracer: Optional[Tracer] = None,
                 registry=None,
                 hist_name: str = "cluster_request_ms",
                 actuate: bool = True,
                 rebalance_on_alert: bool = False,
                 hold_s: Optional[float] = None,
                 min_total: int = 8,
                 max_alerts: int = 1024):
        if isinstance(targets, dict):
            self.targets = {c: SLOTarget(c, o) for c, o in targets.items()}
        else:
            self.targets = {t.cls: t for t in targets}
        self.windows = tuple(windows if windows is not None
                             else default_windows(time_scale))
        self.tracer = tracer
        self.registry = registry
        self.hist_name = hist_name
        self.actuate = actuate
        self.rebalance_on_alert = rebalance_on_alert
        # hold: once firing, an alert stays active until its condition
        # has been clear for the window's own short_s (or this
        # override) — without it, one good sampling interval clears the
        # alert, the actuation it triggered is withdrawn, the bad state
        # returns, and the loop flaps every epoch
        self.hold_s = hold_s
        # minimum traffic in a window before its burn is trusted — two
        # bad requests out of two at cold start is not an 800x burn
        self.min_total = min_total
        self.max_alerts = max_alerts
        self.alerts: List[Alert] = []
        self.alerts_dropped = 0
        # cumulative per-class series: sample times + running good/bad
        self._ts: Dict[str, List[float]] = {}
        self._good: Dict[str, List[int]] = {}
        self._bad: Dict[str, List[int]] = {}
        self._active: Dict[Tuple[str, str], bool] = {}
        self._last_true: Dict[Tuple[str, str], float] = {}
        self._burn: Dict[Tuple[str, str], Tuple[float, float]] = {}
        # chaos injections noted for cause correlation
        self._injections: List[Tuple[float, str, str, float]] = []
        # time-in-SLO bookkeeping: evaluate ticks without a fast alert
        self._ticks: Dict[str, int] = {}
        self._ok: Dict[str, int] = {}

    # --- feeding -----------------------------------------------------------

    def observe(self, t: float, cls: str, good: int = 0, bad: int = 0):
        """Append one outcome sample (counts since the previous
        sample).  ``bad`` counts SLO violations: late completions,
        drops, and failures alike."""
        ts = self._ts.setdefault(cls, [])
        g = self._good.setdefault(cls, [])
        b = self._bad.setdefault(cls, [])
        if ts and t < ts[-1]:
            raise ValueError(f"samples must be time-ordered: {t} < "
                             f"{ts[-1]}")
        ts.append(float(t))
        g.append((g[-1] if g else 0) + int(good))
        b.append((b[-1] if b else 0) + int(bad))

    def note_injection(self, t: float, kind: str, node: str = "",
                       duration_s: float = 0.0):
        """Record a chaos injection for cause correlation (plain
        strings — the sim's chaos schedule calls this as it fires)."""
        self._injections.append((float(t), str(kind), str(node or ""),
                                 float(duration_s)))

    # --- window math -------------------------------------------------------

    def _window_counts(self, cls: str, t: float,
                       window_s: float) -> Tuple[int, int]:
        """(bad, total) over ``(t - window_s, t]``; when the window is
        narrower than the sampling interval, fall back to the latest
        sample delta so a coarse feeder still gets a signal."""
        ts = self._ts.get(cls)
        if not ts:
            return 0, 0
        hi = bisect.bisect_right(ts, t) - 1
        if hi < 0:
            return 0, 0
        lo = bisect.bisect_right(ts, t - window_s, 0, hi + 1) - 1
        if lo == hi:
            lo = hi - 1   # sub-interval window: use the last delta
        g, b = self._good[cls], self._bad[cls]
        g0 = g[lo] if lo >= 0 else 0
        b0 = b[lo] if lo >= 0 else 0
        bad = b[hi] - b0
        total = (g[hi] - g0) + bad
        return bad, total

    def burn(self, cls: str, t: float, window_s: float) -> float:
        """Error-budget burn rate over one window: bad fraction divided
        by the budget (1 - objective).  0.0 when there was no traffic."""
        tgt = self.targets.get(cls)
        if tgt is None:
            return 0.0
        bad, total = self._window_counts(cls, t, window_s)
        if total <= 0 or total < self.min_total:
            return 0.0
        return (bad / total) / (1.0 - tgt.objective)

    def budget_remaining(self, cls: str, t: float) -> float:
        """Fraction of the error budget left over the slowest long
        window (1.0 = untouched, 0.0 = fully burned)."""
        w = max(self.windows, key=lambda w: w.long_s)
        return max(0.0, 1.0 - self.burn(cls, t, w.long_s))

    # --- evaluation --------------------------------------------------------

    def evaluate(self, t: float) -> List[Alert]:
        """Advance the monitors to time ``t``; returns newly-fired
        alerts (rising edges only — an alert that stays firing across
        evaluations is reported once)."""
        fired: List[Alert] = []
        for cls in self.targets:
            for w in self.windows:
                key = (cls, w.name)
                bs = self.burn(cls, t, w.short_s)
                bl = self.burn(cls, t, w.long_s)
                self._burn[key] = (bs, bl)
                over = bs >= w.burn and bl >= w.burn
                if over:
                    self._last_true[key] = t
                hold = self.hold_s if self.hold_s is not None else w.short_s
                was = self._active.get(key, False)
                firing = over or (was and t - self._last_true.get(
                    key, float("-inf")) <= hold)
                self._active[key] = firing
                if self.registry is not None:
                    self.registry.gauge("watchtower_burn", cls=cls,
                                        window=w.name).set(bs)
                if firing and not was:
                    alert = Alert(
                        t=t, cls=cls, window=w.name, severity=w.severity,
                        burn_short=bs, burn_long=bl,
                        budget_remaining=self.budget_remaining(cls, t),
                        exemplars=self._exemplars(cls),
                        attribution=self.attribute(t, cls, w.long_s))
                    if len(self.alerts) < self.max_alerts:
                        self.alerts.append(alert)
                    else:
                        self.alerts_dropped += 1
                    fired.append(alert)
                    if self.registry is not None:
                        self.registry.counter(
                            "watchtower_alerts_total", cls=cls,
                            window=w.name, severity=w.severity).inc()
            # time-in-SLO: a tick is in SLO iff no fast alert is active
            self._ticks[cls] = self._ticks.get(cls, 0) + 1
            if not self._active.get((cls, FAST), False):
                self._ok[cls] = self._ok.get(cls, 0) + 1
        return fired

    def active(self, cls: str, window: str = FAST) -> bool:
        return self._active.get((cls, window), False)

    def pressure(self, cls: str) -> float:
        """Actuation signal: 0.0 when healthy; while a fast alert is
        active, the short-window burn normalised by its threshold
        (clipped to 4.0) — the arbiter scales the class's backlog by
        ``1 + pressure``."""
        if not self.active(cls, FAST):
            return 0.0
        bs, _ = self._burn.get((cls, FAST), (0.0, 0.0))
        w = next(w for w in self.windows if w.name == FAST)
        return min(bs / w.burn, 4.0)

    def time_in_slo(self, cls: str) -> float:
        """Fraction of evaluate ticks with no active fast alert."""
        ticks = self._ticks.get(cls, 0)
        return self._ok.get(cls, 0) / ticks if ticks else 1.0

    # --- attribution -------------------------------------------------------

    def attribute(self, t: float, cls: str,
                  window_s: float) -> Attribution:
        """Name the regressed component and rank probable causes.

        Component: mean per-component ms of retained traces finishing
        inside ``(t - window_s, t]`` minus the mean over older retained
        traces (the rolling baseline).  Causes: active chaos injections
        (scored 2.0, +1.0 when the kind's expected component matches)
        then decision spans in the window (0.5, +0.5 on match) — an
        injected fault always outranks the control plane's reaction to
        it.
        """
        component, delta, baseline = "unknown", 0.0, 0.0
        if self.tracer is not None:
            win: Dict[str, float] = {c: 0.0 for c in COMPONENTS}
            base: Dict[str, float] = {c: 0.0 for c in COMPONENTS}
            n_win = n_base = 0
            for tr in self.tracer.requests():
                if tr.cls != cls:
                    continue
                comp = tr.component_ms()
                if t - window_s < tr.t1 <= t + 1e-9:
                    n_win += 1
                    for c, ms in comp.items():
                        win[c] += ms
                elif tr.t1 <= t - window_s:
                    n_base += 1
                    for c, ms in comp.items():
                        base[c] += ms
            if n_win:
                deltas = {}
                for c in COMPONENTS:
                    w_ms = win[c] / n_win
                    b_ms = base[c] / n_base if n_base else 0.0
                    deltas[c] = (w_ms - b_ms, b_ms)
                component = max(COMPONENTS,
                                key=lambda c: deltas[c][0])
                delta, baseline = deltas[component]

        causes: Dict[str, Cause] = {}

        def _add(label: str, score: float, detail: str):
            prev = causes.get(label)
            if prev is None or score > prev.score:
                causes[label] = Cause(label, score, detail)

        for ti, kind, node, dur in self._injections:
            if ti > t:
                continue
            if kind in _TRANSIENT_KINDS and t > ti + dur + window_s:
                continue   # transient fault long over: not a suspect
            score = 2.0
            if EXPECTED_COMPONENT.get(kind) == component:
                score += 1.0
            _add(f"chaos:{kind}", score,
                 f"node={node} t={ti:.3f} dur={dur:.3f}")
        if self.tracer is not None:
            for sp in self.tracer.spans():
                if sp.name not in _DECISION_COMPONENT:
                    continue
                if not (t - 2.0 * window_s < sp.t0 <= t):
                    continue
                score = 0.5
                if _DECISION_COMPONENT[sp.name] == component:
                    score += 0.5
                _add(f"decision:{sp.name}", score,
                     f"node={sp.node or ''} t={sp.t0:.3f}")
        ranked = tuple(sorted(causes.values(),
                              key=lambda c: (-c.score, c.label)))
        return Attribution(component=component, delta_ms=delta,
                           baseline_ms=baseline, causes=ranked)

    # --- exemplars ---------------------------------------------------------

    def _exemplars(self, cls: str, k: int = 4) -> Tuple[int, ...]:
        """Trace ids a fired alert links to: histogram-bucket exemplars
        (slowest buckets first) that are still retained in the tracer,
        topped up from the tracer's tail (slowest retained traces)."""
        retained = set()
        if self.tracer is not None:
            retained = {tr.trace_id for tr in self.tracer.requests()}
        out: List[int] = []
        if self.registry is not None:
            for row in self.registry.snapshot():
                if row["name"] != self.hist_name:
                    continue
                if cls not in row["labels"].values():
                    continue
                for _edge, x in reversed(row.get("exemplars", [])):
                    if x is None or x in out:
                        continue
                    if retained and x not in retained:
                        continue
                    out.append(x)
                    if len(out) >= k:
                        return tuple(out)
        if self.tracer is not None:
            for tr in self.tracer.tail_requests():
                if tr.cls == cls and tr.trace_id not in out:
                    out.append(tr.trace_id)
                    if len(out) >= k:
                        break
        return tuple(out)

    # --- convenience -------------------------------------------------------

    def ingest(self, report, t: float) -> List[Alert]:
        """One-shot feed from a finished ``TrafficReport`` /
        ``ClusterReport``: fold each class's terminal counts into one
        sample at ``t`` and evaluate."""
        for cn, st in report.classes.items():
            late = st.completed - st.good
            self.observe(t, cn, good=st.good,
                         bad=late + st.dropped + st.failed)
        return self.evaluate(t)

    def summary(self) -> dict:
        return {
            "alerts": len(self.alerts),
            "alerts_dropped": self.alerts_dropped,
            "active": sorted(f"{c}/{w}" for (c, w), on
                             in self._active.items() if on),
            "time_in_slo": {c: round(self.time_in_slo(c), 4)
                            for c in sorted(self.targets)},
            "budget_remaining": {
                c: round(self.budget_remaining(
                    c, self._ts[c][-1] if self._ts.get(c) else 0.0), 4)
                for c in sorted(self.targets)},
        }


def format_alerts(alerts: Sequence[Alert]) -> str:
    """Human-readable alert log — serve.py's ``--alerts-out`` sidecar
    and the example's act 8 print this."""
    lines = []
    for a in alerts:
        attr = a.attribution
        why = ""
        if attr is not None:
            why = (f" | {attr.component} +{attr.delta_ms:.2f}ms"
                   f" (base {attr.baseline_ms:.2f}ms) <- {attr.cause}")
        ex = (f" exemplars={list(a.exemplars)}" if a.exemplars else "")
        lines.append(f"[{a.t:8.3f}s] {a.severity.upper():6s} {a.cls} "
                     f"{a.window}-burn short={a.burn_short:.1f}x "
                     f"long={a.burn_long:.1f}x "
                     f"budget={a.budget_remaining:.0%}{why}{ex}")
    return "\n".join(lines)
