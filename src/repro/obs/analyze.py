"""Latency decomposition: WHERE did the p95 go?

:func:`decompose_latency` takes a span source (a
:class:`~repro.obs.trace.Tracer`, an iterable of
:class:`~repro.obs.trace.RequestTrace`, or any report object carrying a
``.tracer``) and answers, per SLO class and per percentile, how the
measured latency splits into ``queue`` / ``collect`` (batching window)
/ ``stack`` / ``dispatch`` / ``device`` / ``warming`` (migration
warmup) components.

Two honesty rules, both enforced here rather than trusted:

* the percentile request is a *real* request — the nearest-rank rule
  (shared :func:`repro.obs.metrics.quantile`) picks an actual trace, so
  the breakdown is one request's true story, not an average of
  incomparable requests;
* components must SUM to the measured latency within ``tol`` (default
  5%) — every trace is checked and violations raise, because a
  decomposition that doesn't add up is a lie about where the time went.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Sequence, Union

from repro.obs.metrics import quantile
from repro.obs.trace import COMPONENTS, RequestTrace, Tracer


class DecompositionError(AssertionError):
    """A trace's components do not sum to its measured latency."""


def _traces_of(source) -> List[RequestTrace]:
    if isinstance(source, Tracer):
        return source.requests()
    tracer = getattr(source, "tracer", None)
    if tracer is not None and not isinstance(source, Iterable):
        return _traces_of(tracer)
    return list(source)


def check_trace(tr: RequestTrace, tol: float = 0.05) -> Dict[str, float]:
    """One trace's component breakdown; raises
    :class:`DecompositionError` if it doesn't sum to ``total_ms``
    within ``tol`` (relative, with a 0.05 ms absolute floor so
    microsecond-scale requests don't trip on rounding)."""
    comp = tr.component_ms()
    got = sum(comp.values())
    want = tr.total_ms
    if abs(got - want) > max(tol * want, 0.05):
        raise DecompositionError(
            f"trace {tr.trace_id} [{tr.cls}]: components sum to "
            f"{got:.3f} ms but measured latency is {want:.3f} ms "
            f"(>{tol:.0%} apart): {comp}")
    return comp


def decompose_latency(source, qs: Sequence[float] = (50, 95),
                      tol: float = 0.05) -> Dict[str, dict]:
    """Per-class percentile decomposition.

    Returns ``{cls: {"n": int, "p50": {...}, "p95": {...}}}`` where each
    percentile entry holds ``total_ms``, ``trace_id``, ``node``, and one
    entry per component (ms, zero when the component didn't occur for
    that request).  Every retained trace is sum-checked against ``tol``
    first — the whole buffer must be honest, not just the percentile
    picks.
    """
    traces = _traces_of(source)
    by_cls: Dict[str, List[RequestTrace]] = {}
    for tr in traces:
        check_trace(tr, tol=tol)
        by_cls.setdefault(tr.cls, []).append(tr)

    out: Dict[str, dict] = {}
    for cls, trs in sorted(by_cls.items()):
        totals = [t.total_ms for t in trs]
        row: dict = {"n": len(trs)}
        for q in qs:
            target = quantile(totals, q)
            # nearest-rank guarantees the percentile IS an observed
            # request; find it and tell that request's story
            pick = min(trs, key=lambda t: (abs(t.total_ms - target),
                                           t.trace_id))
            comp = pick.component_ms()
            entry = {"total_ms": round(pick.total_ms, 3),
                     "trace_id": pick.trace_id, "node": pick.node}
            for name in COMPONENTS:
                entry[name + "_ms"] = round(comp.get(name, 0.0), 3)
            row[f"p{q:g}"] = entry
        out[cls] = row
    return out


def format_decomposition(dec: Dict[str, dict]) -> str:
    """Human-readable table of a :func:`decompose_latency` result —
    the example's act 6 and ``serve.py`` print this."""
    lines = []
    for cls, row in dec.items():
        lines.append(f"{cls} (n={row['n']}):")
        for key, entry in row.items():
            if key == "n":
                continue
            total = entry["total_ms"]
            parts = []
            for name in COMPONENTS:
                ms = entry[name + "_ms"]
                if ms <= 0 or not math.isfinite(total) or total <= 0:
                    continue
                parts.append(f"{name} {ms:.2f}ms ({ms / total:.0%})")
            where = f" @{entry['node']}" if entry.get("node") else ""
            lines.append(f"  {key}: {total:.2f} ms "
                         f"(req {entry['trace_id']}{where}) = "
                         + (" + ".join(parts) if parts else "(empty)"))
    return "\n".join(lines)


def mean_components(source, cls: Union[str, None] = None
                    ) -> Dict[str, float]:
    """Buffer-wide mean ms per component (optionally one class) — the
    benchmark's aggregate view next to the percentile stories."""
    traces = _traces_of(source)
    if cls is not None:
        traces = [t for t in traces if t.cls == cls]
    if not traces:
        return {}
    acc: Dict[str, float] = {name: 0.0 for name in COMPONENTS}
    for tr in traces:
        for name, ms in tr.component_ms().items():
            acc[name] += ms
    return {name: v / len(traces) for name, v in acc.items()}
