"""Metrics registry: counters / gauges / fixed-bucket histograms.

One :class:`MetricsRegistry` replaces the ad-hoc per-subsystem dict
plumbing (``ResourceArbiter._stats``, ``ClusterRouter.routed``, the
sim's ``energy``/``completions`` dicts): instrumented code increments
named, labelled series; the owners' ``summary()`` methods keep their
public shapes by *reading back* from the registry.  A point-in-time
:meth:`MetricsRegistry.snapshot` plus Prometheus-text and JSON exports
make the same numbers scrapeable from ``launch/serve.py
--metrics-out``.

This module is also the home of the ONE shared quantile implementation
(:func:`quantile`, nearest-rank, no interpolation) — the traffic
layer's ``TrafficReport`` percentiles and the histogram percentiles
here both route through it, so a latency percentile means the same
thing wherever it is printed.  (``repro.runtime.monitor.quantile``
re-exports it for back-compat.)

Stdlib-only on purpose: every layer of the stack imports this, so it
must never create an import cycle or pull in jax.
"""
from __future__ import annotations

import json
import math
import re
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# --- the one quantile implementation ----------------------------------------


def quantile(values: Sequence[float], q: float) -> float:
    """Nearest-rank quantile (q in [0, 100]) on a finite sample.

    No interpolation: the answer is always an observed value, so
    hand-built traces in tests have exact expected percentiles.  The
    traffic layer's p50/p95/p99 reporting and the histogram percentiles
    below both go through here (q=0 -> min, q=100 -> max, empty -> nan).
    """
    if not values:
        return float("nan")
    xs = sorted(values)
    k = max(1, math.ceil(q / 100.0 * len(xs)))
    return float(xs[min(k, len(xs)) - 1])


def weighted_quantile(values: Sequence[float],
                      weights: Sequence[float], q: float) -> float:
    """Nearest-rank quantile over (value, weight) pairs — the same rank
    rule as :func:`quantile` with each value repeated ``weight`` times,
    without materialising the repeats.  Histogram percentiles use this
    with bucket upper edges as values and bucket counts as weights."""
    pairs = sorted((v, w) for v, w in zip(values, weights) if w > 0)
    total = sum(w for _, w in pairs)
    if not pairs or total <= 0:
        return float("nan")
    k = max(1.0, math.ceil(q / 100.0 * total))
    acc = 0.0
    for v, w in pairs:
        acc += w
        if acc >= k:
            return float(v)
    return float(pairs[-1][0])


# latency histogram edges (ms); +inf catches the pathological tail
DEFAULT_BUCKETS_MS: Tuple[float, ...] = (
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0,
    1000.0, 2000.0, 5000.0, float("inf"))


class Counter:
    """Monotonic count.  ``inc`` only; resets only by removal."""
    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0):
        if v < 0:
            raise ValueError("counters only go up")
        self.value += v


class Gauge:
    """Point-in-time level (queue depth, granted chips, watts)."""
    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)

    def inc(self, v: float = 1.0):
        self.value += v

    def dec(self, v: float = 1.0):
        self.value -= v


class Histogram:
    """Fixed-bucket histogram (cumulative-style export, upper-edge
    percentiles).  Buckets are upper edges, last edge +inf; tracked
    min/max tighten the q=0/q=100 answers to observed values.

    Each bucket can carry one **exemplar** — an opaque id (a trace id)
    of the latest observation that landed in it — so a p99 bucket links
    to a concrete retained trace.  Keep-latest is deterministic under
    virtual time and costs one slot per bucket."""
    kind = "histogram"

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS_MS):
        edges = tuple(sorted(buckets))
        if not edges or edges[-1] != float("inf"):
            edges = edges + (float("inf"),)
        self.edges = edges
        self.counts = [0] * len(edges)
        self.exemplars: List[object] = [None] * len(edges)
        self.sum = 0.0
        self.count = 0
        self._min = float("inf")
        self._max = float("-inf")

    def observe(self, v: float, exemplar=None):
        for i, edge in enumerate(self.edges):
            if v <= edge:
                self.counts[i] += 1
                if exemplar is not None:
                    self.exemplars[i] = exemplar
                break
        self.sum += v
        self.count += 1
        self._min = min(self._min, v)
        self._max = max(self._max, v)

    def percentile(self, q: float) -> float:
        """Upper-edge nearest-rank percentile; the +inf bucket answers
        with the observed max (there is no finite edge to report)."""
        if self.count == 0:
            return float("nan")
        if q <= 0:
            return self._min
        values = [self._max if e == float("inf") else e
                  for e in self.edges]
        got = weighted_quantile(values, self.counts, q)
        return min(got, self._max)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")


def _labels_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Named, labelled series with get-or-create accessors.

    ``counter("requests_total", cls="interactive", node="n0")`` returns
    the same :class:`Counter` on every call with the same name+labels,
    so hot paths hold a reference and skip the dict lookup entirely.
    """

    def __init__(self):
        self._lock = threading.Lock()
        # name -> labels_key -> series object
        self._series: Dict[str, Dict[tuple, object]] = {}

    def _get(self, name: str, factory, labels: dict):
        key = _labels_key(labels)
        with self._lock:
            by_label = self._series.setdefault(name, {})
            s = by_label.get(key)
            if s is None:
                s = by_label[key] = factory()
            return s

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, Counter, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, Gauge, labels)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS_MS,
                  **labels) -> Histogram:
        return self._get(name, lambda: Histogram(buckets), labels)

    # --- reads ---------------------------------------------------------------

    def value(self, name: str, default: float = 0.0, **labels) -> float:
        """Current value of one series, ``default`` if it was never
        created — summary() readers use this so absent == zero."""
        with self._lock:
            s = self._series.get(name, {}).get(_labels_key(labels))
        if s is None:
            return default
        return s.sum if isinstance(s, Histogram) else s.value

    def labels_of(self, name: str) -> List[dict]:
        """The label sets under one name (to reconstruct per-tenant /
        per-class dict shapes for legacy ``summary()`` consumers)."""
        with self._lock:
            return [dict(k) for k in self._series.get(name, {})]

    def remove(self, name: Optional[str] = None, **labels) -> int:
        """Drop series; with ``name=None`` drops every series carrying
        ALL the given labels (arbiter ``unregister(tenant)`` uses this).
        Returns the number of series removed."""
        match = _labels_key(labels)
        removed = 0
        with self._lock:
            names = [name] if name is not None else list(self._series)
            for n in names:
                by_label = self._series.get(n, {})
                for key in list(by_label):
                    if all(item in key for item in match):
                        del by_label[key]
                        removed += 1
                if not by_label:
                    self._series.pop(n, None)
        return removed

    def snapshot(self) -> List[dict]:
        """Point-in-time flat dump: one dict per series."""
        out = []
        with self._lock:
            items = [(n, dict(bl)) for n, bl in self._series.items()]
        for name, by_label in sorted(items):
            for key, s in sorted(by_label.items()):
                row = {"name": name, "labels": dict(key), "kind": s.kind}
                if isinstance(s, Histogram):
                    row.update(count=s.count, sum=s.sum,
                               buckets=[[e, c] for e, c in
                                        zip(s.edges, s.counts)],
                               exemplars=[[e, x] for e, x in
                                          zip(s.edges, s.exemplars)
                                          if x is not None],
                               p50=s.percentile(50), p95=s.percentile(95),
                               p99=s.percentile(99))
                else:
                    row["value"] = s.value
                out.append(row)
        return out

    def to_json(self, indent: Optional[int] = 1) -> str:
        def _enc(o):
            return "Infinity" if o == float("inf") else o
        rows = self.snapshot()
        for row in rows:
            if "buckets" in row:
                row["buckets"] = [[_enc(e), c] for e, c in row["buckets"]]
            if "exemplars" in row:
                row["exemplars"] = [[_enc(e), x]
                                    for e, x in row["exemplars"]]
            for k in ("p50", "p95", "p99"):
                if k in row and isinstance(row[k], float) \
                        and math.isnan(row[k]):
                    row[k] = None
        return json.dumps({"schema": 1, "series": rows}, indent=indent,
                          sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (counter/gauge/histogram with
        cumulative ``_bucket{le=...}`` rows).

        Names are sanitized to the exposition-format charset and label
        values are escaped (backslash, double-quote, newline) — a tenant
        named ``a"b\\nc`` must not corrupt the scrape."""
        lines: List[str] = []
        with self._lock:
            items = [(n, dict(bl)) for n, bl in self._series.items()]
        for name, by_label in sorted(items):
            pname = _prom_name(name)
            kind = next(iter(by_label.values())).kind
            lines.append(f"# TYPE {pname} {kind}")
            for key, s in sorted(by_label.items()):
                lbl = _prom_labels(key)
                if isinstance(s, Histogram):
                    cum = 0
                    for edge, c in zip(s.edges, s.counts):
                        cum += c
                        le = "+Inf" if edge == float("inf") else f"{edge:g}"
                        extra = (("le", le),) + key
                        lines.append(f"{pname}_bucket{_prom_labels(extra)}"
                                     f" {cum}")
                    lines.append(f"{pname}_sum{lbl} {s.sum:g}")
                    lines.append(f"{pname}_count{lbl} {s.count}")
                else:
                    lines.append(f"{pname}{lbl} {s.value:g}")
        return "\n".join(lines) + ("\n" if lines else "")


# Prometheus exposition charsets: metric names [a-zA-Z_:][a-zA-Z0-9_:]*,
# label names [a-zA-Z_][a-zA-Z0-9_]*; label VALUES are free text with
# backslash/quote/newline escaped.
_PROM_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")
_PROM_LABEL_BAD = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    name = _PROM_NAME_BAD.sub("_", name)
    if not name or name[0].isdigit():
        name = "_" + name
    return name


def _prom_label_name(name: str) -> str:
    name = _PROM_LABEL_BAD.sub("_", name)
    if not name or name[0].isdigit():
        name = "_" + name
    return name


def _prom_escape(value: str) -> str:
    return (value.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _prom_labels(key: Iterable[Tuple[str, str]]) -> str:
    key = tuple(key)
    if not key:
        return ""
    body = ",".join(f'{_prom_label_name(k)}="{_prom_escape(v)}"'
                    for k, v in sorted(key))
    return "{" + body + "}"
