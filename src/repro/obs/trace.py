"""Per-request span trees with a fixed vocabulary + tail-biased retention.

One :class:`Tracer` serves every layer of the stack (engine → arbiter →
cluster) in BOTH time domains: the live path records wall-clock spans
through the injectable ``clock``, and the virtual-time simulators
(:func:`repro.traffic.driver.simulate`,
:func:`repro.cluster.sim.simulate_cluster`) pass explicit virtual
timestamps — the span *schema* is identical either way, which is what
makes a simulated tail request directly comparable to a live one (and
what the sim-vs-live parity tests assert).

**Span vocabulary** (fixed — :data:`SCHEMA` maps each name to the attr
keys it must carry):

* request path (device layer, one tree per request)::

      request -> route -> queue -> collect -> stack -> dispatch
              -> device -> complete          (+ warming when a request
                                              waited out a replica warmup)

* decision spans (runtime / cluster layers): ``arbitrate``,
  ``rebalance``, ``migrate`` (with its real warmup duration),
  ``preempt``, ``scale``, ``health_fail``.

**Retention** is bounded and tail-biased: finished request trees land in
a fixed-capacity buffer that always keeps the globally slowest
``tail_frac`` share (a min-heap on total latency — the p99 outlier that
motivated the trace is never evicted) plus a seeded uniform reservoir
sample of the rest, so percentile *decomposition* stays honest while
memory stays O(capacity).  Decision spans go to a separate capped deque
with a ``decisions_dropped`` counter (the PR-3 ``switch_log`` idiom).

Overhead: recording is a handful of dataclass constructions and one
lock acquisition per finished request (the engine batches a request's
whole span list into a single call); with no tracer attached the
instrumented code paths do nothing.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import random
import threading
import time
from typing import Deque, Dict, Iterable, List, Optional, Sequence, Tuple

# --- span vocabulary (fixed) -------------------------------------------------

# request path, in causal order
REQUEST = "request"     # the root: submit -> future resolved
ROUTE = "route"         # cluster router pick (absent on single-node paths)
QUEUE = "queue"         # waiting for the collector / a slice / busy server
COLLECT = "collect"     # the batching window held open
STACK = "stack"         # host-side pad/stack into the bucket buffer
DISPATCH = "dispatch"   # async device enqueue call
DEVICE = "device"       # dispatch returned -> outputs ready
COMPLETE = "complete"   # outputs ready -> futures resolved
WARMING = "warming"     # stalled behind a migrating replica's warmup

# decision spans (runtime / cluster layers)
ARBITRATE = "arbitrate"
REBALANCE = "rebalance"
MIGRATE = "migrate"
PREEMPT = "preempt"
SCALE = "scale"
HEALTH_FAIL = "health_fail"
CHAOS = "chaos"         # one injected fault landing (repro.chaos)
BROWNOUT = "brownout"   # a class entering/exiting degraded-target mode

REQUEST_SPANS = (ROUTE, QUEUE, COLLECT, STACK, DISPATCH, DEVICE, COMPLETE,
                 WARMING)
DECISION_SPANS = (ARBITRATE, REBALANCE, MIGRATE, PREEMPT, SCALE, HEALTH_FAIL,
                  CHAOS, BROWNOUT)

# the latency components a request's measured latency decomposes into
# (COMPLETE is post-measurement: latency_ms is stamped when outputs are
# ready, before futures resolve, so it is excluded from the sum)
COMPONENTS = (ROUTE, QUEUE, COLLECT, STACK, DISPATCH, DEVICE, WARMING)

# span name -> attr keys every emitter (live or virtual-time) must carry.
# The sim-vs-live parity tests validate both sides against this table.
SCHEMA: Dict[str, Tuple[str, ...]] = {
    ROUTE: (),
    QUEUE: (),
    COLLECT: (),
    STACK: (),
    DISPATCH: (),
    DEVICE: ("bucket", "subnet", "n"),
    COMPLETE: (),
    WARMING: (),
    ARBITRATE: ("tenants", "granted"),
    REBALANCE: ("moves", "preemptions"),
    MIGRATE: ("src", "cost_s"),
    PREEMPT: ("for_cls",),
    SCALE: ("direction",),
    HEALTH_FAIL: (),
    CHAOS: ("kind",),
    BROWNOUT: ("direction",),
}


@dataclasses.dataclass
class Span:
    """One timed interval.  ``t0``/``t1`` are seconds on the tracer's
    clock (wall or virtual); ``cls``/``node`` are the fixed dimensions
    every span carries, ``attrs`` the per-name extras of :data:`SCHEMA`."""
    name: str
    t0: float
    t1: float
    trace_id: int = -1           # -1: decision span (no request tree)
    cls: Optional[str] = None
    node: Optional[str] = None
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def dur_ms(self) -> float:
        return (self.t1 - self.t0) * 1e3


@dataclasses.dataclass
class RequestTrace:
    """One request's span tree (flat list; the root interval is
    ``t0 -> t1`` and the children partition it by component)."""
    trace_id: int
    cls: str
    t0: float
    t1: float = 0.0
    node: Optional[str] = None
    spans: List[Span] = dataclasses.field(default_factory=list)
    # span links: trace_ids of CAUSALLY-PRIOR attempts of the same
    # request (a retried/hedged/preempted request's second attempt links
    # to its first instead of starting an unrelated trace) — carried
    # through the Perfetto export as event args
    links: List[int] = dataclasses.field(default_factory=list)

    @property
    def total_ms(self) -> float:
        """The measured request latency (submit -> outputs ready)."""
        return (self.t1 - self.t0) * 1e3

    def component_ms(self) -> Dict[str, float]:
        """Summed child-span duration per component name."""
        out: Dict[str, float] = {}
        for s in self.spans:
            if s.name in COMPONENTS:
                out[s.name] = out.get(s.name, 0.0) + s.dur_ms
        return out


class Tracer:
    """Bounded, thread-safe span recorder shared by live stack and sims.

    ``clock`` is injectable: the live path uses ``time.perf_counter``
    (the default) and calls that never pass explicit timestamps use it;
    the virtual-time simulators pass explicit ``t`` everywhere, so one
    tracer class serves both domains with one schema.

    ``cap`` bounds retained request trees; ``tail_frac`` of the capacity
    is reserved for the globally slowest requests (kept exactly, via a
    min-heap on total latency) and the rest holds a seeded uniform
    reservoir sample of the remainder — ``dropped`` counts evictions.
    """

    def __init__(self, *, clock=time.perf_counter, cap: int = 4096,
                 tail_frac: float = 0.05, decision_cap: int = 8192,
                 seed: int = 0):
        if cap < 2:
            raise ValueError("tracer cap must be >= 2")
        self.clock = clock
        self.cap = cap
        self.tail_cap = max(1, int(round(cap * tail_frac)))
        self.uniform_cap = max(1, cap - self.tail_cap)
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._next_id = 0
        self._open: Dict[int, RequestTrace] = {}
        # slowest-K retention: min-heap of (total_ms, seq, trace)
        self._tail: List[Tuple[float, int, RequestTrace]] = []
        self._uniform: List[RequestTrace] = []
        self._nontail_seen = 0     # reservoir denominator
        self.finished = 0          # request trees ever completed
        self.aborted = 0           # begun but cancelled (shed/failed)
        self.dropped = 0           # finished trees evicted by sampling
        self.decision_cap = decision_cap
        self.decisions: Deque[Span] = collections.deque(maxlen=decision_cap)
        self.decisions_dropped = 0
        # retirement hook: called with each FINALIZED RequestTrace (every
        # finished tree, whether or not sampling keeps it) — the streaming
        # exporter attaches here.  Always invoked OUTSIDE the tracer lock:
        # the callback may do file IO or call back into the tracer.
        self.on_retire = None

    # --- request span trees --------------------------------------------------

    def begin_request(self, cls: str, *, t: Optional[float] = None,
                      node: Optional[str] = None,
                      links: Sequence[int] = ()) -> int:
        with self._lock:
            rid = self._next_id
            self._next_id += 1
            self._open[rid] = RequestTrace(
                trace_id=rid, cls=cls, node=node,
                t0=self.clock() if t is None else t,
                links=list(links))
            return rid

    def add_span(self, trace_id: int, name: str, t0: float, t1: float, *,
                 node: Optional[str] = None, **attrs):
        with self._lock:
            tr = self._open.get(trace_id)
            if tr is None:
                return   # request already ended/aborted: drop, don't raise
            tr.spans.append(Span(name=name, t0=t0, t1=t1, trace_id=trace_id,
                                 cls=tr.cls, node=node or tr.node,
                                 attrs=attrs))

    def end_request(self, trace_id: int, *, t: Optional[float] = None,
                    node: Optional[str] = None):
        """Finalize one tree at its MEASURED-latency instant (outputs
        ready); post-measurement spans (``complete``) may extend past
        ``t`` and are recorded before this call."""
        with self._lock:
            tr = self._open.pop(trace_id, None)
            if tr is None:
                return
            tr.t1 = self.clock() if t is None else t
            if node is not None:
                tr.node = node
            self._retain(tr)
            cb = self.on_retire
        if cb is not None:
            cb(tr)

    def finish_request(self, trace_id: int, *, t: Optional[float] = None,
                       node: Optional[str] = None,
                       spans: Sequence[Tuple[str, float, float,
                                             Optional[dict]]] = ()):
        """Append a request's remaining spans AND finalize it under one
        lock acquisition — the engine's completer calls this once per
        request instead of ``add_span`` × N + ``end_request``."""
        with self._lock:
            tr = self._open.pop(trace_id, None)
            if tr is None:
                return
            if node is not None:
                tr.node = node
            for name, s0, s1, attrs in spans:
                tr.spans.append(Span(name=name, t0=s0, t1=s1,
                                     trace_id=trace_id, cls=tr.cls,
                                     node=tr.node, attrs=dict(attrs or {})))
            tr.t1 = self.clock() if t is None else t
            self._retain(tr)
            cb = self.on_retire
        if cb is not None:
            cb(tr)

    def abort_request(self, trace_id: int, *, t: Optional[float] = None,
                      retain: bool = False):
        """Forget a begun request that will never complete (shed, failed,
        cancelled) — aborted trees never enter the buffer.

        ``retain=True`` instead FINALIZES the partial tree at the cut
        instant (a closing ``queue`` span covers whatever the emitters
        had not stamped yet, so the decomposition still sums) and keeps
        it — a preempted request's first attempt must stay resolvable
        when its second attempt links back to it."""
        cb = tr = None
        with self._lock:
            tr = self._open.pop(trace_id, None)
            if tr is None:
                return
            self.aborted += 1
            if not retain:
                return
            cut = self.clock() if t is None else t
            last = max((s.t1 for s in tr.spans), default=tr.t0)
            tr.t1 = max(cut, last)
            tr.spans.append(Span(name=QUEUE, t0=last, t1=tr.t1,
                                 trace_id=trace_id, cls=tr.cls,
                                 node=tr.node, attrs={"aborted": True}))
            self._retain(tr)
            cb = self.on_retire
        if cb is not None:
            cb(tr)

    def request(self, cls: str, t0: float, t1: float, *,
                node: Optional[str] = None,
                spans: Sequence[Tuple[str, float, float, Optional[dict]]] = (),
                links: Sequence[int] = ()) -> int:
        """One-shot: record a whole finished request tree under a single
        lock acquisition (the engine and the simulators batch through
        here — per-request tracing cost is one call).  ``links`` names
        causally-prior trace_ids (the first attempt a retry follows)."""
        with self._lock:
            rid = self._next_id
            self._next_id += 1
            tr = RequestTrace(trace_id=rid, cls=cls, t0=t0, t1=t1, node=node,
                              links=list(links))
            for name, s0, s1, attrs in spans:
                tr.spans.append(Span(name=name, t0=s0, t1=s1, trace_id=rid,
                                     cls=cls, node=node,
                                     attrs=dict(attrs or {})))
            self._retain(tr)
            cb = self.on_retire
        if cb is not None:
            cb(tr)
        return rid

    def _retain(self, tr: RequestTrace):
        """Tail-biased sampling: keep the slowest ``tail_cap`` requests
        exactly, reservoir-sample the rest into ``uniform_cap`` slots."""
        self.finished += 1
        entry = (tr.total_ms, self.finished, tr)
        if len(self._tail) < self.tail_cap:
            heapq.heappush(self._tail, entry)
            return
        if entry[:2] > self._tail[0][:2]:
            # slower than the current tail floor: it joins the tail and
            # the displaced request falls through to the uniform sample
            _, _, bumped = heapq.heapreplace(self._tail, entry)
        else:
            bumped = tr
        self._nontail_seen += 1
        if len(self._uniform) < self.uniform_cap:
            self._uniform.append(bumped)
            return
        j = self._rng.randrange(self._nontail_seen)
        if j < self.uniform_cap:
            self._uniform[j] = bumped
        self.dropped += 1

    # --- decision spans ------------------------------------------------------

    def decision(self, name: str, t0: Optional[float] = None,
                 t1: Optional[float] = None, *, cls: Optional[str] = None,
                 node: Optional[str] = None, **attrs) -> Span:
        if t0 is None:
            t0 = self.clock()
        if t1 is None:
            t1 = t0
        span = Span(name=name, t0=t0, t1=t1, cls=cls, node=node, attrs=attrs)
        with self._lock:
            if len(self.decisions) == self.decision_cap:
                self.decisions_dropped += 1   # deque evicts the oldest
            self.decisions.append(span)
        return span

    # --- reads ---------------------------------------------------------------

    def requests(self) -> List[RequestTrace]:
        """Retained request trees (tail + uniform sample), by start time."""
        with self._lock:
            out = [e[2] for e in self._tail] + list(self._uniform)
        return sorted(out, key=lambda tr: (tr.t0, tr.trace_id))

    def tail_requests(self) -> List[RequestTrace]:
        """The always-kept slowest share, slowest first."""
        with self._lock:
            entries = sorted(self._tail, reverse=True)
        return [e[2] for e in entries]

    def spans(self) -> List[Span]:
        """Every retained span (request children + decisions), by t0."""
        out: List[Span] = []
        for tr in self.requests():
            out.extend(tr.spans)
        with self._lock:
            out.extend(self.decisions)
        return sorted(out, key=lambda s: (s.t0, s.t1, s.name))

    def summary(self) -> dict:
        with self._lock:
            return {"finished": self.finished, "aborted": self.aborted,
                    "retained": len(self._tail) + len(self._uniform),
                    "dropped": self.dropped,
                    "decisions": len(self.decisions),
                    "decisions_dropped": self.decisions_dropped}


def validate_schema(spans: Iterable[Span]) -> List[str]:
    """Schema violations (unknown name / missing required attrs) in a
    span stream — empty list means the emitter conforms.  The parity
    tests run both the live and the virtual-time emitters through this.
    """
    problems = []
    for s in spans:
        if s.name not in SCHEMA:
            problems.append(f"unknown span name {s.name!r}")
            continue
        missing = [k for k in SCHEMA[s.name] if k not in s.attrs]
        if missing:
            problems.append(f"span {s.name!r} missing attrs {missing}")
    return problems
