"""Continuous device profiling from retained DEVICE spans.

The Pallas kernels do not (yet) expose hardware counters, but the span
pipeline already records every device dispatch with its ``(subnet,
bucket)`` executable key and measured device time, and the analytic
model (``launch/flops.py`` / ``runtime/hwmodel.py``) knows how many
FLOPs and HBM bytes that executable moves.  Joining the two gives a
per-executable **analytic profile**: MXU utilisation (achieved fraction
of peak FLOP/s) and roofline position (arithmetic intensity vs. the
ridge point) — the "where does each executable sit on the roofline"
view, continuously, from production traces instead of a one-off
microbenchmark.

A batch of ``n`` requests shares ONE device dispatch, and every request
trace in that batch carries a copy of the same DEVICE span — the
aggregation dedupes on ``(node, t0, t1, subnet, bucket)`` so a batch is
counted once, with ``items`` credited from the span's ``n``.

``flops_of(subnet, bucket)`` / ``bytes_of(subnet, bucket)`` are caller
callables returning per-batch totals (the serving layer knows its
model; this module stays model-agnostic).  Peak FLOP/s and HBM
bandwidth default to the analytic hardware model's constants
(lazy-imported — ``repro.obs`` must not depend on ``repro.runtime`` at
import time).
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.obs.trace import DEVICE, RequestTrace, Tracer


def _traces_of(source) -> List[RequestTrace]:
    if isinstance(source, Tracer):
        return source.requests()
    tracer = getattr(source, "tracer", None)
    if tracer is not None and not isinstance(source, Iterable):
        return tracer.requests()
    return list(source)


def _hw_defaults() -> Tuple[float, float]:
    from repro.runtime import hwmodel as hm   # lazy: no obs->runtime cycle
    return float(hm.PEAK_FLOPS), float(hm.HBM_BW)


def profile_devices(source, *,
                    flops_of: Optional[Callable[[str, int], float]] = None,
                    bytes_of: Optional[Callable[[str, int], float]] = None,
                    chips: int = 1, freq: float = 1.0,
                    peak_flops: Optional[float] = None,
                    hbm_bw: Optional[float] = None
                    ) -> Dict[Tuple[str, int], dict]:
    """Aggregate retained DEVICE spans into per-(subnet, bucket) rows.

    Each row carries measured aggregates (``batches``, ``items``,
    ``device_s``, ``ms_per_batch``, ``items_per_s``) and — when
    ``flops_of`` is given — the analytic join: ``flops`` per batch,
    ``mxu_util`` (achieved / peak FLOP/s across ``chips`` at ``freq``),
    and with ``bytes_of`` also ``ai`` (FLOPs/byte), ``ridge`` and
    ``bound`` ("compute" / "memory") — the roofline position.
    """
    if peak_flops is None or hbm_bw is None:
        d_peak, d_bw = _hw_defaults()
        peak_flops = d_peak if peak_flops is None else peak_flops
        hbm_bw = d_bw if hbm_bw is None else hbm_bw
    seen = set()
    agg: Dict[Tuple[str, int], dict] = {}
    for tr in _traces_of(source):
        for sp in tr.spans:
            if sp.name != DEVICE:
                continue
            attrs = sp.attrs or {}
            subnet = str(attrs.get("subnet"))
            bucket = int(attrs.get("bucket", 0) or 0)
            dedupe = (sp.node, round(sp.t0, 9), round(sp.t1, 9),
                      subnet, bucket)
            if dedupe in seen:
                continue
            seen.add(dedupe)
            row = agg.setdefault((subnet, bucket), {
                "subnet": subnet, "bucket": bucket,
                "batches": 0, "items": 0, "device_s": 0.0})
            row["batches"] += 1
            row["items"] += int(attrs.get("n", 1) or 1)
            row["device_s"] += max(sp.t1 - sp.t0, 0.0)

    for (subnet, bucket), row in agg.items():
        dev_s = row["device_s"]
        row["ms_per_batch"] = (dev_s / row["batches"] * 1e3
                               if row["batches"] else 0.0)
        row["items_per_s"] = row["items"] / dev_s if dev_s > 0 else 0.0
        if flops_of is None:
            continue
        fl = float(flops_of(subnet, bucket))
        row["flops"] = fl
        achievable = peak_flops * float(freq) * max(int(chips), 1)
        row["mxu_util"] = (fl * row["batches"] / (dev_s * achievable)
                           if dev_s > 0 and achievable > 0 else 0.0)
        if bytes_of is None:
            continue
        by = float(bytes_of(subnet, bucket))
        row["bytes"] = by
        row["ai"] = fl / by if by > 0 else float("inf")
        ridge = (peak_flops * float(freq)) / hbm_bw if hbm_bw > 0 \
            else float("inf")
        row["ridge"] = ridge
        row["bound"] = "compute" if row["ai"] >= ridge else "memory"
    return dict(sorted(agg.items()))


def export_profile(profile: Dict[Tuple[str, int], dict],
                   registry) -> None:
    """Mirror a profile into a :class:`MetricsRegistry` so it rides the
    existing ``--metrics-out`` export path."""
    for (subnet, bucket), row in profile.items():
        lbl = dict(subnet=subnet, bucket=str(bucket))
        registry.gauge("profile_device_batches", **lbl).set(row["batches"])
        registry.gauge("profile_device_items", **lbl).set(row["items"])
        registry.gauge("profile_device_ms_per_batch",
                       **lbl).set(row["ms_per_batch"])
        if "mxu_util" in row:
            registry.gauge("profile_mxu_util", **lbl).set(row["mxu_util"])
        if "ai" in row:
            registry.gauge("profile_arith_intensity",
                           **lbl).set(row["ai"])


def format_profile(profile: Dict[Tuple[str, int], dict]) -> str:
    """Human-readable profile table (example act 8 / serve.py print)."""
    lines = ["subnet               bkt batches  items  ms/batch  "
             "items/s   mxu%   bound"]
    for (subnet, bucket), row in profile.items():
        mxu = (f"{row['mxu_util'] * 100:5.1f}%"
               if "mxu_util" in row else "    --")
        bound = row.get("bound", "--")
        lines.append(f"{subnet:<20s} {bucket:>3d} {row['batches']:>7d} "
                     f"{row['items']:>6d} {row['ms_per_batch']:>9.3f} "
                     f"{row['items_per_s']:>8.1f} {mxu:>7s}  {bound}")
    return "\n".join(lines)
