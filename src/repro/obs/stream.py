"""Live Perfetto streaming: append spans to disk as requests retire.

The one-shot exporter (:func:`repro.obs.export.write_chrome_trace`)
dumps whatever the tracer RETAINED at exit — bounded, but a crash loses
the run and a long soak only keeps the sample.  :class:`TraceStreamer`
instead hooks :attr:`Tracer.on_retire` and appends every finished
request's events the moment it retires, in the incremental JSON Array
Format (``[`` then one ``{event},`` per line, no closing ``]`` — the
trace-event spec tolerates the missing bracket, so the file loads in
Perfetto mid-run or after a crash).

The shared :class:`~repro.obs.export.EventBuilder` keeps pid/tid
naming state across appends, so the streamed file and a one-shot
export of the same spans name their tracks identically.  Decision
spans are not retired through the hook; :meth:`close` flushes them
from the tracer at shutdown.

``serve.py --stream-trace PATH`` wires this up; the callback runs on
whatever thread retires the request (the engine's completer), so
writes go through one lock and an OS-buffered file handle — a handful
of microseconds per request, off the device-dispatch path.
"""
from __future__ import annotations

import json
import threading
from typing import Optional, Sequence

from repro.obs.export import EventBuilder
from repro.obs.trace import RequestTrace, Span, Tracer


class TraceStreamer:
    """Append-as-they-retire Perfetto stream over one tracer."""

    def __init__(self, path: str, *, t_base: Optional[float] = None):
        self.path = path
        self._f = open(path, "w")
        self._f.write("[\n")
        self._lock = threading.Lock()
        self._builder: Optional[EventBuilder] = (
            None if t_base is None else EventBuilder(t_base=t_base))
        self._tracer: Optional[Tracer] = None
        self.events = 0
        self.closed = False

    # --- wiring ------------------------------------------------------------

    def attach(self, tracer: Tracer) -> "TraceStreamer":
        """Start streaming ``tracer``'s retired requests (one streamer
        per tracer — the hook is a single slot)."""
        tracer.on_retire = self.on_retire
        self._tracer = tracer
        return self

    def on_retire(self, tr: RequestTrace):
        self._emit(tr.spans, links=tr.links)

    # --- writing -----------------------------------------------------------

    def _emit(self, spans: Sequence[Span], links: Sequence[int] = ()):
        with self._lock:
            if self.closed:
                return
            for s in spans:
                if self._builder is None:
                    # rebase on the first span seen, like the one-shot
                    # exporter rebases on the earliest span
                    self._builder = EventBuilder(t_base=s.t0)
                for ev in self._builder.events_for(s, links=links):
                    self._f.write(json.dumps(ev, indent=None,
                                             separators=(",", ":"))
                                  + ",\n")
                    self.events += 1
            self._f.flush()

    def close(self, tracer: Optional[Tracer] = None) -> int:
        """Flush decision spans (they have no retire event), detach,
        and close the file; returns the total event count."""
        tracer = tracer if tracer is not None else self._tracer
        if tracer is not None:
            with tracer._lock:
                decisions = list(tracer.decisions)
            self._emit(decisions)
            if tracer.on_retire == self.on_retire:
                tracer.on_retire = None
        with self._lock:
            if not self.closed:
                self.closed = True
                self._f.close()
        return self.events
