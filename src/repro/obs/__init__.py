"""repro.obs — end-to-end request tracing + metrics for the whole stack.

The paper's runtime layer *monitors* dynamically changing performance
targets and hardware resources; before this package the repo could only
report aggregates (p95, energy totals).  ``repro.obs`` closes the loop
for **individual requests and decisions**: one span schema from the
cluster router down to Pallas dispatch, in both time domains.

Quick start (live)::

    from repro.obs import Tracer, MetricsRegistry, decompose_latency
    from repro.obs.export import write_chrome_trace

    tracer, metrics = Tracer(), MetricsRegistry()
    cluster = Cluster(nodes, router, tracer=tracer, metrics=metrics)
    ... serve traffic ...
    print(format_decomposition(decompose_latency(tracer)))
    write_chrome_trace(tracer, "trace.json")   # open in ui.perfetto.dev
    print(metrics.to_prometheus())

Quick start (virtual time) — the simulators accept the same objects and
emit the *same span schema* with virtual timestamps::

    report = simulate_cluster(..., tracer=Tracer(clock=lambda: 0.0))

What's inside:

* ``trace``    — :class:`Tracer`: bounded, thread-safe, tail-biased
  span buffer (always keeps the slowest K% of requests plus a seeded
  uniform sample); the fixed span vocabulary and its :data:`SCHEMA`
  (``request → route → queue → collect → stack → dispatch → device →
  complete`` plus ``arbitrate`` / ``rebalance`` / ``migrate`` /
  ``preempt`` / ``scale`` / ``health_fail`` decision spans).
* ``metrics``  — :class:`MetricsRegistry`: counters / gauges /
  fixed-bucket histograms with labels, Prometheus-text + JSON export,
  and the one shared nearest-rank :func:`quantile` every percentile in
  the repo routes through.
* ``analyze``  — :func:`decompose_latency`: per-class p50/p95 split
  into queue / collect / stack / dispatch / device / warming, with the
  sum-to-measured-latency invariant *asserted*, not assumed.
* ``export``   — Chrome trace-event / Perfetto JSON
  (:func:`to_chrome_trace`, :func:`write_chrome_trace`), incremental
  via the shared :class:`EventBuilder`.
* ``stream``   — :class:`TraceStreamer`: live Perfetto streaming;
  spans append to disk as requests retire (``serve.py
  --stream-trace``).
* ``health``   — the SLO watchtower: per-class multi-window burn-rate
  :class:`Alert`\\ s with regression :class:`Attribution` (which
  component regressed, ranked probable causes from chaos injections
  and decision spans) and histogram-bucket exemplars; its
  :meth:`Watchtower.pressure` signal closes the monitor→diagnose→
  actuate loop through the arbiter and rebalancer.
* ``profile``  — analytic device profiling: retained DEVICE spans
  joined with the analytic FLOPs/bytes model into per-(subnet, bucket)
  MXU utilisation and roofline position.

Design rules: stdlib-only (imported by every layer — must never cycle
or pull in jax); ``tracer=None`` everywhere means zero work on the hot
path; sims pass explicit virtual timestamps, live code lets the
injectable clock default to ``time.perf_counter``.
"""
from repro.obs.analyze import (DecompositionError, decompose_latency,
                               format_decomposition, mean_components)
from repro.obs.export import (EventBuilder, iter_trace_events,
                              to_chrome_trace, write_chrome_trace)
from repro.obs.health import (FAST, PAGE, SLOW, TICKET, Alert, Attribution,
                              BurnWindow, Cause, SLOTarget, Watchtower,
                              default_windows, format_alerts)
from repro.obs.metrics import (DEFAULT_BUCKETS_MS, Counter, Gauge,
                               Histogram, MetricsRegistry, quantile,
                               weighted_quantile)
from repro.obs.profile import (export_profile, format_profile,
                               profile_devices)
from repro.obs.stream import TraceStreamer
from repro.obs.trace import (ARBITRATE, BROWNOUT, CHAOS, COLLECT, COMPLETE,
                             COMPONENTS, DECISION_SPANS, DEVICE, DISPATCH,
                             HEALTH_FAIL, MIGRATE, PREEMPT, QUEUE, REBALANCE,
                             REQUEST_SPANS, ROUTE, SCALE, SCHEMA, STACK,
                             WARMING, RequestTrace, Span, Tracer,
                             validate_schema)

__all__ = [
    "Tracer", "Span", "RequestTrace", "SCHEMA", "COMPONENTS",
    "REQUEST_SPANS", "DECISION_SPANS", "validate_schema",
    "ROUTE", "QUEUE", "COLLECT", "STACK", "DISPATCH", "DEVICE",
    "COMPLETE", "WARMING", "ARBITRATE", "REBALANCE", "MIGRATE",
    "PREEMPT", "SCALE", "HEALTH_FAIL", "CHAOS", "BROWNOUT",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "DEFAULT_BUCKETS_MS", "quantile", "weighted_quantile",
    "decompose_latency", "format_decomposition", "mean_components",
    "DecompositionError",
    "to_chrome_trace", "write_chrome_trace", "EventBuilder",
    "iter_trace_events", "TraceStreamer",
    "Watchtower", "Alert", "Attribution", "Cause", "SLOTarget",
    "BurnWindow", "default_windows", "format_alerts",
    "FAST", "SLOW", "PAGE", "TICKET",
    "profile_devices", "format_profile", "export_profile",
]
