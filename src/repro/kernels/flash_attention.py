"""Pallas TPU kernel: blocked causal flash attention (online softmax).

The serving shapes (prefill_32k, long_500k) need sub-quadratic memory; on
TPU the natural mapping is KV-blocked online softmax with the running
(max, denominator, accumulator) kept in VMEM scratch across the innermost
grid dimension.  Causally-dead KV tiles are skipped (pl.when), so compute
matches the causal optimum.

Grid: (batch*heads, S/bq, T/bkv), KV innermost.  fp32 softmax state; bf16
or f32 inputs.  GQA callers pass q already grouped per kv head.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq, bkv, n_kv, causal, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0]                       # (bq, d)
        k = k_ref[0]                       # (bkv, d)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(qpos >= kpos, s, _NEG)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)[:, None]
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv

    if causal:
        # causally-dead tile: every key index > every query index — skip
        pl.when(ki * bkv <= qi * bq + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 256, bkv: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, S, D), k/v: (BH, T, D) -> (BH, S, D).

    S % bq == 0 and T % bkv == 0 (ops.py pads); D should be a multiple of
    128 for MXU alignment (not enforced — interpret mode tests sweep odd
    sizes too).
    """
    BH, S, D = q.shape
    _, T, _ = k.shape
    assert S % bq == 0 and T % bkv == 0
    nq, nkv = S // bq, T // bkv
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_kernel, bq=bq, bkv=bkv, n_kv=nkv,
                               causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
