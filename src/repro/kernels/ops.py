"""jit'd public wrappers around the Pallas kernels (padding + reshaping).

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container (the kernel body executes in Python) and compile to Mosaic
on a real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.elastic_matmul import elastic_matmul
from repro.kernels.flash_attention import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def elastic_matmul_op(x, w, k_act, n_act, *, bm=128, bk=128, bn=128,
                      interpret=None):
    """Batched elastic matmul: x (..., K) @ w (K, N) with runtime widths."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    bm_eff = min(bm, max(8, M))
    x2 = _pad_to(_pad_to(x2, 0, bm_eff), 1, bk)
    w2 = _pad_to(_pad_to(w, 0, bk), 1, bn)
    y = elastic_matmul(x2, w2.astype(x.dtype),
                       jnp.asarray(k_act, jnp.int32),
                       jnp.asarray(n_act, jnp.int32),
                       bm=bm_eff, bk=bk, bn=bn, interpret=interpret)
    return y[:M, :N].reshape(lead + (N,))


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bkv", "interpret"))
def flash_attention_op(q, k, v, *, causal=True, bq=256, bkv=256,
                       interpret=None):
    """q (B, S, H, D), k/v (B, T, KH, D) -> (B, S, H, D). GQA repeats kv."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, S, H, D = q.shape
    _, T, KH, _ = k.shape
    if KH != H:
        assert H % KH == 0
        rep = H // KH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    bq_eff = min(bq, S)
    bkv_eff = min(bkv, T)
    qf = _pad_to(qf, 1, bq_eff)
    kf = _pad_to(kf, 1, bkv_eff)
    vf = _pad_to(vf, 1, bkv_eff)
    # NOTE: padding keys would corrupt softmax for non-divisible T in the
    # non-causal case; assignment shapes are powers of two so exact here.
    o = flash_attention(qf, kf, vf, causal=causal, bq=bq_eff, bkv=bkv_eff,
                        interpret=interpret)
    o = o[:, :S].reshape(B, H, S, D).transpose(0, 2, 1, 3)
    return o
