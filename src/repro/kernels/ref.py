"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def elastic_matmul_ref(x: jax.Array, w: jax.Array, k_act, n_act) -> jax.Array:
    """y = x[:, :k_act] @ w[:k_act, :n_act], zero beyond n_act."""
    K = x.shape[1]
    N = w.shape[1]
    kmask = (jnp.arange(K) < k_act).astype(x.dtype)
    nmask = (jnp.arange(N) < n_act).astype(x.dtype)
    y = (x * kmask[None, :]) @ w.astype(x.dtype)
    return y * nmask[None, :]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """Naive attention: q/k/v (BH, S|T, D)."""
    D = q.shape[-1]
    s = jnp.einsum("bsd,btd->bst", q, k).astype(jnp.float32)
    s = s / math.sqrt(D)
    if causal:
        S, T = q.shape[1], k.shape[1]
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p.astype(q.dtype), v)
