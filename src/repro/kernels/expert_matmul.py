"""Pallas TPU kernel: expert-gated grouped matmul (MoE hot path).

Dispatched MoE activations arrive as (E, C, d) — one capacity-padded slab
per expert.  The FFN is then E independent matmuls, but at runtime many
slabs are partially or fully EMPTY (capacity padding; decode-scale token
counts; *elastic expert counts* — the paper's knob applied to MoE).  A
plain batched einsum burns MXU cycles on all of them.

This kernel takes the per-expert token counts via scalar prefetch and
  * skips experts with zero tokens (and experts >= the elastic a_experts),
  * skips token tiles beyond the expert's count,
re-pointing skipped DMAs at resident blocks, so MXU work tracks the REAL
load: compute scales with sum(counts), not E*C.

Grid: (E, C/bc, f/bf); fp32 VMEM accumulator; 128-aligned tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(counts_ref, x_ref, w_ref, o_ref, acc_ref, *, bc, bf, n_f):
    e = pl.program_id(0)
    ci = pl.program_id(1)
    live = ci * bc < counts_ref[e]

    @pl.when(live)
    def _compute():
        x = x_ref[0]                      # (bc, d)
        w = w_ref[0]                      # (d, bf)
        # zero rows beyond this expert's token count (boundary tile)
        row = ci * bc + jax.lax.broadcasted_iota(jnp.int32, (bc, 1), 0)
        x = jnp.where(row < counts_ref[e], x, jnp.zeros_like(x))
        o_ref[0] = jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(o_ref.dtype)

    @pl.when(jnp.logical_not(live))
    def _skip():
        o_ref[0] = jnp.zeros_like(o_ref[0])


def expert_matmul(x: jax.Array, w: jax.Array, counts: jax.Array, *,
                  bc: int = 128, bf: int = 128,
                  interpret: bool = False) -> jax.Array:
    """out[e, c] = x[e, c] @ w[e] for c < counts[e], else 0.

    x: (E, C, d); w: (E, d, F); counts: (E,) int32 (traced ok — one
    executable covers every load/elastic-expert setting).
    C % bc == 0 and F % bf == 0 (ops.py pads).
    """
    E, C, d = x.shape
    _, _, F = w.shape
    assert C % bc == 0 and F % bf == 0
    nc, nf = C // bc, F // bf

    def x_map(e, ci, fi, cnt):
        live = ci * bc < cnt[e]
        return (e, jax.lax.select(live, ci, 0), 0)

    def w_map(e, ci, fi, cnt):
        live = ci * bc < cnt[e]
        return (jax.lax.select(live, e, e), 0, fi)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(E, nc, nf),
        in_specs=[
            pl.BlockSpec((1, bc, d), x_map),
            pl.BlockSpec((1, d, bf), w_map),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda e, ci, fi, cnt: (e, ci, fi)),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
    )
    kernel = functools.partial(_kernel, bc=bc, bf=bf, n_f=nf)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        interpret=interpret,
    )(counts.astype(jnp.int32), x, w)


def expert_matmul_ref(x: jax.Array, w: jax.Array,
                      counts: jax.Array) -> jax.Array:
    """Pure-jnp oracle."""
    E, C, _ = x.shape
    mask = (jnp.arange(C)[None, :] < counts[:, None]).astype(x.dtype)
    return jnp.einsum("ecd,edf->ecf", x * mask[..., None],
                      w.astype(x.dtype))
