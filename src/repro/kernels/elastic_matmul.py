"""Pallas TPU kernel: width-elastic matmul — the paper's hot spot.

A Dynamic-OFA sub-network runs y = x[:, :k_act] @ W[:k_act, :n_act] where
(k_act, n_act) change at RUNTIME (channel scaling).  Recompiling per width
(sliced mode) is the fastest steady-state option, but switching then costs
a compile.  This kernel gives the third point on that trade-off curve: ONE
compiled executable whose MXU work scales with the active width.

TPU mapping (HW adaptation, DESIGN.md §2):
  * grid (M/bm, N/bn, K/bk), K innermost; fp32 VMEM accumulator scratch;
  * (k_act, n_act) arrive via scalar prefetch (SMEM) so both the index_map
    and the kernel body can read them;
  * tiles with n-offset >= n_act or k-offset >= k_act SKIP their MXU work
    (pl.when) and their index_map re-points the DMA at an already-resident
    block, so skipped tiles cost neither bandwidth nor compute;
  * the boundary tile masks lanes beyond the active count, so results are
    bit-comparable to slicing (property-tested against ref.py).

Block sizes default to (128, 128, 128) — MXU-aligned (128x128 systolic
array, lane width 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(scalars_ref, x_ref, w_ref, o_ref, acc_ref, *, bm, bk, bn,
            n_k_tiles):
    k_act = scalars_ref[0]
    n_act = scalars_ref[1]
    ni = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # is this (n, k) tile inside the active region?
    live = jnp.logical_and(ni * bn < n_act, ki * bk < k_act)

    @pl.when(live)
    def _compute():
        x = x_ref[...]
        w = w_ref[...]
        # boundary k tile: zero lanes beyond k_act
        k_off = ki * bk
        kmask = (k_off + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
                 < k_act)
        w = jnp.where(kmask, w, jnp.zeros_like(w))
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == n_k_tiles - 1)
    def _emit():
        n_off = ni * bn
        nmask = (n_off + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
                 < n_act)
        out = jnp.where(nmask, acc_ref[...], jnp.zeros_like(acc_ref))
        o_ref[...] = out.astype(o_ref.dtype)


def elastic_matmul(x: jax.Array, w: jax.Array, k_act, n_act, *,
                   bm: int = 128, bk: int = 128, bn: int = 128,
                   interpret: bool = False) -> jax.Array:
    """y[m, n] = sum_{k<k_act} x[m, k] w[k, n] for n < n_act, else 0.

    x: (M, K), w: (K, N); k_act/n_act: int32 scalars (traced ok).
    M, K, N must be multiples of the block sizes (ops.py pads).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0
    nm, nn, nk = M // bm, N // bn, K // bk
    scalars = jnp.asarray([k_act, n_act], jnp.int32)

    def x_map(i, j, k, scal):
        # skipped tiles re-fetch block (i, 0): no fresh DMA traffic
        live_k = k * bk < scal[0]
        return (i, jax.lax.select(live_k, k, 0))

    def w_map(i, j, k, scal):
        live = jnp.logical_and(j * bn < scal[1], k * bk < scal[0])
        return (jax.lax.select(live, k, 0), jax.lax.select(live, j, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), x_map),
            pl.BlockSpec((bk, bn), w_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, scal: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = functools.partial(_kernel, bm=bm, bk=bk, bn=bn, n_k_tiles=nk)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(scalars, x, w)
