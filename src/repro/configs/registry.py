"""Architecture registry: ``--arch <id>`` resolves here.

Each architecture module registers an :class:`ArchDef` with its FULL
(paper-table) config, a reduced smoke config of the same family, its
assigned input-shape set, and its optimizer/precision policy.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                  # train | prefill | decode | diff_train | diff_gen
    #                            | vis_train | vis_serve
    seq_len: int = 0
    global_batch: int = 0
    img_res: int = 0
    steps: int = 0
    note: str = ""


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    family: str                # lm | diffusion | vision
    make_config: Callable      # () -> full model config
    make_smoke: Callable       # () -> reduced model config
    shapes: Dict[str, ShapeSpec]
    optimizer: str = "adamw"   # adamw | adafactor | sgdm
    source: str = ""

    def shape(self, name: str) -> ShapeSpec:
        return self.shapes[name]


_REGISTRY: Dict[str, ArchDef] = {}

_MODULES = (
    "kimi_k2_1t_a32b", "deepseek_moe_16b", "qwen1_5_110b", "granite_20b",
    "unet_sdxl", "dit_l2",
    "deit_b", "vit_l16", "resnet_152", "efficientnet_b7",
    "dynamic_ofa_supernet",
)


def register(arch: ArchDef) -> ArchDef:
    _REGISTRY[arch.arch_id] = arch
    return arch


def get_arch(arch_id: str) -> ArchDef:
    if not _REGISTRY:
        load_all()
    key = arch_id.replace("-", "_").replace(".", "_")
    for k, v in _REGISTRY.items():
        if k == arch_id or k.replace("-", "_").replace(".", "_") == key:
            return v
    raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")


def list_archs():
    if not _REGISTRY:
        load_all()
    return sorted(_REGISTRY)


def load_all():
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")


# ---------------------------------------------------------------------------
# shared shape sets (assigned per family)
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768,
                             global_batch=32),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768,
                            global_batch=128),
    "long_500k": ShapeSpec(
        "long_500k", "decode", seq_len=524288, global_batch=1,
        note="decode vs a 512k KV cache is O(S); run for all LM archs "
             "(full-attention only at prefill, which is out of scope here)"),
}

DIFF_SHAPES = {
    "train_256": ShapeSpec("train_256", "diff_train", img_res=256,
                           global_batch=256, steps=1000),
    "gen_1024": ShapeSpec("gen_1024", "diff_gen", img_res=1024,
                          global_batch=4, steps=50),
    "gen_fast": ShapeSpec("gen_fast", "diff_gen", img_res=512,
                          global_batch=16, steps=4),
    "train_1024": ShapeSpec("train_1024", "diff_train", img_res=1024,
                            global_batch=32, steps=1000),
}

VIS_SHAPES = {
    "cls_224": ShapeSpec("cls_224", "vis_train", img_res=224, global_batch=256),
    "cls_384": ShapeSpec("cls_384", "vis_train", img_res=384, global_batch=64),
    "serve_b1": ShapeSpec("serve_b1", "vis_serve", img_res=224, global_batch=1),
    "serve_b128": ShapeSpec("serve_b128", "vis_serve", img_res=224,
                            global_batch=128),
}
