"""dynamic-ofa-supernet — the PAPER's own architecture.

The paper deploys Dynamic-OFA: a ViT/ConvNet supernet whose Pareto-optimal
sub-networks are switched at runtime ([6] Lou et al. CVPRW'21 for ConvNets,
[8] Parry et al. MLCAD'21 for Transformers).  We model it as a ViT-S-sized
supernet with the full elastic space (width/ffn/heads/depth), trained with
the sandwich rule + in-place distillation, and serve it through the runtime
governor.  This is the config the paper-reproduction benchmarks use.
"""
from repro.configs.registry import ArchDef, VIS_SHAPES, register
from repro.core.types import ElasticSpace
from repro.models.vit import ViTConfig

ELASTIC = ElasticSpace(
    width_mults=(0.5, 0.75, 1.0),
    ffn_mults=(0.25, 0.5, 0.75, 1.0),
    heads_mults=(0.5, 0.75, 1.0),
    depth_mults=(1.0 / 3.0, 0.5, 2.0 / 3.0, 5.0 / 6.0, 1.0),
)


def make_config() -> ViTConfig:
    return ViTConfig(
        name="dynamic-ofa-supernet", img_res=224, patch=16, n_layers=12,
        d_model=384, n_heads=6, d_ff=1536, exit_layers=(3, 5, 7, 9, 11),
        param_dtype="float32", compute_dtype="bfloat16", elastic=ELASTIC,
    )


def make_smoke() -> ViTConfig:
    return ViTConfig(
        name="dynamic-ofa-smoke", img_res=32, patch=8, n_layers=6,
        d_model=64, n_heads=4, d_ff=256, n_classes=10,
        exit_layers=(1, 3, 5), param_dtype="float32", compute_dtype="float32",
        elastic=ElasticSpace(width_mults=(0.5, 1.0), ffn_mults=(0.25, 0.5, 1.0),
                             heads_mults=(0.5, 1.0),
                             depth_mults=(1.0 / 3.0, 2.0 / 3.0, 1.0)),
    )


register(ArchDef(
    arch_id="dynamic-ofa-supernet", family="vision",
    make_config=make_config, make_smoke=make_smoke,
    shapes=VIS_SHAPES, optimizer="adamw",
    source="paper [6,7,8]: Dynamic-OFA / OFA / Dynamic Transformer",
))
