"""unet-sdxl — SDXL UNet backbone [arXiv:2307.01952; paper tier].

img_res=1024 latent=128, ch=320, ch_mult=(1,2,4), 2 res blocks,
transformer_depth (0,2,10) [SDXL stage0 has no attention], ctx_dim=2048.
The text-encoder frontend is a stub: ctx/pooled embeddings are inputs.
"""
from repro.configs.registry import ArchDef, DIFF_SHAPES, register
from repro.core.types import ElasticSpace
from repro.models.unet import UNetConfig

ELASTIC = ElasticSpace(
    ffn_mults=(0.5, 0.75, 1.0),
    depth_mults=(0.3, 0.5, 1.0),      # transformer-depth scaling (10 -> 3/5/10)
)


def make_config() -> UNetConfig:
    return UNetConfig(
        name="unet-sdxl", img_res=1024, ch=320, ch_mult=(1, 2, 4),
        n_res_blocks=2, transformer_depth=(0, 2, 10), ctx_dim=2048,
        d_head=64, pooled_dim=1280,
        param_dtype="float32", compute_dtype="bfloat16",
        elastic=ELASTIC,
    )


def make_smoke() -> UNetConfig:
    return UNetConfig(
        name="unet-smoke", img_res=64, ch=32, ch_mult=(1, 2),
        n_res_blocks=1, transformer_depth=(0, 2), ctx_dim=64, d_head=16,
        pooled_dim=32, param_dtype="float32", compute_dtype="float32",
        elastic=ElasticSpace(ffn_mults=(0.5, 1.0), depth_mults=(0.5, 1.0)),
    )


register(ArchDef(
    arch_id="unet-sdxl", family="diffusion",
    make_config=make_config, make_smoke=make_smoke,
    shapes=DIFF_SHAPES, optimizer="adamw",
    source="arXiv:2307.01952 (paper tier)",
))
