"""deepseek-moe-16b — fine-grained MoE [arXiv:2401.06066; hf].

28L d_model=2048 16H (MHA kv=16) d_ff=1408/expert vocab=102400,
64 routed experts top-6 + 2 shared, first layer dense (d_ff 10944).
"""
from repro.configs.registry import ArchDef, LM_SHAPES, register
from repro.core.types import ElasticSpace
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ELASTIC = ElasticSpace(
    ffn_mults=(0.5, 0.75, 1.0),
    heads_mults=(0.5, 0.75, 1.0),
    depth_mults=(0.5, 0.75, 1.0),
    expert_counts=(32, 48, 64),
    top_ks=(2, 4, 6),
)


def make_config() -> LMConfig:
    return LMConfig(
        name="deepseek-moe-16b",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1408, vocab_size=102400,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared=2,
                      capacity_factor=1.25, group_size=256),
        first_k_dense=1, d_ff_dense=10944,
        attn_impl="blocked_causal", block_q=512, block_kv=512,
        remat="dots_nb", param_dtype="float32", compute_dtype="bfloat16",
        elastic=ELASTIC,
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="deepseek-moe-smoke",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=32, vocab_size=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=2,
                      capacity_factor=2.0, group_size=32),
        first_k_dense=1, d_ff_dense=128,
        attn_impl="ref", param_dtype="float32", compute_dtype="float32",
        elastic=ElasticSpace(ffn_mults=(0.5, 1.0), heads_mults=(0.5, 1.0),
                             depth_mults=(0.5, 1.0), expert_counts=(4, 8),
                             top_ks=(1, 2)),
    )


register(ArchDef(
    arch_id="deepseek-moe-16b", family="lm",
    make_config=make_config, make_smoke=make_smoke,
    shapes=LM_SHAPES, optimizer="adamw",
    source="arXiv:2401.06066 (hf tier)",
))
