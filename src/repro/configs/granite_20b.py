"""granite-20b — dense code LM, MQA [arXiv:2405.04324; hf tier].

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.  GPT-BigCode-style:
non-gated 4x GELU MLP with biases.
"""
from repro.configs.registry import ArchDef, LM_SHAPES, register
from repro.core.types import ElasticSpace
from repro.models.transformer import LMConfig

ELASTIC = ElasticSpace(
    ffn_mults=(0.25, 0.5, 0.75, 1.0),
    heads_mults=(2.0 / 3.0, 1.0),        # 32 / 48 heads: divisible by mesh 16
    depth_mults=(0.5, 0.75, 1.0),
)


def make_config() -> LMConfig:
    return LMConfig(
        name="granite-20b",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
        d_ff=24576, vocab_size=49152, qkv_bias=True, gated_mlp=False,
        act="gelu",
        attn_impl="blocked_causal", block_q=512, block_kv=512,
        remat="dots_nb", param_dtype="float32", compute_dtype="bfloat16",
        elastic=ELASTIC,
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="granite-smoke",
        n_layers=4, d_model=64, n_heads=8, n_kv_heads=1, d_head=8,
        d_ff=256, vocab_size=512, qkv_bias=True, gated_mlp=False, act="gelu",
        attn_impl="ref", param_dtype="float32", compute_dtype="float32",
        elastic=ElasticSpace(ffn_mults=(0.5, 1.0), heads_mults=(0.5, 1.0),
                             depth_mults=(0.5, 1.0)),
    )


register(ArchDef(
    arch_id="granite-20b", family="lm",
    make_config=make_config, make_smoke=make_smoke,
    shapes=LM_SHAPES, optimizer="adamw",
    source="arXiv:2405.04324 (hf tier)",
))
