"""Architecture configs: one module per assigned arch + the paper's own."""
from repro.configs.registry import (ArchDef, ShapeSpec, get_arch, list_archs,
                                    load_all)
