"""qwen1.5-110b — dense LM with QKV bias [hf:Qwen/Qwen1.5; hf tier].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""
from repro.configs.registry import ArchDef, LM_SHAPES, register
from repro.core.types import ElasticSpace
from repro.models.transformer import LMConfig

ELASTIC = ElasticSpace(
    ffn_mults=(0.25, 0.5, 0.75, 1.0),   # 12288/24576/36864/49152 — all /16 even
    heads_mults=(0.5, 0.75, 1.0),       # 32/48/64 heads, GQA groups stay even
    depth_mults=(0.5, 0.75, 1.0),
)


def make_config() -> LMConfig:
    return LMConfig(
        name="qwen1.5-110b",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=49152, vocab_size=152064, qkv_bias=True,
        attn_impl="blocked_causal", block_q=512, block_kv=512,
        remat="dots_nb", param_dtype="float32", compute_dtype="bfloat16",
        elastic=ELASTIC,
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="qwen1.5-smoke",
        n_layers=4, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
        d_ff=256, vocab_size=512, qkv_bias=True,
        attn_impl="ref", param_dtype="float32", compute_dtype="float32",
        elastic=ElasticSpace(ffn_mults=(0.5, 1.0), heads_mults=(0.5, 1.0),
                             depth_mults=(0.5, 1.0)),
    )


register(ArchDef(
    arch_id="qwen1.5-110b", family="lm",
    make_config=make_config, make_smoke=make_smoke,
    shapes=LM_SHAPES, optimizer="adamw",
    source="hf:Qwen/Qwen1.5 (hf tier)",
))
