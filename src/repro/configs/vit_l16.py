"""vit-l16 — ViT-Large/16 [arXiv:2010.11929; paper tier].

img_res=224 patch=16 24L d_model=1024 16H d_ff=4096.
"""
from repro.configs.registry import ArchDef, VIS_SHAPES, register
from repro.core.types import ElasticSpace
from repro.models.vit import ViTConfig

ELASTIC = ElasticSpace(
    width_mults=(0.5, 0.75, 1.0),
    ffn_mults=(0.25, 0.5, 0.75, 1.0),
    heads_mults=(0.5, 0.75, 1.0),
    depth_mults=(0.25, 0.5, 0.75, 1.0),
)


def make_config() -> ViTConfig:
    return ViTConfig(
        name="vit-l16", img_res=224, patch=16, n_layers=24, d_model=1024,
        n_heads=16, d_ff=4096, exit_layers=(7, 15, 23),
        param_dtype="float32", compute_dtype="bfloat16", elastic=ELASTIC,
    )


def make_smoke() -> ViTConfig:
    return ViTConfig(
        name="vit-smoke", img_res=32, patch=8, n_layers=4, d_model=32,
        n_heads=4, d_ff=64, n_classes=10, param_dtype="float32",
        compute_dtype="float32",
        elastic=ElasticSpace(width_mults=(0.5, 1.0), ffn_mults=(0.5, 1.0),
                             heads_mults=(0.5, 1.0), depth_mults=(0.5, 1.0)),
    )


register(ArchDef(
    arch_id="vit-l16", family="vision",
    make_config=make_config, make_smoke=make_smoke,
    shapes=VIS_SHAPES, optimizer="adamw",
    source="arXiv:2010.11929 (paper tier)",
))
