"""efficientnet-b7 — compound-scaled MBConv net [arXiv:1905.11946; paper].

width_mult=2.0 depth_mult=3.1 img_res=600.  Runtime slimmable width
settings + elastic depth/kernel on top (the paper technique's native fit:
EfficientNet already parameterises width/depth/resolution).
"""
from repro.configs.registry import ArchDef, VIS_SHAPES, register
from repro.core.types import ElasticSpace
from repro.models.efficientnet import EffNetConfig

WIDTH_SETTINGS = (1.0, 0.75, 0.5)

ELASTIC = ElasticSpace(
    width_mults=WIDTH_SETTINGS,
    depth_mults=(0.5, 0.75, 1.0),
    kernel_sizes=(3, 5),
)


def make_config() -> EffNetConfig:
    return EffNetConfig(
        name="efficientnet-b7", width_mult=2.0, depth_mult=3.1, img_res=600,
        width_settings=WIDTH_SETTINGS,
        param_dtype="float32", compute_dtype="bfloat16", elastic=ELASTIC,
    )


def make_smoke() -> EffNetConfig:
    return EffNetConfig(
        name="effnet-smoke", width_mult=0.5, depth_mult=0.5, img_res=32,
        n_classes=10, width_settings=(1.0, 0.5),
        param_dtype="float32", compute_dtype="float32",
        elastic=ElasticSpace(width_mults=(1.0, 0.5), depth_mults=(0.5, 1.0),
                             kernel_sizes=(3, 5)),
    )


register(ArchDef(
    arch_id="efficientnet-b7", family="vision",
    make_config=make_config, make_smoke=make_smoke,
    shapes=VIS_SHAPES, optimizer="sgdm",
    source="arXiv:1905.11946 (paper tier)",
))
