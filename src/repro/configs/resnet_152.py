"""resnet-152 — bottleneck ResNet [arXiv:1512.03385; paper tier].

depths (3,8,36,3), width 64, bottleneck x4.  Slimmable width settings with
switchable BN per the slimmable-networks recipe.
"""
from repro.configs.registry import ArchDef, VIS_SHAPES, register
from repro.core.types import ElasticSpace
from repro.models.resnet import ResNetConfig

WIDTH_SETTINGS = (1.0, 0.75, 0.5, 0.25)

ELASTIC = ElasticSpace(
    width_mults=WIDTH_SETTINGS,
    depth_mults=(0.5, 0.75, 1.0),
)


def make_config() -> ResNetConfig:
    return ResNetConfig(
        name="resnet-152", depths=(3, 8, 36, 3), width=64, img_res=224,
        width_settings=WIDTH_SETTINGS,
        param_dtype="float32", compute_dtype="bfloat16", elastic=ELASTIC,
    )


def make_smoke() -> ResNetConfig:
    return ResNetConfig(
        name="resnet-smoke", depths=(2, 2), width=16, img_res=32,
        n_classes=10, width_settings=(1.0, 0.5),
        param_dtype="float32", compute_dtype="float32",
        elastic=ElasticSpace(width_mults=(1.0, 0.5), depth_mults=(0.5, 1.0)),
    )


register(ArchDef(
    arch_id="resnet-152", family="vision",
    make_config=make_config, make_smoke=make_smoke,
    shapes=VIS_SHAPES, optimizer="sgdm",
    source="arXiv:1512.03385 (paper tier)",
))
