"""dit-l2 — Diffusion Transformer L/2 [arXiv:2212.09748; paper tier].

img_res=256 (latent 32), patch=2, 24L d_model=1024 16H.
"""
from repro.configs.registry import ArchDef, DIFF_SHAPES, register
from repro.core.types import ElasticSpace
from repro.models.dit import DiTConfig

ELASTIC = ElasticSpace(
    width_mults=(0.5, 0.75, 1.0),
    ffn_mults=(0.5, 0.75, 1.0),
    heads_mults=(0.5, 0.75, 1.0),
    depth_mults=(0.5, 0.75, 1.0),
)


def make_config() -> DiTConfig:
    return DiTConfig(
        name="dit-l2", img_res=256, patch=2, n_layers=24, d_model=1024,
        n_heads=16, remat="dots",
        param_dtype="float32", compute_dtype="bfloat16", elastic=ELASTIC,
    )


def make_smoke() -> DiTConfig:
    return DiTConfig(
        name="dit-smoke", img_res=64, patch=2, n_layers=2, d_model=32,
        n_heads=4, n_classes=10, param_dtype="float32",
        compute_dtype="float32",
        elastic=ElasticSpace(width_mults=(0.5, 1.0), ffn_mults=(0.5, 1.0),
                             heads_mults=(0.5, 1.0), depth_mults=(0.5, 1.0)),
    )


register(ArchDef(
    arch_id="dit-l2", family="diffusion",
    make_config=make_config, make_smoke=make_smoke,
    shapes=DIFF_SHAPES, optimizer="adamw",
    source="arXiv:2212.09748 (paper tier)",
))
