"""kimi-k2-1t-a32b — trillion-param MoE LM [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff=2048/expert vocab=163840,
MoE 384 experts top-8 (+1 shared, first layer dense — DeepSeek-V3-style
layout; the dense-layer FFN width is an approximation, noted in DESIGN.md).

Precision/optimizer policy: bf16 params + Adafactor (factored second
moment) — AdamW fp32 state for 1T params cannot fit 256 x 16 GB v5e; see
EXPERIMENTS.md §Dry-run notes.
"""
from repro.configs.registry import ArchDef, LM_SHAPES, register
from repro.core.types import ElasticSpace
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ELASTIC = ElasticSpace(
    ffn_mults=(0.5, 0.75, 1.0),
    heads_mults=(0.5, 0.75, 1.0),
    depth_mults=(0.5, 0.75, 1.0),
    expert_counts=(192, 256, 384),
    top_ks=(4, 6, 8),
)


def make_config() -> LMConfig:
    return LMConfig(
        name="kimi-k2-1t-a32b",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_head=112,
        d_ff=2048, vocab_size=163840,
        moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, n_shared=1,
                      capacity_factor=1.25, group_size=256),
        first_k_dense=1, d_ff_dense=18432,
        attn_impl="blocked_causal", block_q=512, block_kv=512,
        remat="dots_nb", param_dtype="bfloat16", compute_dtype="bfloat16",
        elastic=ELASTIC,
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="kimi-k2-smoke",
        n_layers=4, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
        d_ff=32, vocab_size=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1,
                      capacity_factor=2.0, group_size=32),
        first_k_dense=1, d_ff_dense=128,
        attn_impl="ref", param_dtype="float32", compute_dtype="float32",
        elastic=ElasticSpace(ffn_mults=(0.5, 1.0), heads_mults=(0.5, 1.0),
                             depth_mults=(0.5, 1.0), expert_counts=(4, 8),
                             top_ks=(1, 2)),
    )


register(ArchDef(
    arch_id="kimi-k2-1t-a32b", family="lm",
    make_config=make_config, make_smoke=make_smoke,
    shapes=LM_SHAPES, optimizer="adafactor",
    source="arXiv:2501.kimi2 (paper-table; unverified tier)",
))
