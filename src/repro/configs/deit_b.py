"""deit-b — DeiT-Base with distillation token [arXiv:2012.12877; paper tier].

img_res=224 patch=16 12L d_model=768 12H d_ff=3072 + distill token.
"""
from repro.configs.registry import ArchDef, VIS_SHAPES, register
from repro.core.types import ElasticSpace
from repro.models.vit import ViTConfig

ELASTIC = ElasticSpace(
    width_mults=(0.5, 0.75, 1.0),
    ffn_mults=(0.25, 0.5, 0.75, 1.0),
    heads_mults=(0.5, 0.75, 1.0),
    depth_mults=(0.25, 0.5, 0.75, 1.0),
)


def make_config() -> ViTConfig:
    return ViTConfig(
        name="deit-b", img_res=224, patch=16, n_layers=12, d_model=768,
        n_heads=12, d_ff=3072, distill_token=True, exit_layers=(3, 7, 11),
        param_dtype="float32", compute_dtype="bfloat16", elastic=ELASTIC,
    )


def make_smoke() -> ViTConfig:
    return ViTConfig(
        name="deit-smoke", img_res=32, patch=8, n_layers=4, d_model=32,
        n_heads=4, d_ff=64, n_classes=10, distill_token=True,
        exit_layers=(1, 3), param_dtype="float32", compute_dtype="float32",
        elastic=ElasticSpace(width_mults=(0.5, 1.0), ffn_mults=(0.5, 1.0),
                             heads_mults=(0.5, 1.0), depth_mults=(0.5, 1.0)),
    )


register(ArchDef(
    arch_id="deit-b", family="vision",
    make_config=make_config, make_smoke=make_smoke,
    shapes=VIS_SHAPES, optimizer="adamw",
    source="arXiv:2012.12877 (paper tier)",
))
