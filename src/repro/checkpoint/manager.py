"""Checkpointing: async sharded save, keep-k rotation, elastic restore.

Fault-tolerance contract (large-scale runnability):
  * saves are ATOMIC (write to ``.tmp`` dir, fsync, rename) so a failure
    mid-save never corrupts the latest good checkpoint;
  * saves are ASYNC (device->host copy happens synchronously — cheap —
    then disk IO on a background thread) so the train loop isn't blocked;
  * restore is ELASTIC: arrays are re-placed with whatever mesh/sharding
    the *restoring* job uses, so a 512-chip run resumes on 256 chips after
    losing a pod (tests/test_checkpoint.py proves reshard equivalence);
  * on multi-host, each process saves only its addressable shards under
    ``proc<k>/`` (single-host saves the full arrays — this container).
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _to_host(tree):
    return jax.tree_util.tree_map(lambda x: np.asarray(x), tree)


def save_checkpoint(ckpt_dir: str, step: int, state: Any, *,
                    blocking: bool = True) -> threading.Thread:
    """state: any pytree (params/opt/rng/...).  Returns the writer thread."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    host_state = _to_host(state)  # synchronous D2H; cheap vs training step
    leaves, treedef = jax.tree_util.tree_flatten(host_state)

    def write():
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for i, leaf in enumerate(leaves):
            np.save(tmp / f"leaf_{i:05d}.npy", leaf)
        with open(tmp / "treedef.pkl", "wb") as f:
            pickle.dump(treedef, f)
        (tmp / "meta.json").write_text(json.dumps(
            {"step": step, "n_leaves": len(leaves),
             "time": time.time(),  # repro: allow-wallclock(checkpoint metadata timestamp; never read by sim paths)
             "process_count": jax.process_count()}))
        os.replace(tmp, final)  # atomic publish

    t = threading.Thread(target=write, daemon=True)
    t.start()
    if blocking:
        t.join()
    return t


def restore_checkpoint(ckpt_dir: str, *, step: Optional[int] = None,
                       shardings=None) -> tuple:
    """Returns (step, state).  ``shardings``: optional pytree of
    NamedSharding to re-place arrays on a (possibly different) mesh."""
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*"))
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = step if step is not None else steps[-1]
    d = ckpt_dir / f"step_{step:08d}"
    with open(d / "treedef.pkl", "rb") as f:
        treedef = pickle.load(f)
    meta = json.loads((d / "meta.json").read_text())
    leaves = [np.load(d / f"leaf_{i:05d}.npy")
              for i in range(meta["n_leaves"])]
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return step, state


class CheckpointManager:
    """save_every/keep-k rotation + restart discovery + async writes."""

    def __init__(self, ckpt_dir: str, *, save_every: int = 100,
                 keep: int = 3, async_save: bool = True):
        self.dir = Path(ckpt_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.save_every = save_every
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, state) -> bool:
        if step % self.save_every:
            return False
        self.wait()
        self._pending = save_checkpoint(self.dir, step, state,
                                        blocking=not self.async_save)
        self._gc()
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*"))
        return steps[-1] if steps else None

    def restore_latest(self, shardings=None):
        return restore_checkpoint(self.dir, shardings=shardings)

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
