"""Chaos injection + request reliability (PR 8).

One seeded :class:`Scenario` drives BOTH timelines: the virtual-time
simulator (``simulate_cluster(chaos=...)``) and a live
:class:`ChaosController` thread perturbing a real cluster.  The
:class:`Reliability` layer (per-class retries with deadline-aware
exponential backoff, a cluster-level retry budget, hedged interactive
requests, brownout degradation) is what the injections exercise.

``ChaosController`` is imported lazily — it pulls in the cluster
front-end, which itself (via the simulator) depends on this package's
policy types.
"""
from repro.chaos.engine import ChaosTimeline
from repro.chaos.reliability import (BrownoutPolicy, Reliability,
                                     RetryBudget, RetryPolicy)
from repro.chaos.scenario import (DEFAULT_LADDER, FAIL_STOP, KINDS,
                                  PARTITION, RACK_FAIL, SPOT_PREEMPT,
                                  STRAGGLER, THERMAL, WEDGE, Injection,
                                  Scenario, generate)

__all__ = [
    "BrownoutPolicy", "ChaosController", "ChaosTimeline", "DEFAULT_LADDER",
    "FAIL_STOP", "Injection", "KINDS", "PARTITION", "RACK_FAIL",
    "Reliability", "RetryBudget", "RetryPolicy", "SPOT_PREEMPT",
    "STRAGGLER", "Scenario", "THERMAL", "WEDGE", "generate",
]


def __getattr__(name):
    if name == "ChaosController":   # lazy: avoids a cluster import cycle
        from repro.chaos.live import ChaosController
        return ChaosController
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
