"""Seeded, deterministic fault-scenario vocabulary.

A :class:`Scenario` is a named, seeded tuple of :class:`Injection`s —
the shared chaos vocabulary BOTH timelines consume:
:func:`repro.cluster.sim.simulate_cluster` schedules the injections in
virtual time, and the live :class:`repro.chaos.live.ChaosController`
replays the same scenario against a real :class:`repro.cluster.Cluster`
on the wall clock.  Because a scenario is plain data, the same seeded
correlated-failure day can be asserted bit-identical in simulation and
then rehearsed against real servers.

Injection kinds (the paper's "resources change under you", taken to
cluster scale):

* ``fail_stop``     — the node dies NOW; queued work resolves failed.
* ``wedge``         — silent stall: routable, accepts work, completes
  nothing — only the stall health check can see it.
* ``straggler``     — service slows ×``factor`` for ``duration_s``
  (thermal neighbour, noisy co-tenant, fabric retries).
* ``thermal``       — DVFS ladder degradation: the node's temperature
  throttle steps down ``ladder`` over ``duration_s`` then recovers —
  the paper's governor-throttling story as an injected fault.
* ``spot_preempt``  — preemption WITH notice: the node drains for
  ``notice_s`` (no new routes, queues serve out) and then fail-stops.
* ``rack_fail``     — correlated failure: every node in ``nodes``
  fail-stops at the same instant.
* ``partition``     — the router→node edge drops for ``duration_s``:
  no NEW routes reach the node, but it keeps serving what it has.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

FAIL_STOP = "fail_stop"
WEDGE = "wedge"
STRAGGLER = "straggler"
THERMAL = "thermal"
SPOT_PREEMPT = "spot_preempt"
RACK_FAIL = "rack_fail"
PARTITION = "partition"
KINDS = (FAIL_STOP, WEDGE, STRAGGLER, THERMAL, SPOT_PREEMPT, RACK_FAIL,
         PARTITION)

# default DVFS ladder a thermal injection steps through (fractions of
# full frequency, mirroring the LUT's hw-state freq tiers)
DEFAULT_LADDER = (0.875, 0.75, 0.625, 0.5)


@dataclasses.dataclass(frozen=True)
class Injection:
    """One scheduled fault.  ``t`` is seconds from scenario start
    (virtual seconds in the sim; wall seconds / ``speed`` live)."""
    t: float
    kind: str
    node: Optional[str] = None          # target (all kinds but rack_fail)
    nodes: Tuple[str, ...] = ()         # rack_fail: the correlated set
    factor: float = 2.0                 # straggler: service slowdown ×k
    duration_s: float = 0.0             # straggler / thermal / partition
    notice_s: float = 0.0               # spot_preempt: drain window
    ladder: Tuple[float, ...] = DEFAULT_LADDER   # thermal: throttle steps

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown injection kind {self.kind!r} "
                             f"(not in {KINDS})")
        if self.kind == RACK_FAIL:
            if not self.nodes:
                raise ValueError("rack_fail needs a non-empty `nodes`")
        elif self.node is None:
            raise ValueError(f"{self.kind} needs a target `node`")

    def targets(self) -> Tuple[str, ...]:
        return self.nodes if self.kind == RACK_FAIL else (self.node,)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, ordered fault schedule (plain data, fully seeded)."""
    name: str = "scenario"
    seed: int = 0
    injections: Tuple[Injection, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "injections",
                           tuple(sorted(self.injections,
                                        key=lambda i: (i.t, i.kind))))

    def summary(self) -> List[Tuple[float, str, str]]:
        """``(t, kind, node)`` per target — what reports embed."""
        out = []
        for inj in self.injections:
            for nn in inj.targets():
                out.append((inj.t, inj.kind, nn))
        return out


def generate(seed: int, horizon_s: float, node_names: Sequence[str], *,
             racks: Optional[Dict[str, Sequence[str]]] = None,
             n_faults: int = 4,
             kinds: Sequence[str] = (STRAGGLER, THERMAL, WEDGE,
                                     SPOT_PREEMPT, PARTITION, RACK_FAIL,
                                     FAIL_STOP),
             name: str = "generated") -> Scenario:
    """Seeded random scenario: ``n_faults`` injections drawn uniformly
    over ``kinds``/``node_names``/[0, horizon_s).  Same seed ⇒ same
    scenario ⇒ (through the deterministic simulator) bit-identical
    reports — the chaos determinism tests run exactly this."""
    rng = random.Random(seed)
    racks = dict(racks or {})
    injections: List[Injection] = []
    for _ in range(n_faults):
        kind = rng.choice(list(kinds))
        t = round(rng.uniform(0.0, horizon_s), 3)
        if kind == RACK_FAIL and racks:
            rack = rng.choice(sorted(racks))
            injections.append(Injection(t=t, kind=kind,
                                        nodes=tuple(racks[rack])))
            continue
        if kind == RACK_FAIL:
            kind = FAIL_STOP   # no rack map: degrade to a single failure
        nn = rng.choice(list(node_names))
        injections.append(Injection(
            t=t, kind=kind, node=nn,
            factor=round(rng.uniform(1.5, 4.0), 2),
            duration_s=round(rng.uniform(0.5, horizon_s / 2), 3),
            notice_s=round(rng.uniform(0.2, 2.0), 3)))
    return Scenario(name=f"{name}-{seed}", seed=seed,
                    injections=tuple(injections))
