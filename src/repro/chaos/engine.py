"""Compile a :class:`Scenario` into timeline queries + primitive events.

One :class:`ChaosTimeline` serves both consumers:

* the virtual-time simulator polls the CONTINUOUS overlays each epoch —
  :meth:`latency_mult` (stragglers), :meth:`throttle` (thermal DVFS
  ladder) and :meth:`partitioned` (router→node edge down) — and merges
  the DISCRETE events (:meth:`lifecycle`) into its existing
  ``fail_at``/``drain_at``/``wedge_at`` scripting, so chaos rides the
  exact failover machinery operators script by hand;
* the live :class:`~repro.chaos.live.ChaosController` walks
  :meth:`events` — every injection flattened to timestamped primitive
  state changes (including the *ends* of windows and each thermal
  ladder step) — and applies them to a real cluster on the wall clock.

Both views are derived from the same frozen scenario, which is what
makes a simulated chaos day and its live rehearsal the same experiment.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.chaos.scenario import (FAIL_STOP, PARTITION, RACK_FAIL,
                                  SPOT_PREEMPT, STRAGGLER, THERMAL, WEDGE,
                                  Injection, Scenario)

# primitive live/lifecycle actions a scenario compiles down to
FAIL = "fail"
DRAIN = "drain"            # spot-preemption notice: stop routing, serve out
WEDGE_ON = "wedge_on"
STRAGGLE_ON = "straggle_on"
STRAGGLE_OFF = "straggle_off"
THROTTLE = "throttle"      # one thermal ladder step (value carried)
PARTITION_ON = "partition_on"
PARTITION_OFF = "partition_off"


class ChaosTimeline:
    """Deterministic query/event view of one scenario."""

    def __init__(self, scenario: Scenario,
                 node_names: Sequence[str]):
        known = set(node_names)
        for inj in scenario.injections:
            unknown = [n for n in inj.targets() if n not in known]
            if unknown:
                raise ValueError(f"injection {inj.kind!r}@{inj.t}: "
                                 f"unknown nodes {unknown}")
        self.scenario = scenario
        # windows per node for the continuous overlays
        self._stragglers: Dict[str, List[Tuple[float, float, float]]] = {}
        self._thermals: Dict[str, List[Injection]] = {}
        self._partitions: Dict[str, List[Tuple[float, float]]] = {}
        for inj in scenario.injections:
            if inj.kind == STRAGGLER:
                self._stragglers.setdefault(inj.node, []).append(
                    (inj.t, inj.t + inj.duration_s, inj.factor))
            elif inj.kind == THERMAL:
                self._thermals.setdefault(inj.node, []).append(inj)
            elif inj.kind == PARTITION:
                self._partitions.setdefault(inj.node, []).append(
                    (inj.t, inj.t + inj.duration_s))

    # --- continuous overlays (sim polls these each epoch) -------------------

    def latency_mult(self, node: str, t: float) -> float:
        """Product of active straggler slowdowns on ``node`` at ``t``."""
        mult = 1.0
        for t0, t1, factor in self._stragglers.get(node, ()):
            if t0 <= t < t1:
                mult *= factor
        return mult

    def throttle(self, node: str, t: float) -> float:
        """Thermal DVFS throttle at ``t``: the ladder value of the
        deepest active thermal window (1.0 = full frequency; the node
        recovers the instant its window ends)."""
        val = 1.0
        for inj in self._thermals.get(node, ()):
            if inj.t <= t < inj.t + inj.duration_s and inj.ladder:
                frac = (t - inj.t) / max(inj.duration_s, 1e-9)
                idx = min(int(frac * len(inj.ladder)), len(inj.ladder) - 1)
                val = min(val, inj.ladder[idx])
        return val

    def partitioned(self, node: str, t: float) -> bool:
        """Is the router→``node`` edge down at ``t``?  The node keeps
        serving its queue — only NEW routes are blocked."""
        return any(t0 <= t < t1
                   for t0, t1 in self._partitions.get(node, ()))

    # --- discrete lifecycle events (sim merges into fail/drain/wedge) -------

    def lifecycle(self) -> List[Tuple[float, str, str]]:
        """``(t, FAIL|DRAIN|WEDGE_ON, node)`` — the fail-stop family,
        expanded: a rack failure is N simultaneous fails, a spot
        preemption is a drain notice followed by a fail."""
        out: List[Tuple[float, str, str]] = []
        for inj in self.scenario.injections:
            if inj.kind in (FAIL_STOP, RACK_FAIL):
                out.extend((inj.t, FAIL, nn) for nn in inj.targets())
            elif inj.kind == WEDGE:
                out.append((inj.t, WEDGE_ON, inj.node))
            elif inj.kind == SPOT_PREEMPT:
                out.append((inj.t, DRAIN, inj.node))
                out.append((inj.t + inj.notice_s, FAIL, inj.node))
        return sorted(out)

    # --- flattened primitive timeline (live controller walks this) ----------

    def events(self) -> List[Tuple[float, str, str, float]]:
        """Every state change as ``(t, action, node, value)`` — window
        ends and thermal ladder steps included, time-sorted."""
        out: List[Tuple[float, str, str, float]] = [
            (t, action, nn, 0.0) for t, action, nn in self.lifecycle()]
        for nn, wins in self._stragglers.items():
            for t0, t1, factor in wins:
                out.append((t0, STRAGGLE_ON, nn, factor))
                out.append((t1, STRAGGLE_OFF, nn, 1.0))
        for nn, injs in self._thermals.items():
            for inj in injs:
                step = inj.duration_s / max(len(inj.ladder), 1)
                for i, val in enumerate(inj.ladder):
                    out.append((inj.t + i * step, THROTTLE, nn, val))
                out.append((inj.t + inj.duration_s, THROTTLE, nn, 1.0))
        for nn, wins in self._partitions.items():
            for t0, t1 in wins:
                out.append((t0, PARTITION_ON, nn, 0.0))
                out.append((t1, PARTITION_OFF, nn, 1.0))
        return sorted(out)
