"""Request reliability policies: retries, hedges, budget, brownout.

Chaos injection (:mod:`repro.chaos.scenario`) exposes what the serving
stack was missing: a request caught in a failure simply resolved with
an error payload.  This module is the policy layer both drivers consume
(:func:`repro.cluster.sim.simulate_cluster` ``reliability=`` and
:func:`repro.traffic.driver.drive_live` ``reliability=``):

* :class:`RetryPolicy` — per-class: bounded attempts, exponential
  backoff, and DEADLINE-AWARE: a retry that cannot even be resubmitted
  before the request's SLO deadline is never scheduled (it would burn
  capacity to produce a guaranteed-late answer).
* :class:`RetryBudget` — cluster-level: total retries granted may never
  exceed ``burst + fraction × completed`` — a retry storm against a
  degraded fleet self-limits instead of melting the survivors.
* :class:`BrownoutPolicy` — graceful degradation: when the smoothed
  chaos pressure (failures+retries per outcome) of a class stays high,
  the arbiter pins it to its DEGRADE target
  (:meth:`repro.runtime.arbiter.ResourceArbiter.set_brownout`) and
  shedding is suspended — serve degraded instead of dropping, the
  paper's degrade-don't-fail story under injected faults.
* Hedging (``RetryPolicy.hedge=True``) — an interactive-class request
  is enqueued on TWO distinct replicas; the first completion wins and
  the loser is accounted ``hedge_wasted``, never double-counted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-class retry behaviour.  ``max_attempts`` counts the first
    try; ``backoff(k)`` is the wait before attempt ``k+1``."""
    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    hedge: bool = False     # duplicate-submit to a second replica

    def backoff(self, attempts: int) -> float:
        """Backoff after ``attempts`` tries (exponential)."""
        return self.backoff_s * self.backoff_mult ** max(attempts - 1, 0)


@dataclasses.dataclass
class RetryBudget:
    """Cluster-level allowance: retries ≤ burst + fraction × goodput.

    Mutable counters — the drivers take a FRESH copy per run
    (:meth:`fresh`) so two runs from one config are independent and
    deterministic."""
    fraction: float = 0.1
    burst: int = 16
    granted: int = 0
    denied: int = 0

    def fresh(self) -> "RetryBudget":
        return RetryBudget(fraction=self.fraction, burst=self.burst)

    def allowance(self, completed: int) -> float:
        return self.burst + self.fraction * completed

    def allow(self, completed: int) -> bool:
        if self.granted + 1 <= self.allowance(completed):
            self.granted += 1
            return True
        self.denied += 1
        return False


@dataclasses.dataclass(frozen=True)
class BrownoutPolicy:
    """Enter/exit thresholds on the per-class chaos-pressure EWMA
    (failures+retries as a share of that epoch's outcomes)."""
    enter_pressure: float = 0.3
    exit_pressure: float = 0.05
    beta: float = 0.5           # EWMA smoothing per epoch


@dataclasses.dataclass
class Reliability:
    """The whole reliability layer, one object both drivers accept."""
    policies: Dict[str, RetryPolicy] = dataclasses.field(
        default_factory=dict)
    default: Optional[RetryPolicy] = dataclasses.field(
        default_factory=RetryPolicy)
    budget: RetryBudget = dataclasses.field(default_factory=RetryBudget)
    brownout: Optional[BrownoutPolicy] = dataclasses.field(
        default_factory=BrownoutPolicy)

    def policy_for(self, cls_name: str) -> Optional[RetryPolicy]:
        return self.policies.get(cls_name, self.default)
