"""Live chaos: replay a :class:`Scenario` against a real Cluster.

:class:`ChaosController` walks the scenario's flattened primitive
timeline (:meth:`repro.chaos.engine.ChaosTimeline.events`) on the wall
clock (scaled by ``speed``) and perturbs the cluster through the same
surfaces an operator or the paper's runtime would:

* ``fail`` / ``drain``   — :meth:`Cluster.fail` / :meth:`Cluster.drain`
  (spot preemption = drain for the notice window, then fail);
* ``wedge_on``           — every replica server on the node wedges
  (:meth:`DynamicServer.wedge`: silently parked, ``resume()`` defeated)
  so only the stall health check can catch it;
* ``straggle_on/off``    — capacity multiplier on the node's hw state
  (``ClusterNode.chaos_capacity = 1/factor``): fewer effective chips,
  the arbiter re-water-fills onto slower points;
* ``throttle``           — thermal DVFS ladder via
  ``ClusterNode.chaos_throttle`` (filters the LUT to low-frequency
  points, exactly the paper's governor throttling);
* ``partition_on/off``   — router weight 0 on every (class, node) edge
  of the target node: no new routes, in-flight work still completes.

Every applied event is logged (``applied``), counted
(``chaos_injections_total``) and — when the cluster has a tracer —
emitted as a ``chaos`` decision span, so a live chaos day is observable
with the same vocabulary as the simulated one.
"""
from __future__ import annotations

import threading
import time
from typing import List, Tuple

from repro.chaos import engine as ce
from repro.chaos.engine import ChaosTimeline
from repro.chaos.scenario import Scenario
from repro.obs import trace as obs


class ChaosController:
    """Daemon thread applying one scenario to one live cluster."""

    def __init__(self, cluster, scenario: Scenario, *,
                 speed: float = 1.0):
        self.cluster = cluster
        self.scenario = scenario
        self.speed = speed
        self.timeline = ChaosTimeline(scenario, list(cluster.nodes))
        self.applied: List[Tuple[float, str, str]] = []
        self._partitioned: dict = {}   # node -> [(cls, node)] weights set
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # --- lifecycle ----------------------------------------------------------

    def start(self) -> "ChaosController":
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()

    def join(self, timeout_s: float = 30.0):
        if self._thread is not None:
            self._thread.join(timeout=timeout_s)

    @property
    def done(self) -> bool:
        return self._thread is not None and not self._thread.is_alive()

    # --- the injection loop -------------------------------------------------

    def _loop(self):
        t0 = time.perf_counter()
        for t, action, nn, value in self.timeline.events():
            wait = t / self.speed - (time.perf_counter() - t0)
            if wait > 0 and self._stop.wait(wait):
                return
            if self._stop.is_set():
                return
            try:
                self._apply(action, nn, value)
            except Exception:   # noqa: BLE001 — chaos must not kill chaos
                continue
            self.applied.append((t, action, nn))
            self.cluster.metrics.counter("chaos_injections_total",
                                         kind=action).inc()
            if self.cluster.tracer is not None:
                tw = time.perf_counter()
                self.cluster.tracer.decision(obs.CHAOS, tw, tw, node=nn,
                                             kind=action)

    def _apply(self, action: str, nn: str, value: float):
        cluster, node = self.cluster, self.cluster.nodes[nn]
        if action == ce.FAIL:
            cluster.fail(nn, reason=f"chaos: {self.scenario.name} "
                                    f"fail-stop on {nn}")
        elif action == ce.DRAIN:
            # spot-preemption notice: drain in the background for the
            # notice window; the scheduled FAIL lands regardless
            threading.Thread(target=cluster.drain, args=(nn,),
                             kwargs=dict(timeout_s=30.0),
                             daemon=True).start()
        elif action == ce.WEDGE_ON:
            for server in node.servers.values():
                server.wedge()
        elif action == ce.STRAGGLE_ON:
            node.chaos_capacity = 1.0 / max(value, 1.0)
        elif action == ce.STRAGGLE_OFF:
            node.chaos_capacity = 1.0
        elif action == ce.THROTTLE:
            node.chaos_throttle = value
        elif action == ce.PARTITION_ON:
            edges = []
            for cls_name, placed in cluster.placements_snapshot().items():
                if nn in placed:
                    cluster.router.set_weight(cls_name, nn, 0.0)
                    edges.append(cls_name)
            self._partitioned[nn] = edges
        elif action == ce.PARTITION_OFF:
            for cls_name in self._partitioned.pop(nn, ()):
                cluster.router.set_weight(cls_name, nn, None)
