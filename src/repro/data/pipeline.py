"""Data pipeline: deterministic synthetic streams + memmap shards.

Restart semantics: every batch is a pure function of (seed, step), so a
job restored at step N regenerates exactly the batches it would have seen
— deterministic skip-ahead without data-loader state in the checkpoint.
Per-host sharding slices the global batch by process index.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


def host_shard(global_batch: int) -> slice:
    """This process's slice of the global batch."""
    per = global_batch // jax.process_count()
    i = jax.process_index()
    return slice(i * per, (i + 1) * per)


def synthetic_lm_batches(*, global_batch: int, seq_len: int, vocab: int,
                         seed: int = 0, start_step: int = 0
                         ) -> Iterator[dict]:
    """Zipf-ish token stream with next-token labels (learnable structure:
    token t+1 correlates with token t so loss visibly decreases)."""
    sl = host_shard(global_batch)
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        base = rng.zipf(1.5, size=(global_batch, seq_len + 1)) % vocab
        drift = np.cumsum(rng.integers(0, 3, size=(global_batch, seq_len + 1)),
                          axis=1)
        toks = ((base + drift) % vocab).astype(np.int32)
        yield {"tokens": toks[sl, :-1], "labels": toks[sl, 1:]}
        step += 1


def synthetic_image_batches(*, global_batch: int, img_res: int,
                            n_classes: int, seed: int = 0,
                            start_step: int = 0) -> Iterator[dict]:
    """Class-conditional blob images — a small model can actually fit them,
    so supernet-training examples show real accuracy orderings."""
    sl = host_shard(global_batch)
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        labels = rng.integers(0, n_classes, size=global_batch)
        imgs = rng.normal(0, 0.3, size=(global_batch, img_res, img_res, 3))
        # class-dependent quadrant brightness pattern
        q = img_res // 2
        for c in range(n_classes):
            m = labels == c
            gy, gx = (c % 4) // 2, (c % 4) % 2
            imgs[m, gy * q:(gy + 1) * q, gx * q:(gx + 1) * q, c % 3] += \
                1.0 + 0.25 * (c // 4)
        yield {"images": imgs[sl].astype(np.float32),
               "labels": labels[sl].astype(np.int32)}
        step += 1


def memmap_token_batches(path: str, *, global_batch: int, seq_len: int,
                         dtype=np.int32, start_step: int = 0
                         ) -> Iterator[dict]:
    """Production-style binary token file reader (np.memmap, zero-copy),
    deterministic stride order, per-host sharded."""
    data = np.memmap(path, dtype=dtype, mode="r")
    tokens_per_step = global_batch * (seq_len + 1)
    n_steps = len(data) // tokens_per_step
    sl = host_shard(global_batch)
    step = start_step
    while True:
        i = step % max(n_steps, 1)
        chunk = np.asarray(data[i * tokens_per_step:(i + 1) * tokens_per_step])
        chunk = chunk.reshape(global_batch, seq_len + 1)
        yield {"tokens": chunk[sl, :-1].astype(np.int32),
               "labels": chunk[sl, 1:].astype(np.int32)}
        step += 1


class Prefetcher:
    """Background-thread prefetch queue over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._fill, daemon=True)
        self._t.start()

    def _fill(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        except BaseException as e:  # noqa: BLE001 — surface in consumer
            self._err = e
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None and self._err is not None:
            raise self._err
        return item

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
