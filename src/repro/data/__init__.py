from repro.data.pipeline import (Prefetcher, host_shard, memmap_token_batches,
                                 synthetic_image_batches,
                                 synthetic_lm_batches)
