"""Cluster-level admission control.

A tenant's resource share cannot straddle hosts — chips on two machines
never serve one model slice — so cluster admission reduces to a
PLACEMENT question: does some node's headroom (capacity left after its
equal-or-higher-priority tenants' minimal feasible shares) fit the
prospective class's minimal share?  :func:`cluster_admission` asks every
routable node's :meth:`ResourceArbiter.admission_check` and returns the
set of nodes that can host the class — its *placement set* — raising
:class:`AdmissionError` when the set is empty.  Adding a node with
enough headroom turns the same rejected class admissible, which is the
whole point of scaling out.

:func:`cluster_headroom` sums the per-node headroom for observability
(capacity-planning dashboards want the aggregate even though admission
binds per node).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.cluster.node import ClusterNode
from repro.runtime.arbiter import AdmissionError, Headroom
from repro.runtime.lut import LUT


def cluster_admission(nodes: Sequence[ClusterNode], lut: LUT,
                      target_latency_ms: float, *, priority: int = 0,
                      min_accuracy: Optional[float] = None,
                      t: float = 0.0) -> List[str]:
    """Names of routable nodes whose headroom fits the class's minimal
    share; raises :class:`AdmissionError` when no placement exists."""
    placed = []
    for n in nodes:
        if not n.routable:
            continue
        if n.arbiter.admission_check(lut, target_latency_ms, n.g(t),
                                     priority=priority,
                                     min_accuracy=min_accuracy) is not None:
            placed.append(n.name)
    if not placed:
        hr = cluster_headroom(nodes, t=t)
        raise AdmissionError(
            f"no placement fits a minimal share under {target_latency_ms}ms "
            f"across {sum(1 for n in nodes if n.routable)} routable node(s) "
            f"(summed headroom: {hr.chips} chips)")
    return placed


def cluster_headroom(nodes: Sequence[ClusterNode], *, t: float = 0.0
                     ) -> Headroom:
    """Summed unreserved capacity across routable nodes (observability —
    admission itself binds per node, see module docstring).  ``power_w``
    is inf when any routable node runs without a power budget."""
    chips = 0
    power = 0.0
    for n in nodes:
        if not n.routable:
            continue
        hr = n.headroom(t)
        chips += max(0, hr.chips)
        power += max(0.0, hr.power_w)   # inf (no budget) propagates
    return Headroom(chips=chips, power_w=power)
