"""Cluster-wide placement engine: the node-level objective, one level up.

The PR-6 tentpole.  :mod:`repro.runtime.waterfill` extracted the
arbiter's min-share + backlog-first-surplus objective into a
level-agnostic solver; this module runs the SAME objective over nodes
instead of chip slices — the hierarchical resource manager of Xun et al.
(arXiv:2105.03608), with the switching-cost awareness of Dynamic-OFA
(arXiv:2105.03596): a reconfiguration is only worth its price.

Four pure planners, all deterministic (the simulator scripts them with
``rebalance_at``/``scale_at``; the live front-end runs them on a
``rebalance_interval_s`` thread):

* :func:`solve_placement` — fresh global K-replica solve.  Pass 1 gives
  every class, in priority order, ONE replica on the node where its
  minimal feasible share is smallest (the solver's own min-share key);
  pass 2+ pours the surplus back, backlog-first, adding replicas on
  further nodes until nothing fits or the replica cap is reached.
  Per-node budgets reserve only equal-or-higher-priority shares —
  lower-priority tenants are preemptable, exactly the single-node
  admission rule — so with ``replicas=None`` and uniform headroom the
  solve reproduces today's replicate-everywhere placement.
* :func:`plan_rebalance` — diff the fresh solve against the current
  placements and price every proposed change with its REAL cost:
  :func:`migration_cost` charges a new replica the bucket-ladder
  warmup (calibrated latencies when a store is attached) plus the
  weight transfer, in seconds and joules (calibrated watts).  A change
  is approved only when the backlog it can drain over the rebalance
  horizon beats ``hysteresis`` times its cost — steady load diffs to
  nothing, so the no-flapping guarantee is structural, not tuned.
* :func:`plan_preemptions` — cross-node preemption: a backlogged
  high-priority class evicts the lowest-priority co-located replica
  that still has another routable home, so the hot class gets the
  whole node and the victim's traffic reroutes (wired through the
  arbiter's existing ``export_tenant``/``preempt`` machinery by the
  callers).
* :func:`plan_scaling` — autoscaling over the node pool: sustained
  backlog per chip spins a STANDBY node up; an idle cluster under a
  high energy price spins the smallest UP node down (never below
  ``min_nodes``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.cluster.node import STANDBY, UP, ClusterNode
from repro.runtime import hwmodel as hm
from repro.runtime import waterfill as wf
from repro.runtime.lut import LUT, bucket_ladder, bucket_latency_ms

# priced-migration hysteresis: a change must promise this many times its
# cost in drained-backlog seconds before it is applied
DEFAULT_HYSTERESIS = 2.0
# modelled weight-transfer time for one replica's parameters (the image
# has no real NIC to measure; calibrated warmup dominates in practice)
DEFAULT_TRANSFER_S = 0.25
# autoscaler thresholds (backlog per chip, cluster-wide EWMA)
SCALE_UP_BACKLOG = 2.0
SCALE_DOWN_BACKLOG = 0.25
PRICE_HIGH = 1.0


@dataclasses.dataclass
class ClassSpec:
    """One SLO class, phrased for the placement planners."""
    name: str
    lut: LUT
    target_latency_ms: float
    priority: int = 0
    min_accuracy: Optional[float] = None
    backlog: float = 0.0          # cluster-wide queued requests
    max_batch: int = 8
    # DEGRADE (never-drop) classes: when NO node admits the strict
    # target, place best-effort everywhere at this relaxed target
    fallback_target_ms: Optional[float] = None


@dataclasses.dataclass
class PlacementPlan:
    """A fresh global solve: class -> replica nodes."""
    placements: Dict[str, List[str]]
    best_effort: List[str]         # classes placed via fallback_target_ms


@dataclasses.dataclass(frozen=True)
class MigrationCost:
    """What standing a replica up on a new node really costs."""
    seconds: float    # weight transfer + bucket-ladder warmup
    joules: float     # seconds x calibrated slice watts


@dataclasses.dataclass(frozen=True)
class Move:
    """One proposed placement change (add / remove / move)."""
    cls: str
    src: Optional[str]             # None => pure add (scale-out)
    dst: Optional[str]             # None => pure remove (scale-in)
    cost_s: float
    cost_j: float
    benefit_s: float               # backlog drained over the horizon

    @property
    def kind(self) -> str:
        if self.src and self.dst:
            return "move"
        return "add" if self.dst else "remove"


@dataclasses.dataclass
class RebalancePlan:
    """Fresh solve + the priced diff against the current placements."""
    target: PlacementPlan
    moves: List[Move]              # approved: benefit beats priced cost
    rejected: List[Move]           # priced out by hysteresis


@dataclasses.dataclass(frozen=True)
class Eviction:
    """Cross-node preemption: evict ``victim``'s replica on ``node`` so
    backlogged ``for_cls`` stops sharing the machine with it."""
    victim: str
    node: str
    for_cls: str


@dataclasses.dataclass
class ScalePlan:
    """One autoscaling step (at most one action per call — the caller's
    EWMA provides the 'sustained' hysteresis)."""
    spin_up: List[str]
    spin_down: List[str]


# --- demands (the solver's view of one class on one node) -------------------

def _planning_lut(lut: LUT, calibration) -> LUT:
    """Raw LUT, or point latencies re-estimated from measured buckets —
    the same blend the node arbiters plan with."""
    if calibration is None:
        return lut
    return LUT([dataclasses.replace(
        p, latency_ms=calibration.point_latency_ms(p.subnet, p.latency_ms))
        for p in lut.points])


def _power_scale(name: str, calibration) -> float:
    if calibration is None:
        return 1.0
    return max(1e-6, calibration.power_scale(name))


def _demand_on(spec: ClassSpec, node: ClusterNode, t: float,
               calibration) -> wf.Demand:
    """Phrase ``spec`` hosted on ``node`` as a solver demand — identical
    arithmetic to the arbiter's own demand construction."""
    g = node.g(t)
    scale = _power_scale(spec.name, calibration)
    lut = _planning_lut(spec.lut, calibration)

    def priced(p) -> wf.PricedPoint:
        base = hm.slice_power_w(p.hw_state)
        return wf.PricedPoint(units=p.hw_state.chips, cost=base * scale,
                              base_cost=base, latency_ms=p.latency_ms,
                              accuracy=p.accuracy, energy_mj=p.energy_mj,
                              payload=p)

    def feasible(chips_cap: int, power_cap: float):
        pts = lut.feasible(
            max_latency_ms=spec.target_latency_ms,
            chips_available=chips_cap,
            power_budget_w=(None if math.isinf(power_cap)
                            else power_cap / scale),
            min_accuracy=spec.min_accuracy,
            max_freq=g.temperature_throttle)
        return [priced(p) for p in pts]

    def candidates(chips_cap: int, power_cap: float):
        return [priced(p) for p in lut.points
                if p.hw_state.chips <= chips_cap
                and hm.slice_power_w(p.hw_state) * scale <= power_cap]

    return wf.Demand(name=spec.name, feasible=feasible,
                     candidates=candidates, priority=spec.priority,
                     backlog=spec.backlog)


@dataclasses.dataclass
class _NodeBudget:
    """Per-node capacity with priority-aware reservations: a query at
    priority p sees capacity minus equal-or-higher-priority shares only
    (lower-priority tenants are preemptable — the admission rule)."""
    chips: int
    power: float
    reserved: List[Tuple[int, int, float]] = dataclasses.field(
        default_factory=list)   # (priority, chips, priced_w)

    def caps(self, priority: int) -> Tuple[int, float]:
        chips = self.chips - sum(r[1] for r in self.reserved
                                 if r[0] >= priority)
        power = self.power - sum(r[2] for r in self.reserved
                                 if r[0] >= priority)
        return chips, power

    def reserve(self, priority: int, point: wf.PricedPoint):
        self.reserved.append((priority, point.units, point.cost))


# --- the fresh global solve -------------------------------------------------

def solve_placement(specs: Sequence[ClassSpec],
                    nodes: Sequence[ClusterNode], *, t: float = 0.0,
                    replicas: Optional[int] = None,
                    calibration=None) -> PlacementPlan:
    """Fresh K-replica placement: the waterfill objective over nodes.

    ``replicas=None`` means replicate on every node that fits (today's
    behaviour); an integer caps each class's replica count.  Only
    routable (UP) nodes are considered.
    """
    up = [n for n in nodes if n.routable]
    budgets = {n.name: _NodeBudget(
        chips=n.g(t).total_chips,
        power=(n.g(t).power_budget_w
               if n.g(t).power_budget_w is not None else math.inf))
        for n in up}
    demands = {(s.name, n.name): _demand_on(s, n, t, calibration)
               for s in specs for n in up}
    placements: Dict[str, List[str]] = {s.name: [] for s in specs}

    # pass 1: ONE replica per class, priority order (stable — ties by
    # spec order), on the node where its minimal share is smallest by
    # the solver's own min-share key; node ties go to node order.
    order = sorted(specs, key=lambda s: -s.priority)
    for s in order:
        best = None
        for n in up:
            chips_cap, power_cap = budgets[n.name].caps(s.priority)
            pt = wf.min_share_point(demands[(s.name, n.name)],
                                    chips_cap, power_cap)
            if pt is None:
                continue
            key = (pt.units, pt.base_cost, -pt.accuracy)
            if best is None or key < best[0]:
                best = (key, n.name, pt)
        if best is None:
            continue
        _, nn, pt = best
        budgets[nn].reserve(s.priority, pt)
        placements[s.name].append(nn)

    # pass 2+: surplus replicas, backlog-first (deepest backlog wins,
    # then priority), one new replica per class per pass, nodes in
    # order — until a full pass adds nothing or every class hit its cap.
    cap = len(up) if replicas is None else max(1, replicas)
    filling = sorted(order, key=lambda s: (-s.backlog, -s.priority))
    for _ in range(max(wf.MAX_FILL_PASSES, len(up))):
        changed = False
        for s in filling:
            if len(placements[s.name]) >= cap:
                continue
            hosted = set(placements[s.name])
            for n in up:
                if n.name in hosted:
                    continue
                chips_cap, power_cap = budgets[n.name].caps(s.priority)
                pt = wf.min_share_point(demands[(s.name, n.name)],
                                        chips_cap, power_cap)
                if pt is None:
                    continue
                budgets[n.name].reserve(s.priority, pt)
                placements[s.name].append(n.name)
                changed = True
                break
        if not changed:
            break

    # never-drop fallback: classes no node admits go best-effort
    # everywhere at their relaxed target (mirrors the DEGRADE path)
    best_effort = []
    for s in specs:
        if not placements[s.name] and s.fallback_target_ms is not None:
            placements[s.name] = [n.name for n in up]
            best_effort.append(s.name)
    return PlacementPlan(placements=placements, best_effort=best_effort)


# --- priced migrations ------------------------------------------------------

def migration_cost(spec: ClassSpec, *, calibration=None,
                   transfer_s: float = DEFAULT_TRANSFER_S) -> MigrationCost:
    """What a new replica of ``spec`` really costs before it serves.

    Warmup compiles/warms one batch per bucket of the class's ladder at
    its fastest point — calibrated per-bucket latencies when a store is
    attached — plus the weight transfer; joules price those seconds at
    the slice's calibrated watts.  This is the Dynamic-OFA lesson: a
    switch is only free in models that ignore it.
    """
    lut = _planning_lut(spec.lut, calibration)
    pt = min(lut.points, key=lambda p: (p.latency_ms, -p.accuracy))
    warm_ms = 0.0
    for b in bucket_ladder(spec.max_batch):
        warm_ms += bucket_latency_ms(pt.latency_ms, b, spec.max_batch,
                                     calibration=calibration, spec=pt.subnet)
    seconds = transfer_s + warm_ms / 1e3
    watts = hm.slice_power_w(pt.hw_state) * _power_scale(spec.name,
                                                         calibration)
    return MigrationCost(seconds=seconds, joules=seconds * watts)


def _service_s(spec: ClassSpec, calibration) -> float:
    """Per-request seconds at the class's fastest point (benefit unit)."""
    lut = _planning_lut(spec.lut, calibration)
    pt = min(lut.points, key=lambda p: (p.latency_ms, -p.accuracy))
    return pt.latency_ms / 1e3 / max(1, spec.max_batch)


def plan_rebalance(specs: Sequence[ClassSpec],
                   nodes: Sequence[ClusterNode],
                   current: Dict[str, Sequence[str]], *, t: float = 0.0,
                   horizon_s: float = 5.0,
                   hysteresis: float = DEFAULT_HYSTERESIS,
                   replicas: Optional[int] = None, calibration=None,
                   transfer_s: float = DEFAULT_TRANSFER_S) -> RebalancePlan:
    """Fresh solve, diffed against ``current``, every change priced.

    A proposed add/move is approved only when the backlog the new
    replica could drain over ``horizon_s`` exceeds ``hysteresis`` times
    its migration cost; an unpaired remove is approved only when the
    class keeps at least one replica.  Under steady load the fresh
    solve reproduces the current placements and the plan is empty —
    zero migrations, by construction.
    """
    plan = solve_placement(specs, nodes, t=t, replicas=replicas,
                           calibration=calibration)
    up_names = {n.name for n in nodes if n.routable}
    moves: List[Move] = []
    rejected: List[Move] = []
    for s in specs:
        cur = [nn for nn in current.get(s.name, ()) if nn in up_names]
        tgt = plan.placements[s.name]
        adds = [nn for nn in tgt if nn not in cur]
        removes = [nn for nn in cur if nn not in tgt]
        if not adds and not removes:
            continue
        cost = migration_cost(s, calibration=calibration,
                              transfer_s=transfer_s)
        # a new replica's worth: the queued work it could absorb within
        # the horizon, at the class's fastest per-request service time
        benefit_s = min(s.backlog * _service_s(s, calibration), horizon_s)
        # pair removes with adds into moves; leftovers are pure changes
        n_pairs = min(len(adds), len(removes))
        proposals = ([Move(cls=s.name, src=removes[i], dst=adds[i],
                           cost_s=cost.seconds, cost_j=cost.joules,
                           benefit_s=benefit_s) for i in range(n_pairs)]
                     + [Move(cls=s.name, src=None, dst=nn,
                             cost_s=cost.seconds, cost_j=cost.joules,
                             benefit_s=benefit_s)
                        for nn in adds[n_pairs:]]
                     + [Move(cls=s.name, src=nn, dst=None, cost_s=0.0,
                             cost_j=0.0, benefit_s=0.0)
                        for nn in removes[n_pairs:]])
        kept = len(cur)
        for mv in proposals:
            if mv.kind == "remove":
                # scale-in costs nothing but must never orphan the class
                if kept > 1:
                    moves.append(mv)
                    kept -= 1
                else:
                    rejected.append(mv)
            elif mv.benefit_s > hysteresis * mv.cost_s:
                moves.append(mv)
                if mv.kind == "add":
                    kept += 1
            else:
                rejected.append(mv)
    return RebalancePlan(target=plan, moves=moves, rejected=rejected)


# --- cross-node preemption --------------------------------------------------

def plan_preemptions(specs: Sequence[ClassSpec],
                     nodes: Sequence[ClusterNode],
                     placements: Dict[str, Sequence[str]], *,
                     min_backlog: float = 1.0,
                     node_backlog: Optional[
                         Callable[[str, str], float]] = None
                     ) -> List[Eviction]:
    """Which lower-priority replicas should a backlogged class evict?

    For every backlogged class (priority-desc), on every node it shares
    with a STRICTLY lower-priority class that still has another routable
    replica, evict the lowest-priority such victim — its traffic
    reroutes to its surviving replicas, the hot class keeps the node.
    ``node_backlog(cls, node)`` localises the trigger (defaults to the
    spec's cluster-wide backlog).
    """
    up_names = {n.name for n in nodes if n.routable}
    evicted = set()   # (cls, node) pairs already planned away

    def homes(cls: str) -> List[str]:
        return [nn for nn in placements.get(cls, ())
                if nn in up_names and (cls, nn) not in evicted]

    evictions: List[Eviction] = []
    for s in sorted(specs, key=lambda s: -s.priority):
        for nn in placements.get(s.name, ()):
            if nn not in up_names:
                continue
            pressure = (node_backlog(s.name, nn) if node_backlog is not None
                        else s.backlog)
            if pressure < min_backlog:
                continue
            victims = [v for v in specs
                       if v.priority < s.priority
                       and nn in homes(v.name) and len(homes(v.name)) > 1]
            if not victims:
                continue
            victim = min(victims, key=lambda v: v.priority)
            evictions.append(Eviction(victim=victim.name, node=nn,
                                      for_cls=s.name))
            evicted.add((victim.name, nn))
    return evictions


# --- autoscaling ------------------------------------------------------------

def plan_scaling(nodes: Sequence[ClusterNode], *, backlog_per_chip: float,
                 energy_price: float = 0.0, t: float = 0.0,
                 min_nodes: int = 1,
                 up_threshold: float = SCALE_UP_BACKLOG,
                 down_threshold: float = SCALE_DOWN_BACKLOG,
                 price_high: float = PRICE_HIGH) -> ScalePlan:
    """One autoscaling decision over the node pool.

    Sustained backlog (the caller passes an EWMA, not an instantaneous
    read) above ``up_threshold`` spins up the first STANDBY node; a
    cluster idling below ``down_threshold`` while the energy price is at
    or above ``price_high`` spins down the smallest UP node — never
    below ``min_nodes``.
    """
    up = [n for n in nodes if n.state == UP]
    standby = [n for n in nodes if n.state == STANDBY]
    if backlog_per_chip > up_threshold and standby:
        return ScalePlan(spin_up=[standby[0].name], spin_down=[])
    if (backlog_per_chip < down_threshold and energy_price >= price_high
            and len(up) > max(1, min_nodes)):
        victim = min(up, key=lambda n: (n.g(t).total_chips, n.name))
        return ScalePlan(spin_up=[], spin_down=[victim.name])
    return ScalePlan(spin_up=[], spin_down=[])
