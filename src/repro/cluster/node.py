"""One serving node: a ResourceArbiter + its DynamicServers + lifecycle.

A :class:`ClusterNode` is exactly the single-device stack PRs 1-3 built
(water-filling arbiter, SLO-registered tenants, bucketed serving
engines), wrapped with what the cluster front-end needs:

* a **load signal** — the arbiter's summed queue-depth + arrival-rate
  EWMA backlog, normalised by the node's chip count, so the router can
  compare a busy small node against an idle big one;
* a **lifecycle state** — UP (routable), STANDBY (powered-off pool
  member the autoscaler can spin up), DRAINING (stop routing, keep
  serving until the queues empty), DRAINED (tenants migrated away), and
  DEAD (fail-stop: queued work resolves with error payloads);
* a **liveness signal** — :class:`StallDetector` turns the node's
  completion counters into a health verdict: completions flat while
  backlog is non-zero for K consecutive health epochs means the node is
  WEDGED (silently stuck — worker hung, device lost — without
  fail-stopping), and the health checker fails it over automatically
  instead of waiting for an operator's ``fail_at``/``drain``.

The same object backs both the live front-end (:mod:`.frontend`) and
the virtual-time simulator (:mod:`.sim`); ``g_fn(t)`` yields the node's
machine state at virtual/elapsed time ``t`` (heterogeneous clusters are
just nodes with different ``g_fn``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.runtime.arbiter import (GlobalConstraints, Headroom,
                                   ResourceArbiter)
from repro.runtime.engine import DynamicServer

# health-check default: epochs of flat completions (with backlog) before
# a node is declared wedged and failed over
HEALTH_EPOCHS = 3


@dataclasses.dataclass
class StallDetector:
    """Stall-based liveness: completions flat while backlog > 0.

    One :meth:`observe` per health epoch with the node's cumulative
    completion count and current backlog.  A healthy node under load
    moves its counter every epoch; a wedged one accepts work (backlog
    grows) but completes nothing.  K consecutive stalled epochs return
    True — the caller's cue to run the existing failover path
    (:meth:`repro.cluster.frontend.Cluster.fail` live, the ``fail_at``
    machinery in :func:`repro.cluster.sim.simulate_cluster`).
    Completions moving — or the backlog emptying — resets the streak.
    """
    epochs: int = HEALTH_EPOCHS
    _last_completed: Optional[int] = None
    _stalled: int = 0

    def observe(self, completed: int, backlog: float) -> bool:
        stalled = (self._last_completed is not None
                   and completed == self._last_completed
                   and backlog > 0)
        self._stalled = self._stalled + 1 if stalled else 0
        self._last_completed = completed
        return self._stalled >= self.epochs

    @property
    def stalled_epochs(self) -> int:
        return self._stalled

# lifecycle states
UP = "up"
STANDBY = "standby"     # powered-off pool member; the autoscaler's spare
DRAINING = "draining"   # no new routes; queues serve to empty
DRAINED = "drained"     # graceful exit complete, tenants migrated
DEAD = "dead"           # fail-stop: queued requests resolve with errors
NODE_STATES = (UP, STANDBY, DRAINING, DRAINED, DEAD)


@dataclasses.dataclass
class ClusterNode:
    """One arbiter-governed machine inside the cluster."""
    name: str
    g_fn: Callable[[float], GlobalConstraints]
    arbiter: ResourceArbiter = dataclasses.field(
        default_factory=ResourceArbiter)
    servers: Dict[str, DynamicServer] = dataclasses.field(
        default_factory=dict)
    state: str = UP
    health: StallDetector = dataclasses.field(default_factory=StallDetector)
    # chaos overlay on the hw state (repro.chaos): a thermal injection
    # lowers the DVFS throttle (only low-frequency LUT points remain), a
    # straggler shrinks effective capacity.  1.0/1.0 = no perturbation;
    # g() applies them so the arbiter re-water-fills under the fault
    # without the node's g_fn knowing chaos exists.
    chaos_throttle: float = 1.0
    chaos_capacity: float = 1.0

    @property
    def routable(self) -> bool:
        """May the router send NEW traffic here?"""
        return self.state == UP

    @property
    def alive(self) -> bool:
        """Does the node still serve (routable or draining)?"""
        return self.state in (UP, DRAINING)

    def attach_obs(self, tracer=None, metrics=None):
        """Wire observability down the node's stack: the arbiter gets the
        tracer (ARBITRATE/PREEMPT decision spans labelled with this
        node's name) and every server records request span trees and
        engine counters.  The cluster front-end calls this on attach and
        again for servers placed later (:meth:`_place_on`)."""
        if tracer is not None:
            self.arbiter.tracer = tracer
            self.arbiter.trace_label = self.name
        for server in self.servers.values():
            if tracer is not None:
                server.tracer = tracer
                server.trace_node = self.name
            if metrics is not None:
                server.metrics = metrics

    def g(self, t: float = 0.0) -> GlobalConstraints:
        g = self.g_fn(t)
        if self.chaos_throttle < 1.0 or self.chaos_capacity < 1.0:
            g = dataclasses.replace(
                g,
                total_chips=max(1, int(g.total_chips * self.chaos_capacity)),
                temperature_throttle=min(g.temperature_throttle,
                                         self.chaos_throttle))
        return g

    def load(self, t: float = 0.0, extra_backlog: float = 0.0) -> float:
        """Backlog per chip — the router's comparison key.

        The numerator is the arbiter's summed per-tenant backlog (queue
        depth + arrival-rate EWMA, refreshed each arbitration) plus any
        ``extra_backlog`` the caller tracks between ticks (the simulator
        passes this-epoch arrivals); the denominator makes a half-full
        small node rank busier than a half-full big one, which is what
        lets power-of-two-choices exploit skewed capacity.
        """
        chips = max(1, self.g(t).total_chips)
        return (self.arbiter.total_backlog() + extra_backlog) / chips

    def headroom(self, t: float = 0.0) -> Headroom:
        """Unreserved capacity after tenant minimal shares (admission)."""
        return self.arbiter.headroom(self.g(t))

    def outstanding(self) -> int:
        """Unresolved futures across this node's servers (live drain)."""
        return sum(s.outstanding() for s in self.servers.values())

    def completed(self) -> int:
        """Cumulative requests answered across this node's servers — the
        liveness counter the health checker watches for stalls."""
        return sum(s.served for s in self.servers.values())

    def starved(self) -> bool:
        """Did the last arbitration deliberately park EVERY tenant?

        A fully starved node (thermal throttle, power dip, higher-priority
        tenants holding all chips) shows the same signature as a wedge —
        completions flat, futures outstanding — but it is the arbiter's
        own doing and recovers the moment conditions improve.  The health
        check must not kill it."""
        last = self.arbiter.last_allocations()
        return bool(last) and all(a.point is None for a in last.values())

    def check_health(self) -> bool:
        """One live health epoch: True when the node looks wedged
        (completions flat across K epochs while futures are outstanding).
        The front-end's health loop calls this and runs ``fail()``.

        Epochs where the arbiter parked every tenant
        (:meth:`starved`) report zero backlog to the detector, so a
        deliberate starvation resets the stall streak instead of
        counting toward a false-positive failover."""
        backlog = 0 if self.starved() else self.outstanding()
        return self.health.observe(self.completed(), backlog)
