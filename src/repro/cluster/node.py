"""One serving node: a ResourceArbiter + its DynamicServers + lifecycle.

A :class:`ClusterNode` is exactly the single-device stack PRs 1-3 built
(water-filling arbiter, SLO-registered tenants, bucketed serving
engines), wrapped with what the cluster front-end needs:

* a **load signal** — the arbiter's summed queue-depth + arrival-rate
  EWMA backlog, normalised by the node's chip count, so the router can
  compare a busy small node against an idle big one;
* a **lifecycle state** — UP (routable), DRAINING (stop routing, keep
  serving until the queues empty), DRAINED (tenants migrated away), and
  DEAD (fail-stop: queued work resolves with error payloads).

The same object backs both the live front-end (:mod:`.frontend`) and
the virtual-time simulator (:mod:`.sim`); ``g_fn(t)`` yields the node's
machine state at virtual/elapsed time ``t`` (heterogeneous clusters are
just nodes with different ``g_fn``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.runtime.arbiter import (GlobalConstraints, Headroom,
                                   ResourceArbiter)
from repro.runtime.engine import DynamicServer

# lifecycle states
UP = "up"
DRAINING = "draining"   # no new routes; queues serve to empty
DRAINED = "drained"     # graceful exit complete, tenants migrated
DEAD = "dead"           # fail-stop: queued requests resolve with errors
NODE_STATES = (UP, DRAINING, DRAINED, DEAD)


@dataclasses.dataclass
class ClusterNode:
    """One arbiter-governed machine inside the cluster."""
    name: str
    g_fn: Callable[[float], GlobalConstraints]
    arbiter: ResourceArbiter = dataclasses.field(
        default_factory=ResourceArbiter)
    servers: Dict[str, DynamicServer] = dataclasses.field(
        default_factory=dict)
    state: str = UP

    @property
    def routable(self) -> bool:
        """May the router send NEW traffic here?"""
        return self.state == UP

    @property
    def alive(self) -> bool:
        """Does the node still serve (routable or draining)?"""
        return self.state in (UP, DRAINING)

    def g(self, t: float = 0.0) -> GlobalConstraints:
        return self.g_fn(t)

    def load(self, t: float = 0.0, extra_backlog: float = 0.0) -> float:
        """Backlog per chip — the router's comparison key.

        The numerator is the arbiter's summed per-tenant backlog (queue
        depth + arrival-rate EWMA, refreshed each arbitration) plus any
        ``extra_backlog`` the caller tracks between ticks (the simulator
        passes this-epoch arrivals); the denominator makes a half-full
        small node rank busier than a half-full big one, which is what
        lets power-of-two-choices exploit skewed capacity.
        """
        chips = max(1, self.g(t).total_chips)
        return (self.arbiter.total_backlog() + extra_backlog) / chips

    def headroom(self, t: float = 0.0) -> Headroom:
        """Unreserved capacity after tenant minimal shares (admission)."""
        return self.arbiter.headroom(self.g(t))

    def outstanding(self) -> int:
        """Unresolved futures across this node's servers (live drain)."""
        return sum(s.outstanding() for s in self.servers.values())
