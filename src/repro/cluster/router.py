"""Cluster request routing: round-robin, least-loaded, power-of-two.

The router spreads ONE SLO class's traffic across the nodes where that
class is placed.  Three policies, all deterministic under a fixed seed:

* ``round_robin``   — cycle the routable placements; ignores load.  The
  baseline: under skewed node capacity it keeps feeding the slow node
  its full share and the slow node's queue (and the class p95) explodes;
* ``least_loaded``  — always the minimum :meth:`ClusterNode.load`
  (backlog per chip).  Optimal signal use, but every front-end choosing
  the same minimum herds onto one node between signal refreshes;
* ``p2c``           — power-of-two-choices (Mitzenmacher 2001): sample
  two distinct candidates with a seeded rng, send to the less loaded.
  Near-least-loaded tail behaviour without the herding, and the default.

The placement engine steers traffic with **weight hints**
(:meth:`ClusterRouter.set_weight`): a per-(class, node) multiplier on
the load signal's attractiveness.  Weight 0 takes a replica out of
rotation entirely — how a WARMING replica (mid-migration or a freshly
spun-up node) avoids traffic until its weights have transferred and its
buckets are compiled — and weights scale the compared load otherwise
(weight 2 looks half as loaded).  Round-robin honours only the
in/out-of-rotation part.
"""
from __future__ import annotations

import collections
from typing import Callable, Deque, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.node import ClusterNode
from repro.obs.metrics import MetricsRegistry

P2C = "p2c"
LEAST_LOADED = "least_loaded"
ROUND_ROBIN = "round_robin"
ROUTERS = (P2C, LEAST_LOADED, ROUND_ROBIN)


class ClusterRouter:
    """Per-class routing decisions over routable placements.

    ``decisions`` logs every pick as ``(t, class, node)`` — the cluster
    determinism tests compare it across runs, and :meth:`routed_counts`
    aggregates it for reports.  Like the engine's ``switch_log`` (PR 3),
    the log is a bounded deque: a long live run keeps the NEWEST
    ``decision_log_cap`` picks and counts the rest in
    ``decisions_dropped`` instead of growing without limit.
    """

    def __init__(self, policy: str = P2C, *, seed: int = 0,
                 decision_log_cap: int = 1 << 20,
                 metrics: Optional[MetricsRegistry] = None):
        if policy not in ROUTERS:
            raise ValueError(f"router {policy!r} not in {ROUTERS}")
        self.policy = policy
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._rr: dict = {}            # per-class round-robin cursor
        self.decision_log_cap = decision_log_cap
        self.decisions: Deque[Tuple[float, str, str]] = collections.deque(
            maxlen=decision_log_cap)
        self.decisions_dropped = 0
        # per-(class, node) pick counts live in the metrics registry
        # (series ``router_routed_total``); the cluster injects its shared
        # registry so one scrape sees routing next to placement counters
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.weights: dict = {}        # (class, node) -> load multiplier

    def set_weight(self, cls_name: str, node_name: str,
                   weight: Optional[float]):
        """Placement hint: 0 removes the replica from rotation (warming),
        >1 attracts traffic, <1 repels it; ``None`` clears the hint."""
        if weight is None:
            self.weights.pop((cls_name, node_name), None)
        else:
            self.weights[(cls_name, node_name)] = float(weight)

    def _weight(self, cls_name: str, node: ClusterNode) -> float:
        return self.weights.get((cls_name, node.name), 1.0)

    def pick(self, cls_name: str, candidates: Sequence[ClusterNode], *,
             t: float = 0.0,
             load_fn: Optional[Callable[[ClusterNode], float]] = None
             ) -> Optional[ClusterNode]:
        """Choose a node for one request of ``cls_name`` (None: nowhere
        to go — every placement is draining, dead, or weighted out)."""
        cands = [n for n in candidates
                 if n.routable and self._weight(cls_name, n) > 0]
        if not cands:
            return None
        base = load_fn if load_fn is not None else (lambda n: n.load(t))

        def load(n: ClusterNode) -> float:
            return base(n) / self._weight(cls_name, n)

        if len(cands) == 1:
            node = cands[0]
        elif self.policy == ROUND_ROBIN:
            i = self._rr.get(cls_name, 0)
            node = cands[i % len(cands)]
            self._rr[cls_name] = i + 1
        elif self.policy == LEAST_LOADED:
            # stable: ties go to the earliest candidate
            node = min(cands, key=load)
        else:   # P2C
            i, j = self._rng.choice(len(cands), size=2, replace=False)
            a, b = cands[int(i)], cands[int(j)]
            node = a if load(a) <= load(b) else b
        if len(self.decisions) == self.decision_log_cap:
            self.decisions_dropped += 1   # deque evicts the oldest pick
        self.decisions.append((t, cls_name, node.name))
        self.metrics.counter("router_routed_total", cls=cls_name,
                             node=node.name).inc()
        return node

    def routed_counts(self) -> dict:
        """``{class: {node: requests_routed}}`` for reports —
        reconstructed from the registry's ``router_routed_total`` series."""
        out: dict = {}
        for lbl in self.metrics.labels_of("router_routed_total"):
            n = self.metrics.value("router_routed_total", **lbl)
            out.setdefault(lbl["cls"], {})[lbl["node"]] = int(n)
        return out
