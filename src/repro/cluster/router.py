"""Cluster request routing: round-robin, least-loaded, power-of-two.

The router spreads ONE SLO class's traffic across the nodes where that
class is placed.  Three policies, all deterministic under a fixed seed:

* ``round_robin``   — cycle the routable placements; ignores load.  The
  baseline: under skewed node capacity it keeps feeding the slow node
  its full share and the slow node's queue (and the class p95) explodes;
* ``least_loaded``  — always the minimum :meth:`ClusterNode.load`
  (backlog per chip).  Optimal signal use, but every front-end choosing
  the same minimum herds onto one node between signal refreshes;
* ``p2c``           — power-of-two-choices (Mitzenmacher 2001): sample
  two distinct candidates with a seeded rng, send to the less loaded.
  Near-least-loaded tail behaviour without the herding, and the default.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.node import ClusterNode

P2C = "p2c"
LEAST_LOADED = "least_loaded"
ROUND_ROBIN = "round_robin"
ROUTERS = (P2C, LEAST_LOADED, ROUND_ROBIN)


class ClusterRouter:
    """Per-class routing decisions over routable placements.

    ``decisions`` logs every pick as ``(t, class, node)`` — the cluster
    determinism tests compare it across runs, and :meth:`routed_counts`
    aggregates it for reports.
    """

    def __init__(self, policy: str = P2C, *, seed: int = 0,
                 decision_log_cap: int = 1 << 20):
        if policy not in ROUTERS:
            raise ValueError(f"router {policy!r} not in {ROUTERS}")
        self.policy = policy
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._rr: dict = {}            # per-class round-robin cursor
        self.decisions: List[Tuple[float, str, str]] = []
        self.decision_log_cap = decision_log_cap
        self.decisions_dropped = 0
        self.routed: dict = {}         # class -> node -> count

    def pick(self, cls_name: str, candidates: Sequence[ClusterNode], *,
             t: float = 0.0,
             load_fn: Optional[Callable[[ClusterNode], float]] = None
             ) -> Optional[ClusterNode]:
        """Choose a node for one request of ``cls_name`` (None: nowhere
        to go — every placement is draining or dead)."""
        cands = [n for n in candidates if n.routable]
        if not cands:
            return None
        load = load_fn if load_fn is not None else (lambda n: n.load(t))
        if len(cands) == 1:
            node = cands[0]
        elif self.policy == ROUND_ROBIN:
            i = self._rr.get(cls_name, 0)
            node = cands[i % len(cands)]
            self._rr[cls_name] = i + 1
        elif self.policy == LEAST_LOADED:
            # stable: ties go to the earliest candidate
            node = min(cands, key=load)
        else:   # P2C
            i, j = self._rng.choice(len(cands), size=2, replace=False)
            a, b = cands[int(i)], cands[int(j)]
            node = a if load(a) <= load(b) else b
        if len(self.decisions) < self.decision_log_cap:
            self.decisions.append((t, cls_name, node.name))
        else:
            self.decisions_dropped += 1
        per_cls = self.routed.setdefault(cls_name, {})
        per_cls[node.name] = per_cls.get(node.name, 0) + 1
        return node

    def routed_counts(self) -> dict:
        """``{class: {node: requests_routed}}`` for reports."""
        return {c: dict(m) for c, m in self.routed.items()}
