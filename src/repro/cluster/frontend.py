"""Live cluster front-end: N arbiter-governed nodes behind one router.

:class:`Cluster` composes :class:`~repro.cluster.node.ClusterNode`s into
a single serving surface:

* **register** runs cluster-level admission (:func:`cluster_admission`)
  and places the class on every node that can host its minimal share —
  one DynamicServer replica per placement, built by the caller's
  ``make_server(node)`` factory;
* **submit** routes one request to a placement via the
  :class:`~repro.cluster.router.ClusterRouter` (p2c by default) and
  returns the replica server's future — callers never see nodes;
* **drain** stops routing to a node, waits for its backlog to resolve,
  migrates its tenant registrations to surviving nodes (the arbiter's
  :meth:`export_tenant` hook), and stops it;
* **fail** is fail-stop: every queued request on the dead node resolves
  with an error payload (:meth:`DynamicServer.kill`) and orphaned
  classes are re-admitted elsewhere, so the class's share is
  re-arbitrated instead of lost;
* a **placement engine** (``rebalance_interval_s``) periodically re-runs
  the cluster-wide water-filling solve (:mod:`repro.cluster.placement`)
  against the live placements: approved, migration-cost-priced changes
  move replicas through the arbiter's ``export_tenant`` hook, and
  cross-node preemptions evict lower-priority replicas co-located with
  a backlogged higher-priority class (``preempt`` lands the freed share
  mid-cycle);
* a **health checker** (``health_interval_s``) closes the liveness loop:
  each health epoch every UP node's cumulative completion counter is
  compared against its outstanding futures
  (:meth:`~repro.cluster.node.ClusterNode.check_health`); a node whose
  completions stay flat for K epochs while work is outstanding is
  WEDGED — silently stuck, invisible to the router's load signal — and
  is failed over through the same :meth:`fail` path an operator would
  use, so no caller hangs on it.

Duck-types the ``arbiter`` argument of :func:`repro.traffic.drive_live`
(``start``/``stop``/``summary``) and serves class ports that duck-type
its ``servers`` dict, so the existing live driver drives a whole
cluster unchanged.

Lock discipline (enforced by ``pytest --lock-check``, see
:mod:`repro.analysis.locks`): the canonical project lock order is
``Cluster._admin_lock > Cluster._lock > ResourceArbiter._lock >
DynamicServer locks > Tracer/Metrics locks`` — an outer lock may be held
while taking any lock to its right, never the reverse.  ``_admin_lock``
serialises lifecycle work (register/drain/fail/rebalance) and nests
``_lock`` for the brief routing-state flips; ``_lock`` guards
``placements``/``_classes``/``unplaceable`` and the event logs, and is
held across router picks (which probe node arbiters — hence
arbiter locks sit BELOW it).  Arbiter/engine code never calls back into
the cluster, which is what keeps the order acyclic.  External readers
snapshot via :meth:`placements_snapshot` instead of touching
``placements`` raw.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Sequence

from repro.analysis.guards import guarded_by
from repro.cluster import placement as pl
from repro.cluster.admission import cluster_admission
from repro.cluster.node import (DEAD, DRAINED, DRAINING, HEALTH_EPOCHS, UP,
                                ClusterNode)
from repro.cluster.router import P2C, ClusterRouter
from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry
from repro.runtime.arbiter import AdmissionError
from repro.runtime.engine import DynamicServer
from repro.runtime.lut import LUT


class _ClassPort:
    """Submit-side view of one class: what drive_live treats as a server."""

    def __init__(self, cluster: "Cluster", name: str):
        self._cluster = cluster
        self._name = name

    def submit(self, x, links: Sequence[int] = ()) -> "queue.Queue":
        return self._cluster.submit(self._name, x, links=links)


def _dead_future(reason: str) -> "queue.Queue":
    fut: "queue.Queue" = queue.Queue(maxsize=1)
    fut.put({"y": None, "cancelled": True, "error": reason,
             "latency_ms": 0.0, "subnet": None})
    return fut


@guarded_by("_lock", "placements", "_classes", "unplaceable",
            "health_log", "migration_log", "preempt_log")
class Cluster:
    def __init__(self, nodes: Sequence[ClusterNode], *,
                 router: str = P2C, router_seed: int = 0,
                 health_interval_s: Optional[float] = None,
                 health_epochs: int = HEALTH_EPOCHS,
                 rebalance_interval_s: Optional[float] = None,
                 rebalance_hysteresis: float = pl.DEFAULT_HYSTERESIS,
                 replicas: Optional[int] = None,
                 tracer=None, metrics: Optional[MetricsRegistry] = None,
                 log_cap: int = 4096):
        if not nodes:
            raise ValueError("a cluster needs at least one node")
        names = [n.name for n in nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate node names: {names}")
        self.nodes: Dict[str, ClusterNode] = {n.name: n for n in nodes}
        # observability: ONE tracer spans the whole request path (route
        # at the front-end, queue→device inside each node's engine) and
        # ONE cluster registry holds router/migration/health counters
        # (node arbiters keep their own registries — tenant labels would
        # collide across nodes)
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.router = ClusterRouter(router, seed=router_seed,
                                    metrics=self.metrics)
        for n in nodes:
            n.attach_obs(tracer, self.metrics)
        # stall-based health checking: None disables the checker thread
        self.health_interval_s = health_interval_s
        self.health_epochs = health_epochs
        # event logs are bounded (PR 3 switch_log idiom): a long live run
        # keeps the newest log_cap entries and counts the rest
        self.log_cap = log_cap
        self.health_log: Deque[str] = collections.deque(  # guarded-by: _lock
            maxlen=log_cap)
        self.health_log_dropped = 0
        self._health_stop = threading.Event()
        self._health_thread: Optional[threading.Thread] = None
        # periodic cluster-wide rebalancing (the PR-6 placement engine):
        # None disables the thread; rebalance() stays callable by hand
        self.rebalance_interval_s = rebalance_interval_s
        self.rebalance_hysteresis = rebalance_hysteresis
        self.replicas = replicas
        # (t, cls, src, dst)
        self.migration_log: Deque[tuple] = collections.deque(  # guarded-by: _lock
            maxlen=log_cap)
        self.migration_log_dropped = 0
        # (t, victim, node, for_cls)
        self.preempt_log: Deque[tuple] = collections.deque(  # guarded-by: _lock
            maxlen=log_cap)
        self.preempt_log_dropped = 0
        self._rebalance_stop = threading.Event()
        self._rebalance_thread: Optional[threading.Thread] = None
        # classes whose re-admission attempt found no feasible node —
        # reported in summary() and answered with explicit `no placement`
        # futures instead of a generic dead-future reason
        self.unplaceable: set = set()   # guarded-by: _lock
        for n in nodes:
            n.health.epochs = health_epochs
        # _lock guards the routing state (placements, router picks) and is
        # only ever held briefly; _admin_lock serialises lifecycle work
        # (register/drain/fail) whose slow parts — thread joins, server
        # construction/warmup — must NOT stall submits to healthy nodes
        self._lock = threading.RLock()
        self._admin_lock = threading.RLock()
        # class -> registration info needed to re-place it (migration)
        self._classes: Dict[str, dict] = {}          # guarded-by: _lock
        self.placements: Dict[str, List[str]] = {}   # guarded-by: _lock
        self._t0: Optional[float] = None

    # --- time / state -------------------------------------------------------

    def _now(self) -> float:
        return 0.0 if self._t0 is None else time.perf_counter() - self._t0

    def _routable(self, name: str) -> List[ClusterNode]:
        return [self.nodes[nn] for nn in self.placements.get(name, ())
                if self.nodes[nn].routable]

    # --- registration / admission -------------------------------------------

    def register(self, name: str, lut: LUT, target_latency_ms: float, *,
                 priority: int = 0, min_accuracy: Optional[float] = None,
                 make_server: Optional[
                     Callable[[ClusterNode], DynamicServer]] = None
                 ) -> List[str]:
        """Admit + place one class cluster-wide.

        Raises :class:`AdmissionError` when NO node's headroom fits the
        class's minimal share; otherwise registers a replica on every
        node that can host it and returns the placement list.
        """
        with self._admin_lock:
            with self._lock:
                if name in self._classes:
                    raise ValueError(f"class {name!r} already registered")
            info = dict(lut=lut, target_latency_ms=target_latency_ms,
                        priority=priority, min_accuracy=min_accuracy,
                        make_server=make_server)
            placed = cluster_admission(
                list(self.nodes.values()), lut, target_latency_ms,
                priority=priority, min_accuracy=min_accuracy, t=self._now())
            for nn in placed:
                self._place_on(name, info, self.nodes[nn])
            with self._lock:
                self._classes[name] = info
                self.placements[name] = list(placed)
            return list(placed)

    def _place_on(self, name: str, info: dict, node: ClusterNode):
        server = (info["make_server"](node) if info["make_server"] else None)
        node.arbiter.register(name, info["lut"], info["target_latency_ms"],
                              priority=info["priority"],
                              min_accuracy=info["min_accuracy"],
                              server=server)
        if server is not None:
            node.servers[name] = server
            node.attach_obs(self.tracer, self.metrics)

    def _readmit_orphans(self):
        """Re-place classes whose every replica died/drained away — the
        failed node's share is re-arbitrated on the survivors.  Caller
        holds _admin_lock; server construction runs outside the routing
        lock so healthy-node submits keep flowing.  A class NO survivor
        can host is recorded as unplaceable (``summary()`` reports it,
        submits resolve with an explicit `no placement` payload) instead
        of being silently retried."""
        with self._lock:
            orphans = [(name, info) for name, info in self._classes.items()
                       if not self.placements.get(name)]
        for name, info in orphans:
            try:
                placed = cluster_admission(
                    [n for n in self.nodes.values()
                     if name not in n.arbiter.tenants()],
                    info["lut"], info["target_latency_ms"],
                    priority=info["priority"],
                    min_accuracy=info["min_accuracy"], t=self._now())
            except AdmissionError:
                with self._lock:
                    self.unplaceable.add(name)
                continue
            for nn in placed:
                self._place_on(name, info, self.nodes[nn])
            with self._lock:
                self.placements[name] = list(placed)
                self.unplaceable.discard(name)

    # --- placement engine (periodic rebalancing + preemption) ---------------

    def placements_snapshot(self) -> Dict[str, List[str]]:
        """Locked copy of ``{class: [node, ...]}`` — what external readers
        (chaos controller, tooling) use instead of ``placements`` raw,
        which drain/fail/rebalance mutate concurrently."""
        with self._lock:
            return {name: list(p) for name, p in self.placements.items()}

    def _spec_of(self, name: str, info: dict) -> pl.ClassSpec:
        backlog = 0.0
        with self._lock:
            placed = list(self.placements.get(name, ()))
        for nn in placed:
            node = self.nodes[nn]
            if node.alive and name in node.arbiter.tenants():
                backlog += node.arbiter.backlog(name)
        return pl.ClassSpec(name=name, lut=info["lut"],
                            target_latency_ms=info["target_latency_ms"],
                            priority=info["priority"],
                            min_accuracy=info["min_accuracy"],
                            backlog=backlog)

    def rebalance(self) -> "pl.RebalancePlan":
        """One cluster-wide rebalance: fresh global solve over the same
        water-filling objective the node arbiters run, diffed against
        the live placements, every change priced with its real
        migration cost (hysteresis — steady load applies nothing).
        Approved moves register the replica on the destination and
        export it from the source through the arbiter's migration hook;
        cross-node preemptions evict lower-priority replicas wherever a
        backlogged higher-priority class shares its node."""
        with self._admin_lock:
            t = self._now()
            with self._lock:
                classes = dict(self._classes)
                current = {n: list(p) for n, p in self.placements.items()}
            specs = [self._spec_of(n, i) for n, i in classes.items()]
            up_nodes = [n for n in self.nodes.values() if n.routable]
            horizon = (self.rebalance_interval_s
                       if self.rebalance_interval_s else 5.0)
            plan = pl.plan_rebalance(specs, up_nodes, current, t=t,
                                     horizon_s=horizon,
                                     hysteresis=self.rebalance_hysteresis,
                                     replicas=self.replicas)
            t_plan = (time.perf_counter()
                      if self.tracer is not None else 0.0)
            for mv in plan.moves:
                info = classes[mv.cls]
                t_mv = (time.perf_counter()
                        if self.tracer is not None else 0.0)
                if mv.dst is not None:
                    self._place_on(mv.cls, info, self.nodes[mv.dst])
                    with self._lock:
                        if mv.dst not in self.placements[mv.cls]:
                            self.placements[mv.cls].append(mv.dst)
                if mv.src is not None:
                    self._retire_replica(mv.cls, mv.src)
                with self._lock:
                    if len(self.migration_log) == self.log_cap:
                        self.migration_log_dropped += 1  # deque evicts oldest
                    self.migration_log.append((t, mv.cls, mv.src, mv.dst))
                self.metrics.counter("cluster_migrations_total",
                                     cls=mv.cls).inc()
                if self.tracer is not None:
                    # the span covers the real move: destination server
                    # build/warmup through source drain + export
                    self.tracer.decision(
                        obs.MIGRATE, t_mv, time.perf_counter(),
                        cls=mv.cls, node=mv.dst, src=mv.src,
                        cost_s=mv.cost_s)
            evs = pl.plan_preemptions(specs, up_nodes, current)
            for ev in evs:
                t_ev = (time.perf_counter()
                        if self.tracer is not None else 0.0)
                self._retire_replica(ev.victim, ev.node)
                # the freed share lands NOW, not at the next clock tick
                node = self.nodes[ev.node]
                if ev.for_cls in node.arbiter.tenants():
                    node.arbiter.preempt(ev.for_cls, node.g(t))
                with self._lock:
                    if len(self.preempt_log) == self.log_cap:
                        self.preempt_log_dropped += 1   # deque evicts oldest
                    self.preempt_log.append(
                        (t, ev.victim, ev.node, ev.for_cls))
                self.metrics.counter("cluster_preemptions_total",
                                     cls=ev.victim).inc()
                if self.tracer is not None:
                    self.tracer.decision(
                        obs.PREEMPT, t_ev, time.perf_counter(),
                        cls=ev.victim, node=ev.node, for_cls=ev.for_cls)
            if self.tracer is not None:
                self.tracer.decision(
                    obs.REBALANCE, t_plan, time.perf_counter(),
                    moves=len(plan.moves), preemptions=len(evs))
            return plan

    def set_alert_pressure(self, name: str, pressure: float):
        """Forward a watchtower alert-pressure signal to every replica's
        arbiter: each node scales the class's backlog demand by
        ``1 + pressure`` in its next water-fill (0.0 clears it).  The
        live counterpart of the simulator's actuation hook — drive_live
        calls this as its watchtower evaluates."""
        with self._lock:
            placed = list(self.placements.get(name, ()))
        for nn in placed:
            node = self.nodes[nn]
            if node.alive and name in node.arbiter.tenants():
                node.arbiter.set_alert_pressure(name, pressure)

    def _retire_replica(self, name: str, node_name: str):
        """Take one replica out: stop routing to it, drain its queue,
        export the registration (server stays up until drained)."""
        node = self.nodes[node_name]
        with self._lock:
            if node_name in self.placements.get(name, ()):
                self.placements[name].remove(node_name)
        server = node.servers.pop(name, None)
        if server is not None:
            server.drain(timeout_s=5.0)
        if name in node.arbiter.tenants():
            node.arbiter.export_tenant(name)

    def _rebalance_loop(self):
        while not self._rebalance_stop.is_set():
            self._rebalance_stop.wait(self.rebalance_interval_s)
            if self._rebalance_stop.is_set():
                break
            self.rebalance()

    # --- request path -------------------------------------------------------

    def submit(self, name: str, x,
               links: Sequence[int] = ()) -> "queue.Queue":
        """Route one request.  ``links`` carries the trace_ids of prior
        attempts (a retried or hedged request's second try points at its
        first — the span-link idiom), recorded on the new span tree."""
        t_sub = time.perf_counter() if self.tracer is not None else 0.0
        with self._lock:
            cands = self._routable(name)
            node = self.router.pick(name, cands, t=self._now()) \
                if cands else None
            if node is None and name in self.unplaceable:
                # every replica died AND re-admission found no feasible
                # node: say so, not just "no routable node"
                return _dead_future(
                    f"class {name!r}: no placement — re-admission found "
                    f"no node able to host its minimal share")
        if node is None:
            return _dead_future(f"class {name!r}: no routable node")
        server = node.servers.get(name)
        if server is None:
            return _dead_future(f"class {name!r}: node {node.name} "
                                f"has no server replica")
        if self.tracer is not None:
            # begin the span tree HERE, under the SLO class, with the
            # router's pick as the route span; the engine appends the
            # queue→device children and finalizes at outputs-ready
            tid = self.tracer.begin_request(name, t=t_sub, node=node.name,
                                            links=links)
            self.tracer.add_span(tid, obs.ROUTE, t_sub,
                                 time.perf_counter(), node=node.name)
            return server.submit(x, trace_id=tid)
        return server.submit(x)

    def port(self, name: str) -> _ClassPort:
        return _ClassPort(self, name)

    def ports(self) -> Dict[str, _ClassPort]:
        """``{class: submit-proxy}`` — drive_live's ``servers`` dict."""
        with self._lock:
            names = list(self._classes)
        return {name: _ClassPort(self, name) for name in names}

    # --- lifecycle ----------------------------------------------------------

    def start(self, g_fn=None):
        """Start every node's constraint clock (``g_fn`` is accepted for
        drive_live compatibility; nodes use their own ``g_fn(t)``) and,
        when ``health_interval_s`` is set, the stall-based health
        checker."""
        self._t0 = time.perf_counter()
        for node in self.nodes.values():
            if node.alive:
                node.arbiter.start(lambda n=node: n.g(self._now()))
        if self.health_interval_s is not None:
            self._health_stop.clear()
            self._health_thread = threading.Thread(target=self._health_loop,
                                                   daemon=True)
            self._health_thread.start()
        if self.rebalance_interval_s is not None:
            self._rebalance_stop.clear()
            self._rebalance_thread = threading.Thread(
                target=self._rebalance_loop, daemon=True)
            self._rebalance_thread.start()

    def _health_loop(self):
        # Operator contract: health_epochs x health_interval_s must
        # exceed the node's worst-case single-batch time (a warmed
        # server's batch is milliseconds; an un-warmed cold compile can
        # legitimately stall completions for hundreds of ms and would —
        # correctly, from the detector's point of view — read as a wedge)
        while not self._health_stop.is_set():
            for node in list(self.nodes.values()):
                if node.state == UP and node.check_health():
                    # wedged: completions flat for K epochs with futures
                    # outstanding — run the SAME failover path an
                    # operator's fail() would (queued futures resolve
                    # with error payloads, classes re-admit elsewhere)
                    with self._lock:
                        if len(self.health_log) == self.log_cap:
                            self.health_log_dropped += 1  # deque evicts oldest
                        self.health_log.append(node.name)
                    self.metrics.counter("cluster_health_failed_total",
                                         node=node.name).inc()
                    t_fail = (time.perf_counter()
                              if self.tracer is not None else 0.0)
                    self.fail(node.name,
                              reason=f"health: node {node.name} wedged "
                                     f"(completions stalled "
                                     f"{node.health.stalled_epochs} epochs "
                                     f"with backlog)")
                    if self.tracer is not None:
                        self.tracer.decision(
                            obs.HEALTH_FAIL, t_fail, time.perf_counter(),
                            node=node.name)
            self._health_stop.wait(self.health_interval_s)

    def stop(self):
        self._health_stop.set()
        self._rebalance_stop.set()
        if self._health_thread is not None:
            self._health_thread.join(timeout=5)
            self._health_thread = None
        if self._rebalance_thread is not None:
            self._rebalance_thread.join(timeout=5)
            self._rebalance_thread = None
        for node in self.nodes.values():
            if node.alive:
                node.arbiter.stop()

    def drain(self, node_name: str, timeout_s: float = 30.0) -> bool:
        """Graceful node removal: stop routing, let the backlog resolve
        (each replica's :meth:`DynamicServer.drain`), migrate tenant
        registrations to survivors, stop the node."""
        node = self.nodes[node_name]
        with self._admin_lock:
            with self._lock:
                if node.state != UP:
                    return False
                node.state = DRAINING   # router skips it from here on
            deadline = time.perf_counter() + timeout_s
            drained = True
            for server in node.servers.values():
                # refuses racing submits, waits its backlog out, stops
                drained &= server.drain(
                    timeout_s=max(0.1, deadline - time.perf_counter()))
            for name in node.arbiter.tenants():
                # the servers are already stopped; export keeps the (now
                # empty) registration out of the arbiter's stop path
                node.arbiter.export_tenant(name)
                with self._lock:
                    if node_name in self.placements.get(name, ()):
                        self.placements[name].remove(node_name)
            node.arbiter.stop()
            with self._lock:
                node.state = DRAINED
            self._readmit_orphans()
        return drained

    def fail(self, node_name: str, reason: str = "node failed") -> None:
        """Fail-stop a node NOW: queued requests resolve with ``reason``
        error payloads; orphaned classes re-arbitrate elsewhere."""
        node = self.nodes[node_name]
        with self._admin_lock:
            with self._lock:
                if node.state == DEAD:
                    return
                node.state = DEAD       # router skips it immediately
                for name in list(self.placements):
                    if node_name in self.placements[name]:
                        self.placements[name].remove(node_name)
            # slow half (thread joins) runs outside the routing lock
            for server in node.servers.values():
                server.kill(reason)
            node.arbiter.stop()
            self._readmit_orphans()

    # --- accounting ---------------------------------------------------------

    def summary(self) -> dict:
        with self._lock:
            # snapshot routing state; node/arbiter summaries run unlocked
            # below (they take arbiter locks — below _lock in the order)
            snap = {
                "placements": {n: list(p)
                               for n, p in self.placements.items()},
                "health_failed": list(self.health_log),
                "unplaceable": sorted(self.unplaceable),
                "migrations": list(self.migration_log),
                "preempted": list(self.preempt_log),
                "log_dropped": {"health": self.health_log_dropped,
                                "migrations": self.migration_log_dropped,
                                "preempted": self.preempt_log_dropped},
            }
        return {
            "router": self.router.policy,
            "routed": self.router.routed_counts(),
            "nodes": {nn: {"state": node.state,
                           "arbiter": node.arbiter.summary()}
                      for nn, node in self.nodes.items()},
            **snap,
        }
