"""Deterministic virtual-time cluster simulation.

Mirrors :func:`repro.traffic.driver.simulate` — the same constraint-clock
epochs, SLO policies, batching-aware service model and per-class
accounting — but over N :class:`ClusterNode`s with a
:class:`ClusterRouter` in front:

* each arrival is routed (p2c / least-loaded / round-robin) among the
  routable nodes of its class's placement set, using the per-node
  backlog-per-chip signal the arbiters already track;
* every node runs its OWN real :class:`ResourceArbiter` — per-node
  admission, water-filling, preemption and set_active are all exercised,
  exactly as in the single-node simulator;
* node lifecycle is scriptable: ``drain_at`` stops routing to a node and
  migrates its tenants once its queues empty; ``fail_at`` is fail-stop —
  queued requests resolve as ``failed`` and orphaned classes re-admit on
  the survivors (share re-arbitrated elsewhere); ``wedge_at`` is the
  SILENT failure mode fail-stop can't model — the node keeps accepting
  routed work but completes nothing (hung worker, lost device);
* **stall-based health checking** (``health_epochs=K``): each epoch
  every up node's completion counter is run through its
  :class:`~repro.cluster.node.StallDetector`; completions flat while its
  queues are non-empty for K epochs auto-fails the node through the SAME
  failover path as ``fail_at`` — queued requests resolve as ``failed``,
  orphaned classes re-admit on survivors — replacing operator-only
  lifecycle scripting with measurement-driven liveness;
* the **placement engine** is scriptable the same way: ``rebalance_at``
  runs the cluster-wide rebalancer (fresh global water-filling solve,
  every change priced with its real migration cost, cross-node
  preemption), ``scale_at`` runs the autoscaler over a STANDBY node
  pool (``energy_price_fn`` prices spin-downs), and
  ``placement_mode="first_fit"`` scripts the static baseline
  ``benchmarks/bench_placement.py`` measures against;
* a warmed :class:`repro.runtime.telemetry.CalibrationStore`
  (``calibration=``) makes the replay predict with MEASURED numbers:
  every node's arbiter water-fills on calibrated latencies/watts and
  batches are priced by measured per-bucket EWMAs (see
  :func:`repro.traffic.driver.simulate`).

Everything is seeded (arrival streams + router rng), so one trace under
two routing policies — or the same trace twice — is an exact,
reproducible comparison: the determinism tests assert identical routing
``decisions`` and :class:`ClusterReport` summaries across runs.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import math
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.chaos.engine import (DRAIN as CHAOS_DRAIN, FAIL as CHAOS_FAIL,
                                WEDGE_ON as CHAOS_WEDGE, ChaosTimeline)
from repro.chaos.reliability import Reliability
from repro.chaos.scenario import Scenario
from repro.cluster import placement as pl
from repro.cluster.node import (DEAD, DRAINED, DRAINING, STANDBY, UP,
                                ClusterNode, StallDetector)
from repro.cluster.router import P2C, ClusterRouter
from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry
from repro.runtime.lut import LUT
from repro.traffic import arrivals as arr
from repro.traffic.driver import (BUCKETED_SERVICE, POLICIES, SERVICE_MODELS,
                                  SLO_POLICY, FIFO_POLICY, ClassStats,
                                  _service_ms)
from repro.traffic.slo import DEGRADE, SHED, SLOClass


# initial placement modes
REPLICATE = "replicate"   # a replica on every node that admits the class
FIRST_FIT = "first_fit"   # one replica, on the first node that admits it
PLACEMENT_MODES = (REPLICATE, FIRST_FIT)

# smoothing for the autoscaler's sustained-backlog signal
_SCALE_BETA = 0.5


@dataclasses.dataclass(frozen=True)
class _Req:
    """One queued attempt.  ``t`` is when THIS attempt entered the system
    (its queue-position / batching key); ``t0`` is the original arrival —
    latency and the retry deadline are always measured from ``t0``, so a
    retried request can never be counted good past its real SLO.
    ``gid`` groups hedge copies (-1 = unhedged); ``first_rid`` carries the
    first failed attempt's trace_id so a retry's span tree links back."""
    t: float
    t0: float
    attempts: int = 1
    gid: int = -1
    first_rid: int = -1


@dataclasses.dataclass
class ClusterReport:
    """One cluster run: per-class stats + per-node view + routing log."""
    policy: str
    router: str
    classes: Dict[str, ClassStats]
    nodes: Dict[str, dict]
    decisions: List[Tuple[float, str, str]]
    routed: dict = dataclasses.field(default_factory=dict)
    # (virtual second, node) pairs auto-failed by the stall health check
    health_failed: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)
    # placement-engine activity (rebalance_at / scale_at scripting)
    migrations: List[Tuple[float, str, Optional[str], Optional[str]]] = \
        dataclasses.field(default_factory=list)   # (t, cls, src, dst)
    preempted: List[Tuple[float, str, str, str]] = \
        dataclasses.field(default_factory=list)   # (t, victim, node, for)
    scale_events: List[Tuple[float, str, str]] = \
        dataclasses.field(default_factory=list)   # (t, "up"/"down", node)
    # classes whose re-admission attempt found NO feasible node (they had
    # been admitted, then lost every replica) — satellite: no silent retry
    unplaceable: List[str] = dataclasses.field(default_factory=list)
    decisions_dropped: int = 0
    # events evicted from the capped logs above (switch_log idiom)
    log_dropped: Dict[str, int] = dataclasses.field(default_factory=dict)
    # modelled serving energy per class (sum of dispatched batches'
    # OpPoint.energy_mj) + warmup energy paid for migrations/spin-ups —
    # the bench's "no higher energy" axis prices migrations honestly
    energy_mj: Dict[str, float] = dataclasses.field(default_factory=dict)
    migration_energy_mj: float = 0.0
    # chaos scenario activity: (t, kind, node) per applied injection,
    # in scenario order — part of the determinism contract
    injections: List[Tuple[float, str, str]] = dataclasses.field(
        default_factory=list)
    # brownout transitions: (t, cls, "enter"/"exit")
    brownouts: List[Tuple[float, str, str]] = dataclasses.field(
        default_factory=list)
    # SLO watchtower alerts fired during the run (rising edges), in
    # firing order — typed repro.obs.health.Alert records
    alerts: List = dataclasses.field(default_factory=list)
    # reliability accounting: retries granted by the cluster budget, and
    # the ones turned away (past-deadline / budget-exhausted / attempt cap)
    retry_granted: int = 0
    retry_denied: Dict[str, int] = dataclasses.field(default_factory=dict)
    # the run's observability handles (``decompose_latency(report)``
    # reads .tracer); excluded from summary() — not plain data
    tracer: Optional[object] = None
    metrics: Optional[MetricsRegistry] = None

    @property
    def total_goodput(self) -> int:
        return sum(s.good for s in self.classes.values())

    @property
    def total_energy_mj(self) -> float:
        return sum(self.energy_mj.values()) + self.migration_energy_mj

    @property
    def total_dropped(self) -> int:
        return sum(s.dropped for s in self.classes.values())

    @property
    def total_failed(self) -> int:
        return sum(s.failed for s in self.classes.values())

    def summary(self) -> dict:
        return {"policy": self.policy, "router": self.router,
                "total_goodput": self.total_goodput,
                "total_dropped": self.total_dropped,
                "total_failed": self.total_failed,
                "classes": {n: s.summary()
                            for n, s in self.classes.items()},
                "routed": self.routed,
                "health_failed": list(self.health_failed),
                "migrations": list(self.migrations),
                "preempted": list(self.preempted),
                "scale_events": list(self.scale_events),
                "unplaceable": list(self.unplaceable),
                "injections": list(self.injections),
                "brownouts": list(self.brownouts),
                "alerts": [[round(a.t, 6), a.cls, a.window, a.severity]
                           for a in self.alerts],
                "retry_granted": self.retry_granted,
                "retry_denied": dict(self.retry_denied),
                "log_dropped": dict(self.log_dropped),
                "energy_mj": {n: round(e, 2)
                              for n, e in self.energy_mj.items()},
                "migration_energy_mj": round(self.migration_energy_mj, 2),
                "nodes": self.nodes}


def simulate_cluster(classes: Sequence[SLOClass], luts: Dict[str, LUT],
                     streams: Dict[str, Sequence[float]],
                     nodes: Sequence[ClusterNode], *,
                     router: str = P2C, router_seed: int = 0,
                     interval_s: float = 0.1, policy: str = SLO_POLICY,
                     service_model: str = BUCKETED_SERVICE,
                     max_drain_s: float = 120.0,
                     fail_at: Optional[Dict[str, float]] = None,
                     drain_at: Optional[Dict[str, float]] = None,
                     wedge_at: Optional[Dict[str, float]] = None,
                     chaos: Optional[Scenario] = None,
                     reliability: Optional[Reliability] = None,
                     watchtower=None,
                     health_epochs: Optional[int] = None,
                     calibration=None,
                     placement_mode: str = REPLICATE,
                     rebalance_at: Sequence[float] = (),
                     scale_at: Sequence[float] = (),
                     rebalance_horizon_s: Optional[float] = None,
                     hysteresis: float = pl.DEFAULT_HYSTERESIS,
                     replicas: Optional[int] = None,
                     energy_price_fn=None,
                     min_nodes: int = 1,
                     tracer=None,
                     metrics: Optional[MetricsRegistry] = None,
                     log_cap: int = 4096
                     ) -> ClusterReport:
    """Run one seeded trace through the cluster in virtual time.

    ``nodes`` must be freshly-built (their arbiters get the class
    registrations).  ``fail_at``/``drain_at`` map node names to the
    virtual second their lifecycle event lands (processed on the next
    epoch boundary; a failing node stops COMPLETING batches at the exact
    fail instant — work that would finish after it is left queued and
    resolves as ``failed``).

    ``wedge_at`` silently wedges a node: it stays routable and keeps
    accepting work, but completes nothing from that instant on — the
    failure mode only measurement can see.  With ``health_epochs=K`` the
    stall-based health check watches every node's completion counters
    and auto-fails a wedged node after K flat epochs with backlog,
    driving the same failover path as ``fail_at`` (queued requests
    resolve ``failed``, orphaned classes re-admit on survivors).

    ``calibration`` threads a warmed measurement store through every
    node's arbiter and the batch service model.

    ``chaos`` (a :class:`repro.chaos.Scenario`) schedules deterministic
    fault injections in virtual time.  Its fail-stop family (node fail,
    silent wedge, spot preemption = drain notice then fail, correlated
    rack failure) is MERGED into the ``fail_at``/``drain_at``/
    ``wedge_at`` scripting above, so chaos rides the exact failover
    machinery operators script by hand; its continuous overlays are
    polled each epoch — a straggler multiplies the node's batch service
    time by ``factor``, a thermal injection walks the node's DVFS
    throttle down a ladder (the arbiter re-water-fills over the
    low-frequency LUT points), and a partition hides the router→node
    edge (the node keeps serving its queue; new routes avoid it).

    ``reliability`` (a :class:`repro.chaos.Reliability`) turns on the
    request-reliability layer: a FAILED attempt is re-routed through the
    router after its class's exponential backoff — capped by the
    policy's attempt limit, by the cluster-wide retry budget
    (``burst + fraction × completed``), and by the request's own
    deadline (a retry that cannot be resubmitted before the SLO deadline
    is never scheduled).  Classes with ``hedge=True`` enqueue each
    accepted arrival on TWO distinct replicas; the first completion
    wins, the loser counts ``hedge_wasted``.  Sustained chaos pressure
    (failures+retries per outcome, EWMA-smoothed) flips a class into
    BROWNOUT: every replica's arbiter pins it to its DEGRADE target and
    shedding is suspended — serve degraded instead of dropping — until
    the pressure decays below the exit threshold.  Retried requests'
    span trees link to their first failed attempt (``links=``).

    ``watchtower`` (a :class:`repro.obs.Watchtower`) closes the
    monitor→diagnose→actuate loop: each epoch's per-class outcomes
    (late completions, drops, failures) feed its burn-rate monitors,
    fired alerts land on ``report.alerts`` with attribution, and —
    when it ``actuate``\\ s — an active fast-burn alert (a) scales the
    class's backlog in every hosting arbiter via ``set_alert_pressure``
    and (b) browns the class out BEFORE the failure-pressure EWMA
    would (the EWMA only sees failures/retries; the alert also sees
    late completions, so a pure latency fault like a thermal throttle
    actuates epochs earlier).  ``rebalance_on_alert`` additionally
    runs the cluster rebalancer on each rising-edge alert.

    The **placement engine** (PR 6) is scripted the same way lifecycle
    is: ``rebalance_at`` lists the virtual seconds the cluster-wide
    rebalancer runs — a fresh :func:`repro.cluster.placement
    .solve_placement` diffed against the live placements, every change
    priced with its real migration cost and applied only when its
    amortised benefit over ``rebalance_horizon_s`` beats
    ``hysteresis`` x cost (steady load ⇒ empty diff ⇒ zero migrations).
    A migrated/added replica WARMS first: its router weight is 0 and it
    cannot serve until ``t + cost_s``.  Cross-node preemptions run at
    the same instants.  ``scale_at`` lists when the autoscaler looks at
    its sustained-backlog EWMA: spin-up wakes a STANDBY node (replicas
    admitted + warmed onto it), spin-down parks an idle UP node back to
    STANDBY when ``energy_price_fn(t)`` is high — never below
    ``min_nodes``.  ``placement_mode="first_fit"`` scripts the static
    baseline the placement benchmark beats: one replica per class on
    the first admitting node.

    ``tracer`` (a :class:`repro.obs.Tracer`) records the SAME span
    schema the live stack emits, in VIRTUAL time: per-request trees
    (route → queue [→ warming] → collect → stack → dispatch → device →
    complete; host-side stages are zero-width points — the analytic
    service model folds them into the batch) plus per-epoch ARBITRATE
    and scripted REBALANCE / MIGRATE / PREEMPT / SCALE / HEALTH_FAIL
    decision spans.  ``metrics`` feeds the report's energy/completions
    accounting through a :class:`repro.obs.MetricsRegistry` (one is
    created per run when None); the report keeps its public shape, read
    back from the registry, and carries both handles.
    """
    assert policy in POLICIES, policy
    assert service_model in SERVICE_MODELS, service_model
    assert placement_mode in PLACEMENT_MODES, placement_mode
    by_class = {c.name: c for c in classes}
    stats = {c.name: ClassStats() for c in classes}
    nodes = list(nodes)
    by_node = {n.name: n for n in nodes}
    rtr = ClusterRouter(router, seed=router_seed)
    fail_at = dict(fail_at or {})
    drain_at = dict(drain_at or {})
    wedge_at = dict(wedge_at or {})
    wedged = {n.name: False for n in nodes}

    # --- chaos: compile the scenario onto the scripting machinery -----------
    timeline = (ChaosTimeline(chaos, [n.name for n in nodes])
                if chaos is not None else None)
    chaos_due: List[Tuple[float, str, str]] = []
    if timeline is not None:
        # the fail-stop family becomes fail_at/drain_at/wedge_at entries
        # (earliest wins when an operator scripted the same node), so
        # injected faults take the exact failover path scripted ones do
        lifecycle_of = {CHAOS_FAIL: fail_at, CHAOS_DRAIN: drain_at,
                        CHAOS_WEDGE: wedge_at}
        for tc, action, nn in timeline.lifecycle():
            target = lifecycle_of[action]
            target[nn] = min(target.get(nn, math.inf), tc)
        chaos_due = sorted(chaos.summary())

    # --- reliability layer state --------------------------------------------
    rel = reliability
    budget = rel.budget.fresh() if rel is not None else None
    retry_heap: List[Tuple[float, int, str, _Req]] = []
    retry_seq = 0
    retry_denied = {"deadline": 0, "budget": 0, "attempts": 0}
    hedge_groups: Dict[int, dict] = {}
    next_gid = 0
    brown_on = {c.name: False for c in classes}
    brown_p = {c.name: 0.0 for c in classes}
    # alert-driven degrade (watchtower): relaxes the arbiter target like
    # brown_on but does NOT suspend the shed check — tracked separately
    # so the two brownout paths can overlap without fighting
    wt_brown = {c.name: False for c in classes}
    brownouts: List[Tuple[float, str, str]] = []
    injections: List[Tuple[float, str, str]] = []
    # per-run accounting lives in a metrics registry (the report reads
    # it back into its public dict shapes); counter handles are held in
    # dicts so the hot loop pays one attribute bump, no lookups
    m = metrics if metrics is not None else MetricsRegistry()
    completions = {n.name: m.counter("sim_completions_total", node=n.name)
                   for n in nodes}   # liveness counters
    # per-class latency histogram: buckets carry exemplar trace ids so
    # a fired alert links straight to retained p99 traces
    lat_hist = {c.name: m.histogram("cluster_request_ms", cls=c.name)
                for c in classes}
    # --- SLO watchtower -----------------------------------------------------
    wt = watchtower
    run_alerts: List = []
    if wt is not None:
        if wt.tracer is None:
            wt.tracer = tracer
        if wt.registry is None:
            wt.registry = m
        if chaos is not None:
            # note every scheduled injection up front (attribution only
            # considers ones whose time has passed) — durations matter
            # for deciding whether a transient fault is still a suspect
            for inj in chaos.injections:
                for nn2 in (inj.targets() if hasattr(inj, "targets")
                            else ((inj.node,) if inj.node else ())):
                    wt.note_injection(inj.t, inj.kind, nn2,
                                      duration_s=inj.duration_s)
    health = {n.name: StallDetector(epochs=health_epochs or 0)
              for n in nodes} if health_epochs else {}
    # event logs are bounded like the front-end's (switch_log idiom:
    # capped deque + dropped counter); report shapes stay plain lists
    health_failed: Deque[Tuple[float, str]] = collections.deque(
        maxlen=log_cap)
    log_dropped = {"health": 0, "migrations": 0, "preempted": 0,
                   "scale_events": 0}

    def log_event(log: Deque, key: str, item) -> None:
        if len(log) == log.maxlen:
            log_dropped[key] += 1   # deque evicts the oldest
        log.append(item)
    if calibration is not None:
        for node in nodes:
            if node.arbiter.calibration is None:
                node.arbiter.calibration = calibration

    # --- cluster admission + placement (mirrors _register_classes) ---------
    placements: Dict[str, List[str]] = {}
    # how each class registers on a node — the rebalancer/autoscaler
    # re-place classes mid-trace with the SAME registration
    reg_info: Dict[str, dict] = {}
    for c in classes:
        placed: List[str] = []
        reg_info[c.name] = dict(target=c.service_target_ms,
                                priority=c.priority,
                                min_accuracy=c.min_accuracy)
        for node in nodes:
            if not node.routable:
                continue   # STANDBY pool members join via scale_at only
            if policy == FIFO_POLICY:
                node.arbiter.register(c.name, luts[c.name],
                                      c.service_target_ms, priority=0)
                placed.append(node.name)
                continue
            if placed and placement_mode == FIRST_FIT:
                break
            ok = node.arbiter.admission_check(
                luts[c.name], c.service_target_ms, node.g(0.0),
                priority=c.priority, min_accuracy=c.min_accuracy)
            if ok is not None:
                node.arbiter.register(c.name, luts[c.name],
                                      c.service_target_ms,
                                      priority=c.priority,
                                      min_accuracy=c.min_accuracy)
                placed.append(node.name)
        if not placed and policy == SLO_POLICY and c.drop_policy == DEGRADE:
            # never drop: serve best-effort everywhere at the relaxed target
            reg_info[c.name] = dict(target=c.degraded_target_ms,
                                    priority=c.priority, min_accuracy=None)
            for node in nodes:
                if not node.routable:
                    continue
                node.arbiter.register(c.name, luts[c.name],
                                      c.degraded_target_ms,
                                      priority=c.priority)
                placed.append(node.name)
        placements[c.name] = placed
    # distinguishes "admission never placed it" (rejected) from "its
    # placements died mid-trace and nobody re-admitted it" (dropped)
    admitted0 = {cn: bool(p) for cn, p in placements.items()}
    # orphaned classes whose re-admission attempt found no feasible node
    # (reported, not silently retried — PR-6 satellite)
    unplaceable: set = set()

    def readmit_orphans():
        """A class whose every placement died/drained re-arbitrates its
        share on whichever survivors can host its minimal share; one
        that fits NOWHERE is reported as unplaceable."""
        if policy != SLO_POLICY:
            return
        for c in classes:
            if placements[c.name]:
                unplaceable.discard(c.name)
                continue
            for node in nodes:
                if not node.routable or c.name in node.arbiter.tenants():
                    continue
                ok = node.arbiter.admission_check(
                    luts[c.name], c.service_target_ms, node.g(t),
                    priority=c.priority, min_accuracy=c.min_accuracy)
                if ok is not None:
                    node.arbiter.register(c.name, luts[c.name],
                                          c.service_target_ms,
                                          priority=c.priority,
                                          min_accuracy=c.min_accuracy)
                    placements[c.name].append(node.name)
            if placements[c.name]:
                unplaceable.discard(c.name)
            elif admitted0[c.name]:
                unplaceable.add(c.name)

    events = arr.merge({n: ts for n, ts in streams.items()})
    queues = {n.name: {c.name: collections.deque()  # repro: allow-unbounded(per-class work queue, drained every epoch; depth IS the backlog signal)
                       for c in classes}
              for n in nodes}
    busy_until = {n.name: {c.name: 0.0 for c in classes} for n in nodes}
    arrived_epoch = {n.name: {c.name: 0 for c in classes} for n in nodes}
    last_arrival = events[-1][0] if events else 0.0

    def svc_of(allocs):
        # granted OpPoints: the calibrated service model keys measured
        # bucket columns by the point's subnet spec
        return {n: a.point for n, a in allocs.items()}

    def resolve_failure(cn: str, it: _Req, tf: float, nn: Optional[str]):
        """One attempt just died at ``tf`` (fail-stop, lost route).

        Outcomes, in order: absorbed by a live hedge sibling (nothing is
        terminal while a copy is still in flight; a copy outlived by its
        winner counts ``hedge_wasted``); RETRIED — re-enqueued through
        the router after the class's backoff, if the attempt cap, the
        request's own deadline, and the cluster retry budget all allow;
        otherwise terminally ``failed``."""
        nonlocal retry_seq
        st = stats[cn]
        if it.gid >= 0:
            grp = hedge_groups[it.gid]
            grp["live"] -= 1
            if grp["done"]:
                st.hedge_wasted += 1
                return
            if grp["live"] > 0:
                return   # sibling still in flight: not terminal yet
            # last copy of an unresolved group: fall through (retryable)
        first_rid = it.first_rid
        if rel is not None:
            pol = rel.policy_for(cn)
            c = by_class[cn]
            if pol is None or it.attempts >= pol.max_attempts:
                retry_denied["attempts"] += 1
            else:
                t_retry = tf + pol.backoff(it.attempts)
                if t_retry > it.t0 + c.deadline_ms / 1e3:
                    # deadline-aware: a retry that cannot even resubmit
                    # before the SLO deadline is guaranteed-late work
                    retry_denied["deadline"] += 1
                elif not budget.allow(sum(s.completed
                                          for s in stats.values())):
                    retry_denied["budget"] += 1
                else:
                    if tracer is not None and first_rid < 0:
                        # record the failed attempt as its own span tree
                        # so the retry's span link points at something
                        first_rid = tracer.request(
                            cn, it.t, tf, node=nn, spans=[
                                (obs.ROUTE, it.t, it.t, None),
                                (obs.QUEUE, it.t, tf, None)])
                    st.retried += 1
                    retry_seq += 1
                    heapq.heappush(
                        retry_heap,
                        (t_retry, retry_seq, cn,
                         dataclasses.replace(it, t=t_retry,
                                             attempts=it.attempts + 1,
                                             gid=-1, first_rid=first_rid)))
                    return
        st.failed += 1   # error payloads, not lost

    def fail_node(nn: str, tf: float):
        """Fail-stop one node: queued work resolves as failed (or enters
        the retry path when a reliability layer runs), placements shrink,
        orphans re-admit — shared by ``fail_at`` scripting, chaos
        injections and the stall health check."""
        by_node[nn].state = DEAD
        for cn, q in queues[nn].items():
            for it in q:
                resolve_failure(cn, it, tf, nn)
            q.clear()
            busy_until[nn][cn] = 0.0
        for cn in placements:
            if nn in placements[cn]:
                placements[cn].remove(nn)
        readmit_orphans()

    # --- placement engine (rebalance_at / scale_at scripting) ---------------
    rebalance_due = sorted(rebalance_at)
    scale_due = sorted(scale_at)
    horizon_s = (rebalance_horizon_s if rebalance_horizon_s is not None
                 else (rebalance_due[1] - rebalance_due[0]
                       if len(rebalance_due) > 1 else 5.0))
    migrations: Deque[Tuple[float, str, Optional[str], Optional[str]]] = \
        collections.deque(maxlen=log_cap)
    preempted: Deque[Tuple[float, str, str, str]] = \
        collections.deque(maxlen=log_cap)
    scale_events: Deque[Tuple[float, str, str]] = \
        collections.deque(maxlen=log_cap)
    warming: List[Tuple[float, str, str]] = []   # (warm_t, cls, node)
    # make-before-break: (warm_t, cls, src, dst) retires deferred until
    # the destination replica's warmup lands
    pending_retires: List[Tuple[float, str, str, str]] = []
    # (node, cls) -> latest warmup end: attributes a routed request's
    # wait behind a migrating replica to a WARMING span, not queueing
    warm_until: Dict[Tuple[str, str], float] = {}
    scale_ewma = 0.0   # sustained cluster backlog per chip
    energy = {c.name: m.counter("sim_energy_mj_total", cls=c.name)
              for c in classes}
    mig_energy = m.counter("sim_migration_energy_mj_total")

    def spec_of(c) -> pl.ClassSpec:
        return pl.ClassSpec(
            name=c.name, lut=luts[c.name],
            target_latency_ms=reg_info[c.name]["target"],
            priority=reg_info[c.name]["priority"],
            min_accuracy=reg_info[c.name]["min_accuracy"],
            backlog=float(sum(len(queues[n.name][c.name])
                              for n in nodes if n.alive)),
            max_batch=c.max_batch,
            fallback_target_ms=(c.degraded_target_ms
                                if c.drop_policy == DEGRADE else None))

    def start_replica(cn: str, nn: str, t0: float, warm_s: float):
        """Register + WARM a replica: weight 0 and no serving until the
        weights have transferred and its buckets are compiled."""
        node = by_node[nn]
        if cn not in node.arbiter.tenants():
            node.arbiter.register(cn, luts[cn], reg_info[cn]["target"],
                                  priority=reg_info[cn]["priority"],
                                  min_accuracy=reg_info[cn]["min_accuracy"])
            if brown_on.get(cn) or wt_brown.get(cn):
                # class is browned out: the new replica serves the same
                # degraded target its siblings were pinned to
                node.arbiter.set_brownout(cn,
                                          by_class[cn].degraded_target_ms)
        if nn not in placements[cn]:
            placements[cn].append(nn)
        warm_t = t0 + warm_s
        busy_until[nn][cn] = max(busy_until[nn][cn], warm_t)
        warm_until[(nn, cn)] = max(warm_until.get((nn, cn), 0.0), warm_t)
        rtr.set_weight(cn, nn, 0.0)
        warming.append((warm_t, cn, nn))
        unplaceable.discard(cn)

    def retire_replica(cn: str, nn: str, dst: Optional[str]):
        """Export one replica's registration and re-route its queue to
        ``dst`` (or the first surviving placement), arrival order kept."""
        node = by_node[nn]
        if cn in node.arbiter.tenants():
            node.arbiter.export_tenant(cn)
        if nn in placements[cn]:
            placements[cn].remove(nn)
        q = queues[nn][cn]
        if q:
            home = dst or (placements[cn][0] if placements[cn] else None)
            if home is None:
                if rel is not None:
                    # homeless work enters the retry path (ambient epoch
                    # time — retire only ever runs inside the main loop)
                    for it in q:
                        resolve_failure(cn, it, t, nn)
                else:
                    stats[cn].dropped += len(q)
            else:
                moved = []
                for it in q:
                    if tracer is not None and it.first_rid < 0:
                        # preemption span link (ROADMAP follow-up a):
                        # record the preempted attempt's truncated tree
                        # (routed at it.t, queued on nn until the cut)
                        # so the second service attempt links back to it
                        frid = tracer.request(
                            cn, it.t, t, node=nn, spans=[
                                (obs.ROUTE, it.t, it.t, None),
                                (obs.QUEUE, it.t, t, None)])
                        it = dataclasses.replace(it, first_rid=frid)
                    moved.append(it)
                queues[home][cn] = collections.deque(  # repro: allow-unbounded(rebuilds an existing drained work queue; size bounded by its contents)
                    sorted(list(queues[home][cn]) + moved,
                           key=lambda r: (r.t, r.t0)))
            q.clear()
        busy_until[nn][cn] = 0.0
        warm_until.pop((nn, cn), None)

    def run_rebalance(tr: float):
        """One cluster-wide rebalance: fresh solve, priced diff, apply."""
        specs = [spec_of(c) for c in classes]
        up_nodes = [n for n in nodes if n.routable]
        plan = pl.plan_rebalance(specs, up_nodes, placements, t=tr,
                                 horizon_s=horizon_s,
                                 hysteresis=hysteresis, replicas=replicas,
                                 calibration=calibration)
        for mv in plan.moves:
            if mv.dst is not None:
                start_replica(mv.cls, mv.dst, tr, mv.cost_s)
                mig_energy.inc(mv.cost_j * 1e3)
            if mv.src is not None:
                if mv.dst is not None:
                    # make-before-break: the source keeps serving (and
                    # stays routable) until the destination's priced
                    # warmup lands — retiring it now would strand its
                    # queue behind a replica that cannot serve yet
                    pending_retires.append((tr + mv.cost_s, mv.cls,
                                            mv.src, mv.dst))
                else:
                    retire_replica(mv.cls, mv.src, None)
            log_event(migrations, "migrations", (tr, mv.cls, mv.src, mv.dst))
            m.counter("cluster_migrations_total", cls=mv.cls).inc()
            if tracer is not None:
                # the span covers the priced warmup: dst serves at
                # tr + cost_s, exactly when the router weight clears
                tracer.decision(obs.MIGRATE, tr, tr + mv.cost_s,
                                cls=mv.cls, node=mv.dst, src=mv.src,
                                cost_s=mv.cost_s)
        # cross-node preemption: a backlogged high-priority class evicts
        # the lowest-priority co-located replica that has another home
        evs = pl.plan_preemptions(
            specs, up_nodes, placements,
            node_backlog=lambda c, n2: float(len(queues[n2][c])))
        for ev in evs:
            retire_replica(ev.victim, ev.node, None)
            log_event(preempted, "preempted",
                      (tr, ev.victim, ev.node, ev.for_cls))
            m.counter("cluster_preemptions_total", cls=ev.victim).inc()
            if tracer is not None:
                tracer.decision(obs.PREEMPT, tr, tr, cls=ev.victim,
                                node=ev.node, for_cls=ev.for_cls)
        if tracer is not None:
            tracer.decision(obs.REBALANCE, tr, tr, moves=len(plan.moves),
                            preemptions=len(evs))

    def run_scaling(ts: float):
        """One autoscaler step over the node pool."""
        price = energy_price_fn(ts) if energy_price_fn is not None else 0.0
        plan = pl.plan_scaling(nodes, backlog_per_chip=scale_ewma,
                               energy_price=price, t=ts,
                               min_nodes=min_nodes)
        for nn in plan.spin_up:
            node = by_node[nn]
            node.state = UP
            log_event(scale_events, "scale_events", (ts, "up", nn))
            if tracer is not None:
                tracer.decision(obs.SCALE, ts, ts, node=nn,
                                direction="up")
            for c in classes:
                ok = node.arbiter.admission_check(
                    luts[c.name], reg_info[c.name]["target"], node.g(ts),
                    priority=reg_info[c.name]["priority"],
                    min_accuracy=reg_info[c.name]["min_accuracy"])
                if ok is not None:
                    cost = pl.migration_cost(spec_of(c),
                                             calibration=calibration)
                    start_replica(c.name, nn, ts, cost.seconds)
                    mig_energy.inc(cost.joules * 1e3)
        for nn in plan.spin_down:
            node = by_node[nn]
            # only an actually-idle node parks: queued or in-flight work
            # defers the spin-down to the next scale_at instant
            if any(queues[nn].values()) or any(
                    b > ts for b in busy_until[nn].values()):
                continue
            for cn in list(node.arbiter.tenants()):
                retire_replica(cn, nn, None)
            node.state = STANDBY
            log_event(scale_events, "scale_events", (ts, "down", nn))
            if tracer is not None:
                tracer.decision(obs.SCALE, ts, ts, node=nn,
                                direction="down")
            readmit_orphans()

    ei = 0
    t = 0.0
    while True:
        alive = [n for n in nodes if n.alive]
        backlog = ei < len(events) or bool(retry_heap) or any(
            q for n in alive for q in queues[n.name].values())
        in_flight = any(b > t for n in alive
                        for b in busy_until[n.name].values())
        if not backlog and not in_flight:
            break
        if t > last_arrival + max_drain_s:
            break   # safety: leftover queues flushed as dropped below

        # --- lifecycle events (epoch boundary) ------------------------------
        while chaos_due and chaos_due[0][0] <= t:
            # injection becomes visible this boundary: log it (scenario
            # timestamps — part of the determinism contract) + CHAOS span
            tc, kind, nn = chaos_due.pop(0)
            injections.append((tc, kind, nn))
            m.counter("chaos_injections_total", kind=kind).inc()
            if tracer is not None:
                tracer.decision(obs.CHAOS, t, t, node=nn, kind=kind)
        for nn, td in drain_at.items():
            if by_node[nn].state == UP and t >= td:
                by_node[nn].state = DRAINING
        for nn, tw in wedge_at.items():
            # silent stall: stays routable, stops completing — only the
            # health check (or the drain-horizon safety) can end this
            if by_node[nn].alive and t >= tw:
                wedged[nn] = True
        for nn, tf in fail_at.items():
            if by_node[nn].state != DEAD and t >= tf:
                fail_node(nn, t)
        for node in nodes:
            nn = node.name
            if node.state == DRAINING and not any(
                    queues[nn].values()) and not any(
                    b > t for b in busy_until[nn].values()):
                # queues emptied: migrate the registrations off the node
                node.state = DRAINED
                for cn in node.arbiter.tenants():
                    node.arbiter.export_tenant(cn)
                    if nn in placements.get(cn, ()):
                        placements[cn].remove(nn)
                readmit_orphans()

        # --- placement engine (epoch boundary) ------------------------------
        while warming and min(w[0] for w in warming) <= t:
            # warmed replicas rejoin the rotation
            done_w = [w for w in warming if w[0] <= t]
            for _, cn, nn in done_w:
                rtr.set_weight(cn, nn, None)
            warming = [w for w in warming if w[0] > t]
        if pending_retires:
            # make-before-break back half: the destination is warm (its
            # router weight just cleared above) — NOW retire the source,
            # re-homing its backlog onto the serving destination.  A
            # destination that died (or was preempted away) meanwhile
            # falls back to any surviving placement; a source already
            # gone needs nothing.
            due_r = [p for p in pending_retires if p[0] <= t]
            pending_retires = [p for p in pending_retires if p[0] > t]
            for _, cn, src, dst in due_r:
                if src not in placements.get(cn, ()):
                    continue
                dest = (dst if dst in placements.get(cn, ())
                        and by_node[dst].alive else None)
                retire_replica(cn, src, dest)
        up_chips = sum(n.g(t).total_chips for n in nodes if n.state == UP)
        backlog_now = sum(len(q) for n in nodes if n.alive
                          for q in queues[n.name].values())
        scale_ewma = (_SCALE_BETA * scale_ewma + (1.0 - _SCALE_BETA)
                      * (backlog_now / max(1, up_chips)))
        while scale_due and scale_due[0] <= t:
            scale_due.pop(0)
            run_scaling(t)
        while rebalance_due and rebalance_due[0] <= t:
            rebalance_due.pop(0)
            run_rebalance(t)

        # --- chaos continuous overlays (polled each epoch) ------------------
        if timeline is not None:
            for node in nodes:
                # thermal ladder → DVFS throttle: the node's arbiter
                # re-water-fills over the low-frequency LUT points
                node.chaos_throttle = timeline.throttle(node.name, t)

        # --- per-node arbitration with backlog signals ----------------------
        allocs: Dict[str, dict] = {}
        svc: Dict[str, dict] = {}
        for node in nodes:
            if not node.alive:
                continue
            nn = node.name
            for cn in node.arbiter.tenants():
                q = queues[nn][cn]
                node.arbiter.set_active(
                    cn, bool(q) or busy_until[nn][cn] > t,
                    queue_depth=len(q),
                    arrival_rate_rps=arrived_epoch[nn][cn] / interval_s)
                arrived_epoch[nn][cn] = 0
            allocs[nn] = node.arbiter.tick(node.g(t))
            svc[nn] = svc_of(allocs[nn])
            if tracer is not None:
                tracer.decision(
                    obs.ARBITRATE, t, t, node=nn,
                    tenants=len(allocs[nn]),
                    granted=sum(a.chips for a in allocs[nn].values()))
        t_next = t + interval_s
        # epoch-start outcome snapshot: brownout pressure is computed
        # from THIS epoch's deltas at the end of the epoch
        if rel is not None and rel.brownout is not None:
            brown_snap = {cn: (stats[cn].failed + stats[cn].retried,
                               stats[cn].completed + stats[cn].failed
                               + stats[cn].dropped + stats[cn].retried)
                          for cn in stats}
        if wt is not None:
            wt_snap = {cn: (stats[cn].good, stats[cn].completed,
                            stats[cn].dropped, stats[cn].failed)
                       for cn in stats}

        def route_candidates(cn: str, ta: float):
            """Routable placements minus chaos-partitioned edges."""
            cands = [by_node[x] for x in placements[cn]]
            if timeline is not None:
                cands = [nd for nd in cands
                         if not timeline.partitioned(nd.name, ta)]
            return cands

        def load_at(ta: float):
            return lambda nd: nd.load(
                ta, extra_backlog=sum(arrived_epoch[nd.name].values()))

        # --- re-route retries that came due (reliability layer) -------------
        while retry_heap and retry_heap[0][0] < t_next:
            t_r, _, cn, it = heapq.heappop(retry_heap)
            cands = route_candidates(cn, t_r)
            node = rtr.pick(cn, cands, t=t_r, load_fn=load_at(t_r)) \
                if cands else None
            if node is None:
                # nowhere to go *right now* — treat as one more failed
                # attempt (may back off again if attempts/deadline allow)
                resolve_failure(cn, it, t_r, None)
                continue
            arrived_epoch[node.name][cn] += 1
            queues[node.name][cn].append(it)

        # --- route + admit/shed this epoch's arrivals -----------------------
        while ei < len(events) and events[ei][0] < t_next:
            ta, cn = events[ei]
            ei += 1
            c = by_class[cn]
            st = stats[cn]
            st.submitted += 1
            if not placements[cn]:
                if admitted0[cn]:
                    st.dropped += 1   # lost its nodes to failures/drains
                else:
                    st.rejected += 1  # admission never placed the class
                continue
            cands = route_candidates(cn, ta)
            node = rtr.pick(cn, cands, t=ta, load_fn=load_at(ta)) \
                if cands else None
            if node is None:
                if rel is not None:
                    # no reachable replica (all partitioned/warming):
                    # the reliability layer may retry once edges heal
                    resolve_failure(cn, _Req(t=ta, t0=ta), ta, None)
                else:
                    st.dropped += 1   # placements exist but none routable
                continue
            nn = node.name
            arrived_epoch[nn][cn] += 1
            if policy == SLO_POLICY and svc[nn].get(cn) is None:
                # arrival for a class holding no slice on its node:
                # preempt NOW, mid-cycle, exactly as the single-node path
                node.arbiter.preempt(cn, node.g(ta))
                allocs[nn] = node.arbiter.last_allocations()
                svc[nn] = svc_of(allocs[nn])
            if (policy == SLO_POLICY and c.drop_policy == SHED
                    and not brown_on[cn]
                    and svc[nn].get(cn) is not None):
                q_len = len(queues[nn][cn])
                occ = min(q_len + 1, c.max_batch)
                pt = svc[nn][cn]
                lm = (timeline.latency_mult(nn, ta)
                      if timeline is not None else 1.0)
                batch_ms = lm * _service_ms(pt.latency_ms, occ, c.max_batch,
                                            service_model, spec=pt.subnet,
                                            calibration=calibration)
                n_batches = math.ceil((q_len + 1) / c.max_batch)
                eta_ms = (max(0.0, busy_until[nn][cn] - ta) * 1e3
                          + n_batches * batch_ms)
                if eta_ms > c.deadline_ms:
                    st.dropped += 1   # predicted miss: shed on arrival
                    continue
            it = _Req(t=ta, t0=ta)
            pol = rel.policy_for(cn) if rel is not None else None
            if pol is not None and pol.hedge and len(cands) > 1:
                # hedged request: a SECOND copy on a distinct replica
                # that holds a slice; first completion wins, the loser
                # counts hedge_wasted (submitted counted ONCE)
                others = [nd for nd in cands if nd.name != nn]
                second = rtr.pick(cn, others, t=ta, load_fn=load_at(ta))
                if second is not None \
                        and svc.get(second.name, {}).get(cn) is not None:
                    gid = next_gid
                    next_gid += 1
                    hedge_groups[gid] = {"live": 2, "done": False}
                    it = _Req(t=ta, t0=ta, gid=gid)
                    queues[second.name][cn].append(it)
                    arrived_epoch[second.name][cn] += 1
            queues[nn][cn].append(it)

        # --- serve each node's queues in batches ----------------------------
        for node in nodes:
            if not node.alive or wedged[node.name]:
                continue   # wedged: accepts routes, completes nothing
            nn = node.name
            dies = fail_at.get(nn, math.inf)
            lm = (timeline.latency_mult(nn, t)
                  if timeline is not None else 1.0)   # straggler slowdown
            for cn, q in queues[nn].items():
                pt = svc.get(nn, {}).get(cn)
                if pt is None:
                    continue   # starved this epoch; queue waits
                c = by_class[cn]
                st = stats[cn]
                while q:
                    start = max(q[0].t, busy_until[nn][cn], t)
                    if start >= t_next:
                        break
                    k = 0
                    for item in q:
                        if item.t <= start and k < c.max_batch:
                            k += 1
                        else:
                            break
                    k = max(k, 1)
                    done = start + lm * _service_ms(
                        pt.latency_ms, k, c.max_batch, service_model,
                        spec=pt.subnet, calibration=calibration) / 1e3
                    if done > dies:
                        break   # the node dies first: fail_at errors these
                    busy_until[nn][cn] = done
                    st.batches += 1
                    st.batch_occupancy += k
                    energy[cn].inc(pt.energy_mj)
                    completions[nn].inc(k)
                    if tracer is not None:
                        dev_attrs = {
                            "bucket": k, "n": k,
                            "subnet": (pt.subnet.name()
                                       if hasattr(pt.subnet, "name")
                                       else str(pt.subnet))}
                        warm_t = warm_until.get((nn, cn), 0.0)
                    for _ in range(k):
                        it = q.popleft()
                        if it.gid >= 0:
                            grp = hedge_groups[it.gid]
                            grp["live"] -= 1
                            if grp["done"]:
                                # sibling answered first: this copy paid
                                # for a batch slot and nothing else
                                st.hedge_wasted += 1
                                continue
                            grp["done"] = True
                        lat_ms = (done - it.t0) * 1e3
                        st.completed += 1
                        st.latencies_ms.append(lat_ms)
                        if lat_ms <= c.deadline_ms:
                            st.good += 1
                        if tracer is None:
                            lat_hist[cn].observe(lat_ms)
                            continue
                        # virtual-time span tree, same schema as live:
                        # host-side stages are zero-width points at batch
                        # start (the analytic service model folds them
                        # into `device`); a wait behind a migrating
                        # replica's warmup is WARMING, the rest QUEUE —
                        # the components still partition [it.t, done].
                        # A retry's tree starts at ITS OWN submit time
                        # and links to the first failed attempt's tree.
                        w1 = min(start, warm_t)
                        spans = [(obs.ROUTE, it.t, it.t, None)]
                        if w1 > it.t:
                            spans.append((obs.WARMING, it.t, w1, None))
                            spans.append((obs.QUEUE, w1, start, None))
                        else:
                            spans.append((obs.QUEUE, it.t, start, None))
                        spans.extend([
                            (obs.COLLECT, start, start, None),
                            (obs.STACK, start, start, None),
                            (obs.DISPATCH, start, start, None),
                            (obs.DEVICE, start, done, dev_attrs),
                            (obs.COMPLETE, done, done, None)])
                        rid = tracer.request(cn, it.t, done, node=nn,
                                             spans=spans,
                                             links=([it.first_rid]
                                                    if it.first_rid >= 0
                                                    else ()))
                        lat_hist[cn].observe(lat_ms, exemplar=rid)

        # --- stall-based health check (end of epoch) ------------------------
        for node in nodes:
            nn = node.name
            if nn not in health or node.state != UP:
                continue
            backlog_n = sum(len(q) for q in queues[nn].values())
            if health[nn].observe(int(completions[nn].value), backlog_n):
                # completions flat for K epochs with queued work: the
                # node is wedged — auto-fail it over, exactly the path
                # an operator-scripted fail_at would take
                log_event(health_failed, "health", (t_next, nn))
                if tracer is not None:
                    tracer.decision(obs.HEALTH_FAIL, t_next, t_next,
                                    node=nn)
                fail_node(nn, t_next)

        # --- brownout: degrade under sustained chaos pressure ---------------
        if rel is not None and rel.brownout is not None:
            bp = rel.brownout
            for cn, st in stats.items():
                bad = (st.failed + st.retried) - brown_snap[cn][0]
                total = (st.completed + st.failed + st.dropped
                         + st.retried) - brown_snap[cn][1]
                frac = bad / total if total else 0.0
                brown_p[cn] = bp.beta * brown_p[cn] + (1 - bp.beta) * frac
                if not brown_on[cn] and brown_p[cn] >= bp.enter_pressure:
                    # serve degraded instead of dropping: every replica's
                    # arbiter pins the class to its DEGRADE target and
                    # the shed check is suspended (see arrivals above)
                    brown_on[cn] = True
                    brownouts.append((t_next, cn, "enter"))
                    m.counter("cluster_brownouts_total", cls=cn).inc()
                    for nn2 in placements[cn]:
                        if cn in by_node[nn2].arbiter.tenants():
                            by_node[nn2].arbiter.set_brownout(
                                cn, by_class[cn].degraded_target_ms)
                    if tracer is not None:
                        tracer.decision(obs.BROWNOUT, t_next, t_next,
                                        cls=cn, direction="enter")
                elif brown_on[cn] and brown_p[cn] <= bp.exit_pressure:
                    brown_on[cn] = False
                    brownouts.append((t_next, cn, "exit"))
                    if not wt_brown[cn]:
                        # watchtower still burning: its alert owns the
                        # degraded target until it clears
                        for nn2 in placements[cn]:
                            if cn in by_node[nn2].arbiter.tenants():
                                by_node[nn2].arbiter.set_brownout(cn, None)
                    if tracer is not None:
                        tracer.decision(obs.BROWNOUT, t_next, t_next,
                                        cls=cn, direction="exit")

        # --- SLO watchtower: feed outcomes, evaluate, actuate ---------------
        if wt is not None:
            for cn, st in stats.items():
                g0, c0, d0, f0 = wt_snap[cn]
                d_good = st.good - g0
                bad = ((st.completed - c0) - d_good
                       + (st.dropped - d0) + (st.failed - f0))
                # every epoch samples (zeros keep the window clock
                # honest: no-traffic epochs burn nothing)
                wt.observe(t_next, cn, good=d_good, bad=bad)
            alerts_new = wt.evaluate(t_next)
            run_alerts.extend(alerts_new)
            if wt.actuate:
                for cn in stats:
                    p = wt.pressure(cn)
                    for nn2 in placements[cn]:
                        by_node[nn2].arbiter.set_alert_pressure(cn, p)
                    c = by_class[cn]
                    if (wt.active(cn) and not wt_brown[cn]
                            and c.degraded_target_ms > c.service_target_ms):
                        # alert-driven early degrade: the fast burn sees
                        # LATE completions, which the failure-pressure
                        # EWMA is blind to — a pure latency fault relaxes
                        # the arbiter's quality target here, epochs
                        # before (or entirely without) the reactive
                        # path; the shed check stays ON (only the EWMA
                        # brownout suspends admission control)
                        wt_brown[cn] = True
                        brownouts.append((t_next, cn, "enter"))
                        m.counter("cluster_brownouts_total", cls=cn).inc()
                        if not brown_on[cn]:
                            for nn2 in placements[cn]:
                                if cn in by_node[nn2].arbiter.tenants():
                                    by_node[nn2].arbiter.set_brownout(
                                        cn, c.degraded_target_ms)
                        if tracer is not None:
                            tracer.decision(obs.BROWNOUT, t_next, t_next,
                                            cls=cn, direction="enter")
                    elif wt_brown[cn] and not wt.active(cn):
                        wt_brown[cn] = False
                        brownouts.append((t_next, cn, "exit"))
                        if not brown_on[cn]:
                            for nn2 in placements[cn]:
                                if cn in by_node[nn2].arbiter.tenants():
                                    by_node[nn2].arbiter.set_brownout(
                                        cn, None)
                        if tracer is not None:
                            tracer.decision(obs.BROWNOUT, t_next, t_next,
                                            cls=cn, direction="exit")
                if getattr(wt, "rebalance_on_alert", False) and alerts_new:
                    # alert pressure reaches the placement layer too: a
                    # rising-edge alert triggers the autoscaler NOW
                    # instead of at the next scheduled scale_at instant
                    # — the same water-filling objective decides, the
                    # alert only moves the clock.  Only when no standby
                    # capacity came up does a full rebalance run:
                    # rebalancing WHILE fresh replicas warm retires the
                    # degraded-but-serving sources into a capacity hole
                    n_scale = len(scale_events)
                    run_scaling(t_next)
                    if len(scale_events) == n_scale:
                        run_rebalance(t_next)
        t = t_next

    for node in nodes:
        for cn, q in queues[node.name].items():
            for it in q:
                if it.gid >= 0:
                    # horizon flush is terminal: no retries — but a copy
                    # whose sibling already answered is just hedge waste,
                    # and one with a live sibling defers to it
                    grp = hedge_groups[it.gid]
                    grp["live"] -= 1
                    if grp["done"]:
                        stats[cn].hedge_wasted += 1
                        continue
                    if grp["live"] > 0:
                        continue
                if node.state == DEAD:
                    stats[cn].failed += 1
                else:
                    stats[cn].dropped += 1   # unserved within the horizon
            q.clear()
    for _, _, cn, _it in retry_heap:
        stats[cn].failed += 1   # retry scheduled past the horizon
    node_view = {n.name: {"state": n.state,
                          "capacity_chips": n.g(t).total_chips,
                          "arbiter": n.arbiter.summary()}
                 for n in nodes}
    return ClusterReport(policy=policy, router=router, classes=stats,
                         nodes=node_view, decisions=list(rtr.decisions),
                         routed=rtr.routed_counts(),
                         health_failed=list(health_failed),
                         migrations=list(migrations),
                         preempted=list(preempted),
                         scale_events=list(scale_events),
                         unplaceable=sorted(unplaceable),
                         injections=list(injections),
                         brownouts=list(brownouts),
                         alerts=list(run_alerts),
                         retry_granted=budget.granted if budget else 0,
                         retry_denied=dict(retry_denied),
                         decisions_dropped=rtr.decisions_dropped,
                         log_dropped=dict(log_dropped),
                         energy_mj={c.name: energy[c.name].value
                                    for c in classes},
                         migration_energy_mj=mig_energy.value,
                         tracer=tracer, metrics=m)
