"""Cluster layer: multi-node serving over the runtime arbiter stack.

The paper's runtime manager arbitrates ONE device's chips and power;
the follow-up work (Xun et al., DATE 2021) frames the manager as a
hierarchy — per-device decisions under a global coordinator.  This
package is that coordinator, and the ROADMAP's "multi-host traffic"
scaling axis: N independent nodes (each a :class:`ResourceArbiter` plus
its :class:`DynamicServer`s, exactly as PRs 1-3 built them) composed
under a cluster front-end that adds

* **routing** — :class:`ClusterRouter` spreads one SLO class across its
  placement nodes by power-of-two-choices / least-loaded over the
  backlog-per-chip signal the arbiters already track (round-robin is the
  baseline the benchmark beats);
* **cluster-level admission** — :func:`cluster_admission` admits a class
  iff SOME node's headroom (:meth:`ResourceArbiter.headroom`) fits its
  minimal share, raising :class:`AdmissionError` otherwise;
* **lifecycle** — :meth:`Cluster.drain` (stop routing, let queues empty,
  migrate tenant registrations to survivors) and :meth:`Cluster.fail`
  (fail-stop: queued requests resolve with error payloads and orphaned
  classes re-arbitrate elsewhere);
* **deterministic benchmarking** — :func:`simulate_cluster` mirrors
  ``traffic.driver.simulate`` in virtual time, so routing policies are
  compared bit-reproducibly on one seeded trace
  (``benchmarks/bench_cluster.py``).
"""
from repro.cluster.node import (DEAD, DRAINED, DRAINING, HEALTH_EPOCHS,
                                NODE_STATES, STANDBY, UP, ClusterNode,
                                StallDetector)
from repro.cluster.router import (LEAST_LOADED, P2C, ROUND_ROBIN, ROUTERS,
                                  ClusterRouter)
from repro.cluster.admission import cluster_admission, cluster_headroom
from repro.cluster.placement import (ClassSpec, Eviction, MigrationCost,
                                     Move, PlacementPlan, RebalancePlan,
                                     ScalePlan, migration_cost,
                                     plan_preemptions, plan_rebalance,
                                     plan_scaling, solve_placement)
from repro.cluster.frontend import Cluster
from repro.cluster.sim import (FIRST_FIT, REPLICATE, ClusterReport,
                               simulate_cluster)
