"""Core types for dynamic (elastic) networks.

The paper's algorithm knob is a *sub-network* of a trained supernet,
selected at runtime by the resource manager.  A sub-network is described by
a :class:`SubnetSpec` — a frozen, hashable dataclass so that it can key a
compiled-executable cache (sliced mode) and be carried as a static argument
through ``jax.jit``.

``ElasticSpace`` describes the *discrete* options the supernet was trained
for (the paper trains a small set of Pareto-optimal sub-networks).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence, Tuple, Union

import numpy as np

# An "active dim" is either:
#   None          -> full dimension (not elastic here)
#   int           -> STATIC active size: params are sliced at trace time
#   jax.Array     -> DYNAMIC (traced) active size: channels are masked
Active = Union[None, int, "jax.Array"]  # noqa: F821


def is_static(a: Active) -> bool:
    return a is None or isinstance(a, (int, np.integer))


@dataclasses.dataclass(frozen=True)
class SubnetSpec:
    """A single sub-network of the supernet.  Hashable; keys compile caches.

    Multipliers apply to the *full* config dimension and are rounded to the
    hardware/sharding-friendly multiple declared by the ElasticSpace.
    """

    width_mult: float = 1.0        # residual stream / conv channels
    ffn_mult: float = 1.0          # FFN hidden (or per-expert hidden)
    heads_mult: float = 1.0        # attention query heads
    depth_mult: float = 1.0        # fraction of layers (layer scaling)
    num_experts: Optional[int] = None   # MoE: active experts
    top_k: Optional[int] = None         # MoE: active top-k
    kernel_size: Optional[int] = None   # conv: elastic kernel (center crop)
    resolution: Optional[int] = None    # input resolution knob
    steps: Optional[int] = None         # diffusion sampler steps

    def is_full(self) -> bool:
        return (
            self.width_mult == 1.0
            and self.ffn_mult == 1.0
            and self.heads_mult == 1.0
            and self.depth_mult == 1.0
            and self.num_experts is None
            and self.top_k is None
            and self.kernel_size is None
        )

    def name(self) -> str:
        parts = [
            f"w{self.width_mult:g}",
            f"f{self.ffn_mult:g}",
            f"h{self.heads_mult:g}",
            f"d{self.depth_mult:g}",
        ]
        if self.num_experts is not None:
            parts.append(f"e{self.num_experts}")
        if self.top_k is not None:
            parts.append(f"k{self.top_k}")
        if self.kernel_size is not None:
            parts.append(f"ks{self.kernel_size}")
        if self.resolution is not None:
            parts.append(f"r{self.resolution}")
        if self.steps is not None:
            parts.append(f"s{self.steps}")
        return "-".join(parts)


FULL = SubnetSpec()


@dataclasses.dataclass(frozen=True)
class ElasticSpace:
    """The discrete sub-network design space the supernet supports.

    ``round_to`` guarantees sliced dims stay divisible by the tensor-model
    sharding (mesh model axis size x MXU lane width where applicable).
    """

    width_mults: Tuple[float, ...] = (1.0,)
    ffn_mults: Tuple[float, ...] = (1.0,)
    heads_mults: Tuple[float, ...] = (1.0,)
    depth_mults: Tuple[float, ...] = (1.0,)
    expert_counts: Tuple[int, ...] = ()
    top_ks: Tuple[int, ...] = ()
    kernel_sizes: Tuple[int, ...] = ()
    round_to: int = 1

    def min_spec(self) -> SubnetSpec:
        return SubnetSpec(
            width_mult=min(self.width_mults),
            ffn_mult=min(self.ffn_mults),
            heads_mult=min(self.heads_mults),
            depth_mult=min(self.depth_mults),
            num_experts=min(self.expert_counts) if self.expert_counts else None,
            top_k=min(self.top_ks) if self.top_ks else None,
            kernel_size=min(self.kernel_sizes) if self.kernel_sizes else None,
        )

    def max_spec(self) -> SubnetSpec:
        return FULL

    def enumerate(self, limit: Optional[int] = None) -> Tuple[SubnetSpec, ...]:
        """Cartesian enumeration of the space (optionally capped)."""
        experts: Sequence = self.expert_counts or (None,)
        topks: Sequence = self.top_ks or (None,)
        kss: Sequence = self.kernel_sizes or (None,)
        out = []
        for w, f, h, d, e, k, ks in itertools.product(
            self.width_mults, self.ffn_mults, self.heads_mults,
            self.depth_mults, experts, topks, kss,
        ):
            out.append(SubnetSpec(w, f, h, d, e, k, ks))
            if limit is not None and len(out) >= limit:
                break
        return tuple(out)

    def sample(self, rng: np.random.Generator) -> SubnetSpec:
        """Sample a random subnet (host-side; used by the sandwich rule)."""
        pick = lambda xs: xs[int(rng.integers(len(xs)))] if xs else None
        return SubnetSpec(
            width_mult=pick(self.width_mults),
            ffn_mult=pick(self.ffn_mults),
            heads_mult=pick(self.heads_mults),
            depth_mult=pick(self.depth_mults),
            num_experts=pick(self.expert_counts),
            top_k=pick(self.top_ks),
            kernel_size=pick(self.kernel_sizes),
        )


def round_channels(dim: int, mult: float, multiple_of: int = 1) -> int:
    """Scale ``dim`` by ``mult`` and round to a friendly multiple (>=1).

    Mirrors MobileNet/OFA channel rounding but with an explicit multiple so
    sliced dims stay divisible by (model-shards x 128) when required.
    """
    if mult >= 1.0:
        return dim
    target = dim * mult
    n = max(multiple_of, int(target / multiple_of + 0.5) * multiple_of)
    return min(n, dim)
