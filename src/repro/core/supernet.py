"""Sandwich-rule supernet training step (the paper's training recipe).

One masked-mode executable evaluates the max sub-network (teacher, CE on
labels), the min sub-network and ``n_random`` random sub-networks
(students, in-place distillation from the teacher) every step — Slimmable
Networks' sandwich rule as used by Dynamic-OFA.

The random widths enter the jitted step as TRACED scalars, so one compile
covers the whole elastic space; the host samples specs per step.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distill import ce_loss, kd_loss
from repro.core.elastic import sandwich_specs, spec_to_dynamic
from repro.core.types import ElasticSpace
from repro.optim import clip_by_global_norm


def make_sandwich_step(apply_fn: Callable, update_fn: Callable,
                       dims: Dict[str, int], *, n_random: int = 2,
                       kd_weight: float = 1.0, temperature: float = 1.0,
                       clip: float = 1.0):
    """Returns (step_fn, sample_fn).

    ``apply_fn(params, batch, E) -> logits``;
    ``step_fn(params, opt, batch, E_stack, step)`` jit-able;
    ``sample_fn(rng) -> E_stack`` host-side sandwich sampling producing a
    dict of stacked int32 arrays with leading dim (1 + n_random)
    [min, random...] — the teacher (max) runs unmasked.
    """
    n_students = 1 + n_random

    def step_fn(params, opt, batch, E_stack, step):
        def loss_fn(p):
            teacher = apply_fn(p, batch, None)
            loss = ce_loss(teacher, batch["labels"])
            for i in range(n_students):
                E = {k: v[i] for k, v in E_stack.items()}
                logits = apply_fn(p, batch, E)
                loss = loss + kd_weight * kd_loss(logits, teacher,
                                                  temperature) / n_students
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, gn = clip_by_global_norm(grads, clip)
        params, opt = update_fn(params, grads, opt, step)
        return params, opt, {"loss": loss, "gnorm": gn}

    def sample_fn(space: ElasticSpace, rng: np.random.Generator):
        specs = [space.min_spec()] + [space.sample(rng)
                                      for _ in range(n_random)]
        stacks: Dict[str, list] = {}
        for spec in specs:
            E = spec_to_dynamic(spec, dims)
            for k, v in E.items():
                stacks.setdefault(k, []).append(v)
        return {k: jnp.stack(v) for k, v in stacks.items()}

    return step_fn, sample_fn
