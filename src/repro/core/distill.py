"""In-place distillation for supernet training (sandwich rule).

The largest sub-network acts as the teacher within the same training step
(Yu et al. 2019; Cai et al. 2020 progressive shrinking): sub-network logits
are trained against soft teacher targets, the teacher against ground truth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kd_loss(student_logits: jax.Array, teacher_logits: jax.Array,
            temperature: float = 1.0) -> jax.Array:
    """KL(teacher || student) with stop-gradient teacher, mean over tokens."""
    t = jax.lax.stop_gradient(teacher_logits) / temperature
    s = student_logits / temperature
    p_t = jax.nn.softmax(t, -1)
    logp_t = jax.nn.log_softmax(t, -1)
    logp_s = jax.nn.log_softmax(s, -1)
    kl = jnp.sum(p_t * (logp_t - logp_s), axis=-1)
    return jnp.mean(kl) * temperature ** 2


def ce_loss(logits: jax.Array, labels: jax.Array,
            mask: jax.Array | None = None) -> jax.Array:
    """Token-mean cross entropy; labels int32, optional validity mask."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


def sandwich_loss(apply_fn, params, batch, specs, *, kd_weight: float = 1.0,
                  temperature: float = 1.0):
    """Sandwich-rule loss: teacher (max) on labels + students on KD.

    ``apply_fn(params, batch, spec) -> logits``.  ``specs`` must start with
    the max spec.  Returns (total_loss, metrics).
    """
    teacher_logits = apply_fn(params, batch, specs[0])
    loss = ce_loss(teacher_logits, batch["labels"])
    metrics = {"loss_teacher": loss}
    for i, spec in enumerate(specs[1:]):
        logits = apply_fn(params, batch, spec)
        l_kd = kd_loss(logits, teacher_logits, temperature)
        l_ce = ce_loss(logits, batch["labels"])
        loss = loss + kd_weight * l_kd + (1.0 - min(kd_weight, 1.0)) * l_ce
        metrics[f"loss_subnet{i}"] = l_kd
    return loss, metrics
