"""Elastic layers (pure JAX, functional: init -> params dict, apply).

Every layer supports three regimes for each elastic dimension:
  * ``None``            — full size;
  * static ``int``      — sliced parameters (serving mode, compute shrinks);
  * traced scalar       — masked channels (training mode, single executable).

Masked-mode invariant: activations are exact zeros beyond the active count,
and normalisation statistics are computed over active channels only, so the
two regimes produce bit-comparable results (property-tested).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.elastic import active_mask, count_or_none, mask_dim, resolve, take_dim
from repro.core.types import is_static


def _cast(p, dtype):
    return p.astype(dtype) if p.dtype != dtype else p


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = True,
               dtype=jnp.float32, scale: Optional[float] = None) -> dict:
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    k_w, _ = jax.random.split(key)
    p = {"kernel": jax.random.normal(k_w, (d_in, d_out), dtype) * scale}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: dict, x: jax.Array, *, a_in=None, a_out=None) -> jax.Array:
    """x: (..., d_in). Elastic in/out channels.

    In masked mode the input is assumed already zero beyond ``a_in`` (the
    zeros kill the extra rows of the kernel), so only the output needs a
    mask.  In sliced mode both kernel dims are sliced.
    """
    w, b = p["kernel"], p.get("bias")
    if a_in is not None and is_static(a_in):
        w = take_dim(w, a_in, 0)
    if a_out is not None and is_static(a_out):
        w = take_dim(w, a_out, 1)
        if b is not None:
            b = take_dim(b, a_out, 0)
    y = x @ _cast(w, x.dtype)
    if b is not None:
        y = y + _cast(b, x.dtype)
    if a_out is not None and not is_static(a_out):
        y = mask_dim(y, a_out, -1)
    return y


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------

def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p: dict, x: jax.Array, *, a=None, eps: float = 1e-6) -> jax.Array:
    d = x.shape[-1]
    scale, bias = p["scale"], p["bias"]
    if a is not None and is_static(a):
        # sliced mode: caller already sliced x to (..., a)
        scale, bias = take_dim(scale, a, 0), take_dim(bias, a, 0)
        a = None
    if a is None:
        mean = jnp.mean(x, -1, keepdims=True)
        var = jnp.mean(jnp.square(x - mean), -1, keepdims=True)
        y = (x - mean) * jax.lax.rsqrt(var + eps)
        return y * _cast(scale, x.dtype) + _cast(bias, x.dtype)
    # masked statistics over active channels only
    n = a.astype(x.dtype)
    m = active_mask(a, d, x.dtype)
    mean = jnp.sum(x * m, -1, keepdims=True) / n
    var = jnp.sum(jnp.square((x - mean) * m), -1, keepdims=True) / n
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * _cast(scale, x.dtype) + _cast(bias, x.dtype)
    return y * m


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: dict, x: jax.Array, *, a=None, eps: float = 1e-6) -> jax.Array:
    d = x.shape[-1]
    scale = p["scale"]
    if a is not None and is_static(a):
        scale = take_dim(scale, a, 0)
        a = None
    if a is None:
        ms = jnp.mean(jnp.square(x), -1, keepdims=True)
        return x * jax.lax.rsqrt(ms + eps) * _cast(scale, x.dtype)
    n = a.astype(x.dtype)
    m = active_mask(a, d, x.dtype)
    ms = jnp.sum(jnp.square(x * m), -1, keepdims=True) / n
    return x * jax.lax.rsqrt(ms + eps) * _cast(scale, x.dtype) * m


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"embedding": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embedding_apply(p: dict, ids: jax.Array, *, a=None, dtype=jnp.bfloat16) -> jax.Array:
    tbl = p["embedding"]
    if a is not None and is_static(a):
        tbl = take_dim(tbl, a, 1)
        a = None
    y = _cast(tbl, dtype)[ids]
    return mask_dim(y, a, -1) if a is not None else y


def embedding_attend(p: dict, x: jax.Array, *, a=None) -> jax.Array:
    """Tied-embedding logits: x (..., d) @ embedding.T -> (..., vocab)."""
    tbl = p["embedding"]
    if a is not None and is_static(a):
        tbl = take_dim(tbl, a, 1)
    return x @ _cast(tbl, x.dtype).T


# ---------------------------------------------------------------------------
# MLP blocks (dense FFN)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, *, gated: bool = True,
             bias: bool = False, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d_model, d_ff, bias=bias, dtype=dtype),
         "wo": dense_init(ks[1], d_ff, d_model, bias=bias, dtype=dtype)}
    if gated:
        p["wg"] = dense_init(ks[2], d_model, d_ff, bias=bias, dtype=dtype)
    return p


def mlp_apply(p: dict, x: jax.Array, *, a_model=None, a_ff=None,
              act: str = "silu") -> jax.Array:
    """Gated (SwiGLU) or plain FFN with elastic hidden and model dims."""
    h = dense_apply(p["wi"], x, a_in=a_model, a_out=a_ff)
    fn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    if "wg" in p:
        g = dense_apply(p["wg"], x, a_in=a_model, a_out=a_ff)
        h = fn(g) * h
    else:
        h = fn(h)
    if a_ff is not None and not is_static(a_ff):
        h = mask_dim(h, a_ff, -1)   # act(0)=0 for relu/silu but not gelu-tanh
    return dense_apply(p["wo"], h, a_in=a_ff, a_out=a_model)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (B, S, ..., D) with D even; positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq  # (B,S,half)
    # broadcast over head dims between S and D
    extra = x.ndim - 3
    ang = ang.reshape(ang.shape[:2] + (1,) * extra + (half,))
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, elastic query heads, ref / blocked / causal-blocked impls)
# ---------------------------------------------------------------------------
#
# Query heads are laid out as (R groups, K kv-heads): flat head h = r*K + k.
# Slicing or masking the first ``a_heads`` heads then keeps every kv head
# with an equal number of groups, so GQA stays well formed for every width.

def attention_init(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
                   *, qkv_bias: bool = False, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "q": dense_init(ks[0], d_model, n_heads * d_head, bias=qkv_bias, dtype=dtype),
        "k": dense_init(ks[1], d_model, n_kv * d_head, bias=qkv_bias, dtype=dtype),
        "v": dense_init(ks[2], d_model, n_kv * d_head, bias=qkv_bias, dtype=dtype),
        "o": dense_init(ks[3], n_heads * d_head, d_model, bias=qkv_bias, dtype=dtype),
    }


def _split_heads(x, n, d_head):
    return x.reshape(x.shape[:-1] + (n, d_head))


def _attn_core(q, k, v, *, causal: bool, q_offset, scale: float,
               kv_len=None) -> jax.Array:
    """q: (B,S,R,K,D); k,v: (B,T,K,D) -> (B,S,R,K,D). fp32 softmax."""
    scores = jnp.einsum("bsrkd,btkd->brkst", q, k).astype(jnp.float32) * scale
    T = k.shape[1]
    tpos = jnp.arange(T)
    neg = jnp.finfo(jnp.float32).min
    if causal:
        qpos = jnp.arange(q.shape[1]) + q_offset
        scores = jnp.where(qpos[:, None] >= tpos[None, :], scores, neg)
    if kv_len is not None:  # decode: only the first kv_len cache slots valid
        scores = jnp.where(tpos[None, :] < kv_len, scores, neg)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("brkst,btkd->bsrkd", w, v)


def _attn_blocked(q, k, v, *, causal: bool, scale: float, block_q: int,
                  block_kv: int, exact_causal: bool) -> jax.Array:
    """Memory-efficient online-softmax attention (pure XLA flash pattern).

    ``exact_causal=True`` unrolls query blocks and truncates each one's KV
    extent, so HLO FLOPs match the causal optimum (~2x saving vs the masked
    scan).  This is the XLA fallback; the Pallas kernel is the TPU fast path.
    """
    B, S, R, K, D = q.shape
    T = k.shape[1]
    nq, nkv = S // block_q, T // block_kv
    assert S % block_q == 0 and T % block_kv == 0

    def q_block(qi, qb):
        # qb: (B, bq, R, K, D); iterate kv blocks with running max/denominator.
        if exact_causal and causal:
            hi = qi + 1  # static python int — kv extent truncated per q block
        else:
            hi = nkv
        ks_ = k[:, : hi * block_kv].reshape(B, hi, block_kv, K, D)
        vs_ = v[:, : hi * block_kv].reshape(B, hi, block_kv, K, D)

        def inner(carry, inp):
            m_prev, l_prev, acc = carry
            kj, vj, j = inp
            s = jnp.einsum("bsrkd,btkd->brkst", qb, kj).astype(jnp.float32) * scale
            if causal:
                qpos = qi * block_q + jnp.arange(block_q)
                tpos = j * block_kv + jnp.arange(block_kv)
                s = jnp.where(qpos[:, None] >= tpos[None, :], s,
                              jnp.finfo(jnp.float32).min)
            m = jnp.maximum(m_prev, s.max(-1))
            p = jnp.exp(s - m[..., None])
            corr = jnp.exp(m_prev - m)
            l = l_prev * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "brkst,btkd->brksd", p.astype(qb.dtype), vj).astype(jnp.float32)
            return (m, l, acc), None

        m0 = jnp.full((B, R, K, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, R, K, block_q), jnp.float32)
        a0 = jnp.zeros((B, R, K, block_q, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            lambda c, i: inner(c, i), (m0, l0, a0),
            (ks_.swapaxes(0, 1), vs_.swapaxes(0, 1), jnp.arange(hi)))
        out = acc / l[..., None]
        return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,bq,R,K,D)

    if exact_causal and causal:
        outs = [q_block(i, q[:, i * block_q:(i + 1) * block_q]) for i in range(nq)]
        return jnp.concatenate(outs, axis=1)
    qb = q.reshape(B, nq, block_q, R, K, D).swapaxes(0, 1)
    # scan over q blocks (masked-causal variant)
    def scan_q(_, inp):
        qi, qblk = inp
        return None, _q_block_masked(qi, qblk, k, v, causal, scale, block_q,
                                     block_kv)
    _, outs = jax.lax.scan(scan_q, None, (jnp.arange(nq), qb))
    return outs.swapaxes(0, 1).reshape(B, S, R, K, D)


def _q_block_masked(qi, qb, k, v, causal, scale, block_q, block_kv):
    """One query block over ALL kv blocks with masking (qi may be traced)."""
    B, bq, R, K, D = qb.shape
    T = k.shape[1]
    nkv = T // block_kv
    ks_ = k.reshape(B, nkv, block_kv, K, D).swapaxes(0, 1)
    vs_ = v.reshape(B, nkv, block_kv, K, D).swapaxes(0, 1)

    def inner(carry, inp):
        m_prev, l_prev, acc = carry
        kj, vj, j = inp
        s = jnp.einsum("bsrkd,btkd->brkst", qb, kj).astype(jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jnp.arange(bq)
            tpos = j * block_kv + jnp.arange(block_kv)
            s = jnp.where(qpos[:, None] >= tpos[None, :], s,
                          jnp.finfo(jnp.float32).min)
        m = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m[..., None])
        corr = jnp.exp(m_prev - m)
        l = l_prev * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "brkst,btkd->brksd", p.astype(qb.dtype), vj).astype(jnp.float32)
        return (m, l, acc), None

    m0 = jnp.full((B, R, K, bq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, R, K, bq), jnp.float32)
    a0 = jnp.zeros((B, R, K, bq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0), (ks_, vs_, jnp.arange(nkv)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).astype(qb.dtype)


def attention_apply(p: dict, x: jax.Array, *, n_heads: int, n_kv: int,
                    d_head: int, causal: bool = True,
                    positions: Optional[jax.Array] = None,
                    rope_theta: Optional[float] = 10000.0,
                    a_model=None, a_heads=None,
                    kv_cache: Optional[dict] = None,
                    impl: str = "ref", block_q: int = 512,
                    block_kv: int = 512, return_kv: bool = False,
                    decode_impl: str = "xla", mesh=None) -> tuple:
    """Returns (out (B,S,d_model_active), new_kv_cache | None).

    kv_cache: {"k": (B,T,K,D), "v": (B,T,K,D), "len": scalar int32} — decode
    appends the new token at position ``len`` and attends to len+1 entries.
    """
    B, S, _ = x.shape
    H = n_heads
    mha = n_kv == n_heads
    # --- static head slicing -------------------------------------------------
    # MHA: kv heads shrink together with query heads.  GQA/MQA: kv heads stay
    # (they are cheap); query groups per kv head shrink, so active heads must
    # be a multiple of n_kv.
    sliced_heads = None
    kv_active = n_kv
    if a_heads is not None and is_static(a_heads):
        sliced_heads = int(a_heads)
        H = sliced_heads
        if mha:
            kv_active = sliced_heads
        else:
            assert sliced_heads % n_kv == 0, \
                "active heads must keep GQA groups even"
    R = H // kv_active

    q = dense_apply(p["q"], x, a_in=a_model, a_out=(None if sliced_heads is None
                                                    else sliced_heads * d_head))
    a_kv = None if (sliced_heads is None or not mha) else kv_active * d_head
    k = dense_apply(p["k"], x, a_in=a_model, a_out=a_kv)
    v = dense_apply(p["v"], x, a_in=a_model, a_out=a_kv)
    q = _split_heads(q, H, d_head).reshape(B, S, R, kv_active, d_head)
    k = _split_heads(k, kv_active, d_head)
    v = _split_heads(v, kv_active, d_head)

    if positions is None:
        if kv_cache is not None:
            positions = kv_cache["len"] + jnp.arange(S)[None, :]
        else:
            positions = jnp.arange(S)[None, :]
    if rope_theta is not None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)

    scale = 1.0 / math.sqrt(d_head)
    new_cache = None
    if return_kv:
        new_cache = {"k": k, "v": v, "len": jnp.asarray(S, jnp.int32)}
    if kv_cache is not None:
        idx = kv_cache["len"]
        if decode_impl == "sharded" and mesh is not None \
                and "model" in mesh.axis_names:
            # two-pass softmax over the sequence-sharded cache (§Perf).
            # axis choice mirrors launch.steps cache specs: big batches
            # shard seq over 'model' only, tiny batches over every axis.
            from repro.distributed.decode_attn import sharded_decode_attention
            seq_axes = (("model",) if B >= 16
                        else ("pod", "data", "model"))
            out, ck, cv = sharded_decode_attention(
                q, k, v, kv_cache["k"], kv_cache["v"], idx, mesh=mesh,
                seq_axes=seq_axes)
            new_cache = {"k": ck, "v": cv, "len": idx + S}
        else:
            # decode: write k/v at position len, attend over the whole cache
            ck = jax.lax.dynamic_update_slice(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), (0, idx, 0, 0))
            new_cache = {"k": ck, "v": cv, "len": idx + S}
            out = _attn_core(q, ck.astype(q.dtype), cv.astype(q.dtype),
                             causal=False, q_offset=idx, scale=scale,
                             kv_len=idx + S)
    elif impl == "ref" or S <= block_q:
        out = _attn_core(q, k, v, causal=causal, q_offset=0, scale=scale)
    else:
        out = _attn_blocked(q, k, v, causal=causal, scale=scale,
                            block_q=block_q, block_kv=block_kv,
                            exact_causal=(impl == "blocked_causal"))

    # --- masked-mode head gating (inactive heads must contribute zeros) ------
    if a_heads is not None and not is_static(a_heads):
        hm = active_mask(a_heads, n_heads, out.dtype).reshape(R, n_kv)
        out = out * hm[None, None, :, :, None]
    out = out.reshape(B, S, H * d_head)
    a_in_o = None if sliced_heads is None else sliced_heads * d_head
    y = dense_apply(p["o"], out, a_in=a_in_o, a_out=a_model)
    return y, new_cache


# ---------------------------------------------------------------------------
# Convolutions (NHWC) + switchable batch norm (slimmable-nets trick)
# ---------------------------------------------------------------------------

def conv_init(key, ksize: int, c_in: int, c_out: int, *, groups: int = 1,
              bias: bool = False, dtype=jnp.float32) -> dict:
    fan_in = ksize * ksize * c_in // groups
    w = jax.random.normal(key, (ksize, ksize, c_in // groups, c_out), dtype)
    p = {"kernel": w * (1.0 / math.sqrt(fan_in))}
    if bias:
        p["bias"] = jnp.zeros((c_out,), dtype)
    return p


def conv_apply(p: dict, x: jax.Array, *, stride: int = 1, groups: int = 1,
               a_in=None, a_out=None, a_kernel: Optional[int] = None,
               padding: str = "SAME") -> jax.Array:
    """NHWC conv with elastic channels and (static) elastic kernel size.

    Elastic kernel = OFA-style centre crop of the full kernel.  Depthwise
    convs pass groups == c_in; elastic channels then slice/mask both sides
    in lockstep (a_in == a_out).
    """
    w, b = p["kernel"], p.get("bias")
    kh = w.shape[0]
    if a_kernel is not None and a_kernel < kh:
        off = (kh - a_kernel) // 2
        w = w[off:off + a_kernel, off:off + a_kernel]
    depthwise = groups > 1
    if not depthwise and a_in is None and x.shape[-1] < w.shape[2]:
        a_in = x.shape[-1]   # auto-slice: input already narrowed upstream
    if a_in is not None and is_static(a_in):
        if not depthwise:
            w = take_dim(w, a_in, 2)
    if a_out is not None and is_static(a_out):
        w = take_dim(w, a_out, 3)
        if b is not None:
            b = take_dim(b, a_out, 0)
        if depthwise:
            groups = int(a_out)
    y = jax.lax.conv_general_dilated(
        x, _cast(w, x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    if b is not None:
        y = y + _cast(b, x.dtype)
    if a_out is not None and not is_static(a_out):
        y = mask_dim(y, a_out, -1)
    return y


def groupnorm_init(c: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def groupnorm_apply(p: dict, x: jax.Array, *, groups: int = 32,
                    eps: float = 1e-5) -> jax.Array:
    """x: (..., C) normalised per group over (spatial..., C/groups)."""
    c = x.shape[-1]
    g = min(groups, c)
    while c % g:
        g -= 1
    lead = x.shape[:1]
    xg = x.reshape(lead + (-1, g, c // g))
    mean = jnp.mean(xg, axis=(1, 3), keepdims=True)
    var = jnp.mean(jnp.square(xg - mean), axis=(1, 3), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    y = xg.reshape(x.shape)
    return y * _cast(p["scale"], x.dtype) + _cast(p["bias"], x.dtype)


def sbn_init(c: int, n_settings: int = 1, dtype=jnp.float32) -> dict:
    """Switchable BatchNorm: independent affine+stats per width setting."""
    return {
        "scale": jnp.ones((n_settings, c), dtype),
        "bias": jnp.zeros((n_settings, c), dtype),
        "mean": jnp.zeros((n_settings, c), dtype),
        "var": jnp.ones((n_settings, c), dtype),
    }


def sbn_apply(p: dict, x: jax.Array, *, setting: int = 0, train: bool = False,
              a=None, eps: float = 1e-5, momentum: float = 0.9):
    """Returns (y, new_stats | None).  ``setting`` indexes the width option."""
    scale, bias = p["scale"][setting], p["bias"][setting]
    if a is not None and is_static(a):
        scale, bias = take_dim(scale, a, 0), take_dim(bias, a, 0)
    if train:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x, axes)
        var = jnp.mean(jnp.square(x), axes) - jnp.square(mean)
        new_stats = (mean, var)
    else:
        mean, var = p["mean"][setting], p["var"][setting]
        if a is not None and is_static(a):
            mean, var = take_dim(mean, a, 0), take_dim(var, a, 0)
        new_stats = None
    y = (x - _cast(mean, x.dtype)) * jax.lax.rsqrt(_cast(var, x.dtype) + eps)
    y = y * _cast(scale, x.dtype) + _cast(bias, x.dtype)
    if a is not None and not is_static(a):
        y = mask_dim(y, a, -1)
    return y, new_stats
