"""Pareto frontier construction over (latency, accuracy, energy).

The runtime manager deploys only Pareto-optimal (sub-network x hw-state)
points — the paper's "pre-selected sub-networks with different
latency-accuracy trade-offs".
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence


@dataclasses.dataclass(frozen=True)
class OpPoint:
    """One operating point: a sub-network under a hardware state."""
    subnet: object            # SubnetSpec
    hw_state: object          # HwState
    latency_ms: float
    energy_mj: float
    accuracy: float

    def dominates(self, other: "OpPoint") -> bool:
        no_worse = (self.latency_ms <= other.latency_ms
                    and self.energy_mj <= other.energy_mj
                    and self.accuracy >= other.accuracy)
        better = (self.latency_ms < other.latency_ms
                  or self.energy_mj < other.energy_mj
                  or self.accuracy > other.accuracy)
        return no_worse and better


def pareto_front(points: Sequence[OpPoint]) -> List[OpPoint]:
    """O(n^2) non-dominated filter (tables are small: |subnets| x |hw|)."""
    front = [p for p in points
             if not any(q.dominates(p) for q in points if q is not p)]
    return sorted(front, key=lambda p: p.latency_ms)


def accuracy_latency_front(points: Sequence[OpPoint]) -> List[OpPoint]:
    """2-D (latency, accuracy) frontier — the paper's Fig.-style curve."""
    best: List[OpPoint] = []
    for p in sorted(points, key=lambda p: (p.latency_ms, -p.accuracy)):
        if not best or p.accuracy > best[-1].accuracy:
            best.append(p)
    return best
