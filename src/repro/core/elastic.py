"""Mask/slice duality for elastic layers.

Two execution modes implement the paper's dynamic DNN:

* masked mode (training) — active sizes are traced scalars; inactive
  channels are exact zeros.  One executable covers every sub-network, so
  the sandwich rule costs a single compile.
* sliced mode (serving) — active sizes are Python ints; parameters are
  sliced at trace time so compute genuinely shrinks (the runtime governor
  switches between per-subnet cached executables).

The invariant that makes both modes agree exactly: every activation tensor
carries zeros beyond its active channel count, and normalisation layers
compute statistics over active channels only.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Active, is_static


def active_mask(a: "jax.Array | int", size: int, dtype=jnp.float32) -> jax.Array:
    """[size] vector: 1.0 for channels < a, else 0.0."""
    return (jnp.arange(size) < a).astype(dtype)


def mask_dim(x: jax.Array, a: Active, axis: int = -1) -> jax.Array:
    """Zero channels >= a along ``axis`` (no-op for None / full static)."""
    if a is None:
        return x
    size = x.shape[axis]
    if is_static(a) and int(a) == size:
        return x
    m = active_mask(a, size, x.dtype)
    shape = [1] * x.ndim
    shape[axis] = size
    return x * m.reshape(shape)


def take_dim(p: jax.Array, a: Active, axis: int) -> jax.Array:
    """STATIC slice of a parameter along ``axis`` to the first ``a`` rows."""
    if a is None:
        return p
    assert is_static(a), "take_dim needs a static active size"
    a = int(a)
    if a == p.shape[axis]:
        return p
    idx = [slice(None)] * p.ndim
    idx[axis] = slice(0, a)
    return p[tuple(idx)]


def resolve(a: Active, full: int) -> "jax.Array | int":
    """Concrete active count (static int or traced scalar)."""
    if a is None:
        return full
    return a


def count_or_none(a: Active, full: int):
    """None if the dim is full/static-full, else the active count."""
    if a is None or (is_static(a) and int(a) == full):
        return None
    return a


# ---------------------------------------------------------------------------
# Sandwich-rule sampling (Yu et al., Slimmable Networks; used by OFA-style
# progressive shrinking).  Host-side sampling keeps the step function static;
# the sampled widths enter the jitted step as *traced* scalars (masked mode).
# ---------------------------------------------------------------------------

def sandwich_specs(space, rng: np.random.Generator, n_random: int = 2):
    """[max, min, n_random x random] — the sandwich rule batch of subnets."""
    out = [space.max_spec(), space.min_spec()]
    for _ in range(n_random):
        out.append(space.sample(rng))
    return out


def spec_to_dynamic(spec, dims: dict) -> dict:
    """Turn a SubnetSpec into traced active counts for masked-mode apply.

    ``dims`` maps knob name -> full size, e.g. {"d_model": 768, "d_ff": 3072,
    "n_heads": 12, "n_layers": 12}.  Returns int32 scalars (device arrays) so
    a single executable handles any spec.
    """
    out = {}
    if "d_model" in dims:
        out["a_model"] = jnp.asarray(
            _round(dims["d_model"], spec.width_mult), jnp.int32)
    if "d_ff" in dims:
        out["a_ff"] = jnp.asarray(_round(dims["d_ff"], spec.ffn_mult), jnp.int32)
    if "n_heads" in dims:
        out["a_heads"] = jnp.asarray(
            _round(dims["n_heads"], spec.heads_mult), jnp.int32)
    if "n_layers" in dims:
        out["a_layers"] = jnp.asarray(
            _round(dims["n_layers"], spec.depth_mult), jnp.int32)
    if "n_experts" in dims and spec.num_experts is not None:
        out["a_experts"] = jnp.asarray(spec.num_experts, jnp.int32)
    return out


def _round(full: int, mult: float) -> int:
    return max(1, int(round(full * mult)))


def spec_to_static(spec, dims: dict, multiple_of: int = 1) -> dict:
    """SubnetSpec -> STATIC active counts (python ints) for sliced mode.

    Like :func:`spec_to_dynamic` but returns hashable ints, so the result
    selects a specialised executable (the serving engine's compile cache).
    ``multiple_of`` keeps sliced dims divisible by the tensor sharding.
    """
    def rnd(full, mult):
        n = max(multiple_of, int(round(full * mult / multiple_of))
                * multiple_of)
        return min(n, full)

    out = {}
    if "d_model" in dims:
        out["a_model"] = rnd(dims["d_model"], spec.width_mult)
    if "d_ff" in dims:
        out["a_ff"] = rnd(dims["d_ff"], spec.ffn_mult)
    if "n_heads" in dims:
        n_kv = dims.get("n_kv_heads", dims["n_heads"])
        h = max(1, int(round(dims["n_heads"] * spec.heads_mult)))
        if dims["n_heads"] % n_kv == 0 and n_kv < dims["n_heads"]:
            h = max(n_kv, (h // n_kv) * n_kv)     # keep GQA groups even
        out["a_heads"] = min(h, dims["n_heads"])
    if "n_layers" in dims:
        out["a_layers"] = _round(dims["n_layers"], spec.depth_mult)
    if "n_experts" in dims and spec.num_experts is not None:
        out["a_experts"] = int(spec.num_experts)
    if spec.top_k is not None:
        out["top_k"] = int(spec.top_k)
    return out
