"""Core of the paper's contribution: elastic (dynamic) networks.

Sub-network description (SubnetSpec / ElasticSpace), the masked/sliced
execution duality, sandwich-rule training utilities, in-place distillation
and Pareto-front construction used by the runtime resource manager.
"""
from repro.core.types import SubnetSpec, ElasticSpace, FULL, round_channels
from repro.core.elastic import (active_mask, mask_dim, take_dim,
                                sandwich_specs, spec_to_dynamic)
