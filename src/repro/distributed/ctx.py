"""Ambient mesh context so model code can hint shardings without
hard-coding a mesh (single-device tests run with no mesh at all).

Also the version-compat home for ``shard_map``: the top-level
``jax.shard_map`` (and its ``check_vma`` kwarg) only exist on newer JAX;
the 0.4.37 floor has ``jax.experimental.shard_map.shard_map`` with
``check_rep`` instead.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable shard_map (``check_vma`` maps to old ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)

_MESH: contextvars.ContextVar = contextvars.ContextVar("repro_mesh", default=None)


def current_mesh():
    return _MESH.get()


@contextlib.contextmanager
def use_mesh(mesh):
    """Set the ambient mesh for model sharding hints AND jax's context."""
    token = _MESH.set(mesh)
    try:
        with mesh:   # jax.sharding.Mesh is a context manager
            yield mesh
    finally:
        _MESH.reset(token)


def batch_axes() -> Tuple[str, ...]:
    """Mesh axes that shard the batch (every non-'model' axis)."""
    mesh = current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a != "model")


def wsc(x, *spec) -> jax.Array:
    """with_sharding_constraint if a mesh is ambient, else identity.

    Axis names not present in the current mesh are dropped from the spec,
    so model code can always hint P(("pod","data"), None, "model").
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    cleaned = P(*[keep(e) for e in spec])
    return jax.lax.with_sharding_constraint(x, cleaned)
