"""Ambient mesh context so model code can hint shardings without
hard-coding a mesh (single-device tests run with no mesh at all).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_MESH: contextvars.ContextVar = contextvars.ContextVar("repro_mesh", default=None)


def current_mesh():
    return _MESH.get()


@contextlib.contextmanager
def use_mesh(mesh):
    """Set the ambient mesh for model sharding hints AND jax's context."""
    token = _MESH.set(mesh)
    try:
        with mesh:   # jax.sharding.Mesh is a context manager
            yield mesh
    finally:
        _MESH.reset(token)


def batch_axes() -> Tuple[str, ...]:
    """Mesh axes that shard the batch (every non-'model' axis)."""
    mesh = current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a != "model")


def wsc(x, *spec) -> jax.Array:
    """with_sharding_constraint if a mesh is ambient, else identity.

    Axis names not present in the current mesh are dropped from the spec,
    so model code can always hint P(("pod","data"), None, "model").
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    cleaned = P(*[keep(e) for e in spec])
    return jax.lax.with_sharding_constraint(x, cleaned)
