"""Distribution substrate: mesh context, sharding rules, collectives."""
from repro.distributed.ctx import (current_mesh, shard_map, use_mesh, wsc,
                                   batch_axes)
