"""Fault tolerance: watchdog, straggler detection, restart driver.

At thousand-node scale the failure model is: chips/hosts vanish (preempt,
ECC, fabric), steps stall (network), or hosts slow down (thermal).  The
SPMD program itself cannot survive a member loss — recovery is
checkpoint/restart, possibly on a SMALLER mesh (elastic restore).  This
module provides the pieces the launcher composes:

* :class:`Watchdog` — heartbeat thread; a stalled step (> timeout) fires a
  callback (in production: abort the job so the scheduler reschedules it —
  here: raise in the main thread via a flag).
* :class:`StragglerMonitor` — per-step wall-time tracker; flags hosts/steps
  slower than k x rolling median.  On TPU SPMD a straggler host slows every
  step globally, so mitigation = flag + (at the fleet level) replace the
  host and restart from the last checkpoint; the monitor provides the
  detection signal and records it.
* :func:`run_with_restarts` — supervisor loop: run the train function,
  catch failures (incl. injected :class:`SimulatedFailure`), restore from
  the latest checkpoint and continue, up to ``max_restarts``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Optional


class SimulatedFailure(RuntimeError):
    """Injected by tests/examples to exercise the restart path."""


class Watchdog:
    """Re-armable heartbeat: firing ``on_stall`` does NOT kill the
    watchdog thread — a later :meth:`beat` (the job recovered, e.g. a
    restart supervisor got it moving again) clears ``stalled`` and arms
    the next stall, so one watchdog covers a whole run-with-restarts
    lifetime instead of only the first incident."""

    def __init__(self, timeout_s: float = 300.0,
                 on_stall: Optional[Callable[[], None]] = None):
        self.timeout_s = timeout_s
        self.on_stall = on_stall
        self._last_beat = time.monotonic()
        self._stop = threading.Event()
        self.stalled = False
        self.stall_count = 0
        self._thread = threading.Thread(target=self._loop, daemon=True)

    def start(self):
        self._last_beat = time.monotonic()
        self._thread.start()
        return self

    def beat(self):
        self._last_beat = time.monotonic()
        self.stalled = False   # recovery re-arms the next stall

    def stop(self):
        self._stop.set()

    def _loop(self):
        fired_for: Optional[float] = None
        while not self._stop.wait(min(self.timeout_s / 4, 5.0)):
            if time.monotonic() - self._last_beat > self.timeout_s:
                if fired_for == self._last_beat:
                    continue   # already fired for this stall; wait for beat
                fired_for = self._last_beat
                self.stalled = True
                self.stall_count += 1
                if self.on_stall:
                    self.on_stall()


class StragglerMonitor:
    def __init__(self, window: int = 50, threshold: float = 2.0,
                 log_cap: int = 1024):
        self.times = deque(maxlen=window)
        self.threshold = threshold
        # bounded flag log (capped deque + dropped counter): a chronic
        # straggler over a week-long job must not grow memory unbounded
        self.flags = deque(maxlen=log_cap)
        self.flags_dropped = 0

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        import statistics
        is_straggler = False
        if len(self.times) >= 10:
            med = statistics.median(self.times)
            if seconds > self.threshold * med:
                is_straggler = True
                if len(self.flags) == self.flags.maxlen:
                    self.flags_dropped += 1   # deque evicts the oldest
                self.flags.append({"step": step, "seconds": seconds,
                                   "median": med})
        self.times.append(seconds)
        return is_straggler


def run_with_restarts(train_fn, *, manager, max_restarts: int = 3,
                      logger=print):
    """Supervisor: ``train_fn(start_step, restored_state|None) -> state``.

    On failure, restores the latest checkpoint and re-invokes train_fn.
    Returns (final_state, n_restarts).
    """
    restarts = 0
    while True:
        start_step, state = 0, None
        latest = manager.latest_step()
        if latest is not None:
            start_step, state = manager.restore_latest()
            start_step += 1
            logger(f"[fault] resuming from checkpoint step {start_step - 1}")
        try:
            return train_fn(start_step, state), restarts
        except (SimulatedFailure, OSError, RuntimeError) as e:
            restarts += 1
            logger(f"[fault] failure at restart {restarts}: {e!r}")
            if hasattr(manager, "wait"):
                # drain in-flight async saves before restore
                manager.wait()  # repro: allow-wait(checkpoint drain joins a finite set of in-flight saves, not an Event)
            if restarts > max_restarts:
                raise
