"""Path-pattern sharding rules -> PartitionSpec per parameter.

t5x-style logical rules, implemented as predicates over the parameter path
string and shape.  ``fsdp`` axes additionally shard the largest
non-model dim of big parameters (ZeRO-3 semantics under GSPMD: per-layer
all-gathers inside the scan).

Specs may name axes ("pod") missing from a given mesh; ``clean_spec``
drops them so one rule set serves single- and multi-pod meshes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def clean_spec(spec: P, mesh) -> P:
    names = set(mesh.axis_names)

    def keep(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in names)
            return kept if kept else None
        return e if e in names else None

    return P(*[keep(e) for e in spec])


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _stacked(path_s: str) -> bool:
    """Stacked-layer params (leading L dim from vmap init / scan)."""
    return "layers/" in path_s and "exit_heads" not in path_s


def lm_rules(path_s: str, shape: Tuple[int, ...], fsdp) -> P:
    base = None
    if path_s.endswith("embed/embedding"):
        return P("model", fsdp)
    if path_s.endswith("lm_head/kernel"):
        return P(fsdp, "model")
    if "/attn/" in path_s or "/attn1/" in path_s:
        if path_s.endswith("o/kernel"):
            base = P("model", fsdp)
        elif path_s.endswith("kernel"):
            base = P(fsdp, "model")
        elif path_s.endswith("o/bias"):
            base = P(None)
        elif path_s.endswith("bias"):
            base = P("model")
    elif "/moe/" in path_s:
        if "router" in path_s:
            base = P(None, None)
        elif "/shared/" in path_s:
            if path_s.endswith("wo/kernel"):
                base = P("model", fsdp)
            elif path_s.endswith("kernel"):
                base = P(fsdp, "model")
            else:
                base = P("model")
        elif path_s.endswith("wo"):
            base = P("model", None, fsdp)
        elif path_s.endswith("wi") or path_s.endswith("wg"):
            base = P("model", fsdp, None)
    elif "/mlp/" in path_s:
        if path_s.endswith("wo/kernel"):
            base = P("model", fsdp)
        elif path_s.endswith("kernel"):
            base = P(fsdp, "model")
        elif path_s.endswith("wo/bias"):
            base = P(None)
        elif path_s.endswith("bias"):
            base = P("model")
    if base is None:
        base = P(*([None] * len(shape)))
        if _stacked(path_s):
            return base
        return base
    if _stacked(path_s):
        return P(None, *base)
    return base


def vision_rules(path_s: str, shape: Tuple[int, ...], fsdp) -> P:
    # transformer-style leaves reuse the LM rules
    if any(t in path_s for t in ("/attn/", "/attn1/", "/mlp/", "embed/")):
        return lm_rules(path_s, shape, fsdp)
    if any(path_s.endswith(s) for s in ("q2/kernel", "kv2/kernel")):
        spec = P(None, "model")
    elif path_s.endswith("o2/kernel"):
        spec = P("model", None)
    elif path_s.endswith("ada/kernel"):
        spec = P(None, "model")
    elif "conv" in path_s or "patch_embed" in path_s or "/dw/" in path_s \
            or any(t in path_s for t in ("expand/", "project/", "stem/",
                                         "head/", "down/", "up/", "skip/",
                                         "proj/", "se_")):
        if len(shape) == 4 and shape[-1] >= 256:
            spec = P(None, None, None, "model")
        else:
            spec = P(*([None] * len(shape)))
    elif path_s.endswith("fc/kernel") and shape[0] >= 1024:
        spec = P("model", None)
    else:
        spec = P(*([None] * len(shape)))
    if _stacked(path_s) and len(spec) == len(shape) - 1:
        return P(None, *spec)
    if len(spec) != len(shape):
        spec = P(*([None] * len(shape)))
    return spec


def param_specs(shapes_tree, family: str, *, fsdp_axes=("pod", "data"),
                fsdp_min_size: int = 1 << 22):
    """pytree of PartitionSpec matching ``shapes_tree`` (of SDS/arrays)."""
    fsdp = tuple(fsdp_axes) if fsdp_axes else None

    def one(path, leaf):
        path_s = _path_str(path)
        shape = tuple(leaf.shape)
        rules = lm_rules if family == "lm" else vision_rules
        spec = rules(path_s, shape, fsdp)
        if len(spec) != len(shape):
            spec = P(*([None] * len(shape)))
        # drop fsdp sharding for small params (all-gather latency not worth it)
        if fsdp and int(np.prod(shape)) < fsdp_min_size:
            spec = P(*[None if e == fsdp or e == tuple(fsdp) else e
                       for e in spec])
        # drop axes a dim can't divide evenly (max shards: 16 per single
        # axis, 32 for the ("pod","data") fsdp pair on the multi-pod mesh)
        def fits(dim, entry):
            if entry is None:
                return True
            req = 32 if isinstance(entry, (tuple, list)) else 16
            return dim % req == 0
        spec = P(*[e if fits(shape[i], e) else None
                   for i, e in enumerate(spec)])
        return spec

    return jax.tree_util.tree_map_with_path(one, shapes_tree)


def opt_specs_like(param_specs_tree, opt_state_shapes, params_shapes):
    """Derive optimizer-state PartitionSpecs from the param specs.

    Elementwise states inherit the param spec; adafactor's factored moments
    drop the corresponding trailing dim of the spec.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params_shapes)
    spec_flat = treedef.flatten_up_to(param_specs_tree)
    state_flat = treedef.flatten_up_to(opt_state_shapes["s"])

    out = []
    for p, spec, st in zip(leaves, spec_flat, state_flat):
        d = {}
        for k, v in st.items():
            if v.shape == p.shape:
                d[k] = spec
            elif k == "vr":                      # p.shape[:-1]
                d[k] = P(*spec[:-1])
            elif k == "vc":                      # p.shape[:-2] + last
                d[k] = P(*(tuple(spec[:-2]) + (spec[-1],)))
            else:
                d[k] = P(*([None] * v.ndim))
        out.append(d)
    return {"s": jax.tree_util.tree_unflatten(treedef, out)}


def to_named(specs_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, clean_spec(s, mesh)), specs_tree,
        is_leaf=lambda x: isinstance(x, P))
