"""Distributed decode attention: two-pass softmax over a sequence-sharded
KV cache (shard_map + pmax/psum).

Baseline finding (§Perf): with the 32k KV cache sequence-sharded over the
``model`` axis, GSPMD lowers one-token decode attention by ALL-GATHERING
the cache (granite-20b: 5 GB/step/device; qwen: 0.55 s collective term).
The classic fix is to keep the cache in place and reduce softmax
statistics instead:

  pass 1: local scores + local max  -> pmax  (B,R,K floats)
  pass 2: local exp-sums + local PV -> psum  (B,R,K + B,R,K,D floats)

Collective bytes drop from O(T·K·D) to O(R·K·D) per token — about four
orders of magnitude for 32k contexts.  The cache update (dynamic-update-
slice at the decode index) also becomes fully local: only the shard owning
the write position updates.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.ctx import shard_map


def sharded_decode_attention(q, k_new, v_new, cache_k, cache_v, cache_len, *,
                             mesh, seq_axes=("model",),
                             batch_axes: Tuple[str, ...] = ("pod", "data")):
    """One decode step against a sequence-sharded cache.

    q:       (B, 1, R, K, D)  new-token queries (RoPE applied), replicated
                              over ``seq_axes``
    k_new:   (B, 1, K, D)     new key/value (RoPE applied)
    cache_k: (B, T, K, D)     T sharded over ``seq_axes`` (one or several
                              mesh axes, row-major)
    cache_len: int32 scalar   write position (new token lands here)

    Returns (out (B,1,R,K,D), new_cache_k, new_cache_v).
    """
    D = q.shape[-1]
    scale = 1.0 / math.sqrt(D)
    seq_axes = tuple(a for a in seq_axes if a in mesh.axis_names)
    b_axes = tuple(a for a in batch_axes
                   if a in mesh.axis_names and a not in seq_axes)
    bspec = b_axes if b_axes else None

    def body(q, kn, vn, ck, cv, clen):
        T_loc = ck.shape[1]
        shard = jnp.zeros((), jnp.int32)
        for a in seq_axes:                       # row-major flat shard index
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        start = shard * T_loc
        # --- local cache write (no cross-shard traffic) -------------------
        idx = clen - start
        in_range = jnp.logical_and(idx >= 0, idx < T_loc)
        safe = jnp.clip(idx, 0, T_loc - 1)
        kn_w = jnp.where(in_range, kn.astype(ck.dtype),
                         jax.lax.dynamic_slice(ck, (0, safe, 0, 0),
                                               kn.shape))
        vn_w = jnp.where(in_range, vn.astype(cv.dtype),
                         jax.lax.dynamic_slice(cv, (0, safe, 0, 0),
                                               vn.shape))
        ck = jax.lax.dynamic_update_slice(ck, kn_w, (0, safe, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, vn_w, (0, safe, 0, 0))
        # --- two-pass softmax ---------------------------------------------
        q0 = q[:, 0].astype(jnp.float32)                    # (B,R,K,D)
        s = jnp.einsum("brkd,btkd->brkt", q0,
                       ck.astype(jnp.float32)) * scale       # (B,R,K,T_loc)
        pos = start + jnp.arange(T_loc)
        s = jnp.where(pos <= clen, s, jnp.finfo(jnp.float32).min)
        m_loc = jnp.max(s, axis=-1)
        m_g = jax.lax.pmax(m_loc, seq_axes)                  # pass 1
        p = jnp.exp(s - m_g[..., None])
        l_loc = jnp.sum(p, axis=-1)
        pv_loc = jnp.einsum("brkt,btkd->brkd", p,
                            cv.astype(jnp.float32))
        l_g = jax.lax.psum(l_loc, seq_axes)                  # pass 2
        pv_g = jax.lax.psum(pv_loc, seq_axes)
        out = (pv_g / jnp.maximum(l_g[..., None], 1e-30))[:, None]
        return out.astype(q.dtype), ck, cv

    cache_spec = P(bspec, seq_axes, None, None)
    rep4 = P(bspec, None, None, None)
    out, ck, cv = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None, None, None), rep4, rep4,
                  cache_spec, cache_spec, P()),
        out_specs=(P(bspec, None, None, None, None), cache_spec, cache_spec),
        check_vma=False,
    )(q, k_new, v_new, cache_k, cache_v, cache_len)
    return out, ck, cv
