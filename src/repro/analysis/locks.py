"""Dynamic lock-order deadlock detector.

Wraps ``threading.Lock``/``threading.RLock`` in tracking proxies that
record, per thread, which locks are already held whenever another is
acquired.  Each (held → acquired) pair becomes an edge in a global
lock-order graph keyed by the lock's *allocation site* (module:line),
so every instance of e.g. ``DynamicServer._acct_lock`` collapses to one
node.  A cycle in that graph means two code paths acquire the same two
lock classes in opposite orders — a potential deadlock — and is
reported with a representative acquisition stack for each direction.

Two ways in:

* explicit — ``mon = LockMonitor(); lk = mon.lock("my-lock")`` (used by
  the tests to build deliberate inversions);
* monkeypatch — ``install()`` swaps ``threading.Lock``/``RLock`` for
  factories that return tracked locks *only when the allocating frame
  is a ``repro.*`` module*, so stdlib internals (queue, Event,
  Condition) keep their native locks.  ``pytest --lock-check`` (see
  ``tests/conftest.py``) installs this for the whole tier-1 suite and
  asserts an acyclic graph at session end.

The monitor also flags **locks held across device dispatch**: the
engine's ``_dispatch`` calls the module-level ``_DISPATCH_NOTE`` hook
(when set) right before handing a batch to the executable; holding any
control-plane lock at that point serializes the control plane behind
device latency.

Canonical project lock order (outermost first) — documented here and in
the owning modules, enforced by this detector under tier-1:

    Cluster._admin_lock  >  Cluster._lock  >  ResourceArbiter._lock
        >  DynamicServer locks (_cache_lock/_acct_lock/_wake_lock/_pad_lock)
        >  Tracer/MetricsRegistry/TraceStreamer internal locks
"""
from __future__ import annotations

import threading
import traceback
from typing import Dict, List, Optional, Tuple

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock

# Keep a few frames of context; full stacks are noise in reports.
_STACK_DEPTH = 12


def _grab_stack() -> List[str]:
    frames = traceback.extract_stack()[:-3]  # drop monitor internals
    frames = frames[-_STACK_DEPTH:]
    return [f"{f.filename}:{f.lineno} in {f.name}" for f in frames]


class _Edge:
    """First-seen evidence that `src` was held while `dst` was acquired."""

    __slots__ = ("src", "dst", "thread", "stack")

    def __init__(self, src: str, dst: str, thread: str, stack: List[str]):
        self.src = src
        self.dst = dst
        self.thread = thread
        self.stack = stack


class LockMonitor:
    """Global acquisition-order graph shared by all tracked locks."""

    def __init__(self):
        self._mu = _REAL_LOCK()
        self._edges: Dict[Tuple[str, str], _Edge] = {}
        self._tls = threading.local()
        self.dispatch_violations: List[Tuple[str, Tuple[str, ...], List[str]]] = []

    # -- per-thread held stack ----------------------------------------------

    def _held(self) -> List[List]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = []
            self._tls.held = held
        return held

    def held_keys(self) -> Tuple[str, ...]:
        return tuple(entry[0] for entry in self._held())

    def on_acquire(self, key: str, obj: object) -> None:
        held = self._held()
        for entry in held:
            if entry[1] is obj:  # re-entrant acquire of the same instance
                entry[2] += 1
                return
        new_edges = []
        for src_key, _obj, _n in held:
            if src_key == key:
                continue  # two instances of one class: order not comparable
            if (src_key, key) not in self._edges:
                new_edges.append(src_key)
        if new_edges:
            stack = _grab_stack()
            tname = threading.current_thread().name
            with self._mu:
                for src_key in new_edges:
                    self._edges.setdefault(
                        (src_key, key), _Edge(src_key, key, tname, stack))
        held.append([key, obj, 1])

    def on_release(self, key: str, obj: object) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i][1] is obj:
                held[i][2] -= 1
                if held[i][2] == 0:
                    del held[i]
                return

    # -- device-dispatch hook -----------------------------------------------

    def note_dispatch(self) -> None:
        """Install as ``repro.runtime.engine._DISPATCH_NOTE`` to flag
        control-plane locks held while a batch is handed to the device."""
        held = self.held_keys()
        if held:
            with self._mu:
                self.dispatch_violations.append(
                    (threading.current_thread().name, held, _grab_stack()))

    # -- graph queries -------------------------------------------------------

    def edges(self) -> List[Tuple[str, str]]:
        with self._mu:
            return sorted(self._edges.keys())

    def cycles(self) -> List[List[str]]:
        """Elementary cycles in the order graph (each as [a, b, ..., a])."""
        with self._mu:
            adj: Dict[str, List[str]] = {}
            for (src, dst) in self._edges:
                adj.setdefault(src, []).append(dst)
        for outs in adj.values():
            outs.sort()
        cycles: List[List[str]] = []
        seen_sigs = set()
        # Iterative DFS from every node; the graphs here are tiny (tens of
        # lock classes), so elementary-cycle enumeration by path DFS is fine.
        for start in sorted(adj):
            stack = [(start, [start])]
            while stack:
                node, path = stack.pop()
                for nxt in adj.get(node, ()):  # pragma: no branch
                    if nxt == start and len(path) > 1:
                        sig = frozenset(path)
                        if sig not in seen_sigs:
                            seen_sigs.add(sig)
                            cycles.append(path + [start])
                    elif nxt not in path and nxt > start:
                        # only explore nodes > start: each cycle found once,
                        # rooted at its smallest node
                        stack.append((nxt, path + [nxt]))
        return cycles

    def report(self) -> str:
        cycles = self.cycles()
        lines: List[str] = []
        if not cycles and not self.dispatch_violations:
            return "lock-order: OK ({} edge(s), no cycles)".format(
                len(self.edges()))
        for cyc in cycles:
            lines.append("POTENTIAL DEADLOCK: " + " -> ".join(cyc))
            with self._mu:
                for a, b in zip(cyc, cyc[1:]):
                    edge = self._edges.get((a, b))
                    if edge is None:
                        continue
                    lines.append(f"  {a} held while acquiring {b} "
                                 f"[thread {edge.thread}]")
                    lines.extend(f"    {frm}" for frm in edge.stack[-6:])
        for tname, held, stack in self.dispatch_violations:
            lines.append(
                f"LOCK HELD ACROSS DEVICE DISPATCH [thread {tname}]: "
                + ", ".join(held))
            lines.extend(f"    {frm}" for frm in stack[-6:])
        return "\n".join(lines)

    def clear(self) -> None:
        with self._mu:
            self._edges.clear()
            self.dispatch_violations.clear()

    # -- explicit construction ----------------------------------------------

    def lock(self, name: str):
        return TrackedLock(_REAL_LOCK(), name, self)

    def rlock(self, name: str):
        return TrackedLock(_REAL_RLOCK(), name, self)


class TrackedLock:
    """Proxy around a real Lock/RLock that reports to a LockMonitor."""

    def __init__(self, real, key: str, monitor: LockMonitor):
        self._real = real
        self._key = key
        self._mon = monitor

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._real.acquire(blocking, timeout)
        if got:
            self._mon.on_acquire(self._key, self)
        return got

    def release(self) -> None:
        self._mon.on_release(self._key, self)
        self._real.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._real.locked() if hasattr(self._real, "locked") else False

    def _is_owned(self) -> bool:
        """Owned by the current thread (guards + Condition support)."""
        if hasattr(self._real, "_is_owned"):
            return self._real._is_owned()
        held = self._mon._held()
        return any(entry[1] is self for entry in held)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<TrackedLock {self._key} real={self._real!r}>"

    def __getattr__(self, name):
        return getattr(self._real, name)


# ---------------------------------------------------------------------------
# Monkeypatch mode

_MONITOR: Optional[LockMonitor] = None


def get_monitor() -> Optional[LockMonitor]:
    return _MONITOR


def _make_factory(real_factory, monitor: LockMonitor, prefix: str):
    import sys

    def factory(*args, **kwargs):
        real = real_factory(*args, **kwargs)
        try:
            frame = sys._getframe(1)
            mod = frame.f_globals.get("__name__", "")
            lineno = frame.f_lineno
        except Exception:  # pragma: no cover - _getframe always works on CPython
            return real
        if mod.startswith(prefix) and not mod.startswith("repro.analysis"):
            return TrackedLock(real, f"{mod}:{lineno}", monitor)
        return real

    return factory


def install(monitor: Optional[LockMonitor] = None,
            module_prefix: str = "repro") -> LockMonitor:
    """Swap threading.Lock/RLock for tracking factories (repro.* only).

    Returns the active monitor.  Idempotent; pair with :func:`uninstall`.
    """
    global _MONITOR
    if _MONITOR is not None:
        return _MONITOR
    _MONITOR = monitor or LockMonitor()
    threading.Lock = _make_factory(_REAL_LOCK, _MONITOR, module_prefix)
    threading.RLock = _make_factory(_REAL_RLOCK, _MONITOR, module_prefix)
    return _MONITOR


def uninstall() -> Optional[LockMonitor]:
    """Restore the real lock factories; returns the monitor for inspection."""
    global _MONITOR
    threading.Lock = _REAL_LOCK
    threading.RLock = _REAL_RLOCK
    mon, _MONITOR = _MONITOR, None
    return mon
