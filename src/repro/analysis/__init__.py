"""Static + dynamic invariant enforcement for the concurrent control plane.

The paper's runtime manager "monitors dynamically changing performance
targets ... and tunes the algorithm and hardware at the same time" — a
concurrent control plane whose correctness rests on a handful of
invariants this repo had, until now, only enforced by review: virtual
time must flow through injected clocks, logs must be bounded, randomness
must be seeded, span emitters must match the PR-7 schema, worker threads
must be daemonized and wake-able, and shared state must be touched under
its owning lock.  Each of those has been violated and hand-fixed at
least once (unbounded ``switch_log``, arrival double-smoothing,
unbounded router decision log); this package makes the fixes permanent:

* :mod:`repro.analysis.lint` — an AST lint pass over ``src/repro`` with
  project rules RT001–RT006 (see ``RULES``); run via
  ``python -m repro.analysis --lint`` and gated in CI;
* :mod:`repro.analysis.locks` — a dynamic lock-order detector:
  instrumented ``Lock``/``RLock`` wrappers (opt-in monkeypatch mode, so
  existing code needs no edits) record per-thread acquisition order
  into a global graph and report cycles — potential deadlocks — with
  both acquisition stacks.  ``pytest --lock-check`` runs the whole
  tier-1 suite as the deadlock corpus;
* :mod:`repro.analysis.guards` — ``guarded_by`` declarations on hot
  shared state (engine accounting, arbiter tenant tables, frontend
  placement maps) that assert the owning lock is held on access when
  ``REPRO_GUARDS=1`` and compile to zero-overhead no-ops otherwise.

Runtime invariants (the rules, with rationale) are documented in
ROADMAP.md under "Runtime invariants".
"""
from repro.analysis.guards import (GuardViolation, disable_guards,
                                   enable_guards, guarded_by,
                                   guards_enabled)
from repro.analysis.lint import (RULES, Finding, format_findings,
                                 lint_file, lint_tree)
from repro.analysis.locks import (LockMonitor, get_monitor, install,
                                  uninstall)

__all__ = [
    "RULES", "Finding", "lint_file", "lint_tree", "format_findings",
    "LockMonitor", "get_monitor", "install", "uninstall",
    "guarded_by", "enable_guards", "disable_guards", "guards_enabled",
    "GuardViolation",
]
