"""``guarded_by`` declarations: assert the owning lock is held on access.

Usage::

    @guarded_by("_acct_lock", "_outstanding", "_arrivals")
    class DynamicServer:
        ...

declares that ``self._outstanding``/``self._arrivals`` may only be read
or written while ``self._acct_lock`` is held.  The declaration is free
by default: it only appends to a registry.  When guards are enabled —
``REPRO_GUARDS=1`` in the environment at import time, or
:func:`enable_guards` at runtime — each declared field gets a data
descriptor that checks lock ownership on every access and raises
:class:`GuardViolation` with the offending field, lock and thread.
:func:`disable_guards` removes the descriptors again; values live in
the instance ``__dict__`` under their real names throughout, so
toggling mid-process hands them off seamlessly (the overhead benchmark
measures the same process with guards on and off).

Two deliberate allowances keep the checks sound without contorting
``__init__`` bodies:

* if the lock attribute does not exist yet, access is allowed —
  construction order (fields before locks) is not a data race;
* the *first binding* of a field (name not yet in the instance dict) is
  allowed — ``__init__`` assigns initial values before any other
  thread can see the object.

Ownership is checked via the lock's ``_is_owned()`` when present
(RLock, tracked locks); plain ``Lock`` falls back to ``locked()``,
which cannot attribute ownership to a thread but still catches
lock-free access.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Tuple, Type

ENV_VAR = "REPRO_GUARDS"


class GuardViolation(AssertionError):
    """A guarded attribute was touched without its owning lock held."""


def _env_enabled() -> bool:
    return os.environ.get(ENV_VAR, "0").lower() not in ("", "0", "false", "off")


_REGISTRY: List[Tuple[Type, str, Tuple[str, ...]]] = []
_enabled = False


class _GuardedField:
    """Data descriptor storing the value under its real name in the
    instance dict, so installing/removing the descriptor never moves
    data around."""

    __slots__ = ("name", "lock_attr", "owner_name")

    def __init__(self, name: str, lock_attr: str, owner_name: str):
        self.name = name
        self.lock_attr = lock_attr
        self.owner_name = owner_name

    def _check(self, inst, verb: str) -> None:
        lock = inst.__dict__.get(self.lock_attr)
        if lock is None:
            lock = getattr(inst, self.lock_attr, None)
        if lock is None:
            return  # construction: the lock doesn't exist yet
        owned = None
        is_owned = getattr(lock, "_is_owned", None)
        if callable(is_owned):
            try:
                owned = bool(is_owned())
            except Exception:
                owned = None
        if owned is None:
            locked = getattr(lock, "locked", None)
            owned = bool(locked()) if callable(locked) else True
        if not owned:
            raise GuardViolation(
                f"{self.owner_name}.{self.name} {verb} without holding "
                f"{self.lock_attr} (thread {threading.current_thread().name})")

    def __get__(self, inst, owner=None):
        if inst is None:
            return self
        try:
            value = inst.__dict__[self.name]
        except KeyError:
            raise AttributeError(self.name) from None
        self._check(inst, "read")
        return value

    def __set__(self, inst, value) -> None:
        if self.name in inst.__dict__:
            self._check(inst, "written")
        inst.__dict__[self.name] = value

    def __delete__(self, inst) -> None:
        self._check(inst, "deleted")
        try:
            del inst.__dict__[self.name]
        except KeyError:
            raise AttributeError(self.name) from None


def guarded_by(lock_attr: str, *fields: str):
    """Class decorator declaring ``fields`` guarded by ``self.<lock_attr>``."""

    def deco(cls):
        spec = (cls, lock_attr, tuple(fields))
        _REGISTRY.append(spec)
        if _enabled:
            _install_spec(spec)
        return cls

    return deco


def _install_spec(spec) -> None:
    cls, lock_attr, fields = spec
    for name in fields:
        current = cls.__dict__.get(name)
        if isinstance(current, _GuardedField):
            continue
        setattr(cls, name, _GuardedField(name, lock_attr, cls.__name__))


def _remove_spec(spec) -> None:
    cls, _lock_attr, fields = spec
    for name in fields:
        if isinstance(cls.__dict__.get(name), _GuardedField):
            delattr(cls, name)


def enable_guards() -> None:
    """Install guard descriptors for every registered declaration."""
    global _enabled
    _enabled = True
    for spec in _REGISTRY:
        _install_spec(spec)


def disable_guards() -> None:
    """Remove all guard descriptors; classes revert to plain attributes."""
    global _enabled
    _enabled = False
    for spec in _REGISTRY:
        _remove_spec(spec)


def guards_enabled() -> bool:
    return _enabled


def registered() -> Dict[str, Dict[str, Tuple[str, ...]]]:
    """{class-name: {lock: fields}} — introspection for tests/CLI."""
    out: Dict[str, Dict[str, Tuple[str, ...]]] = {}
    for cls, lock_attr, fields in _REGISTRY:
        out.setdefault(cls.__name__, {})[lock_attr] = fields
    return out


if _env_enabled():
    _enabled = True
