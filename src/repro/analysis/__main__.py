"""CLI: ``python -m repro.analysis [--lint] [--root DIR] [--json OUT]``.

Exit status 0 on a clean tree, 1 if any finding survives.  This is the
command CI's ``analysis`` job gates on.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import guards
from repro.analysis.lint import RULES, format_findings, lint_tree


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Project invariant enforcement (RT001-RT006).")
    ap.add_argument("--lint", action="store_true",
                    help="run the AST lint pass (default action)")
    ap.add_argument("--root", default=None,
                    help="tree to lint (default: the installed repro package)")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="also write findings as JSON")
    ap.add_argument("--rules", action="store_true",
                    help="list the rules and exit")
    ap.add_argument("--guards", action="store_true",
                    help="list registered guarded-by declarations and exit")
    args = ap.parse_args(argv)

    if args.rules:
        for rid in sorted(RULES):
            print(f"{rid}  {RULES[rid]}")
        return 0
    if args.guards:
        import repro.runtime.engine  # noqa: F401 - populate the registry
        import repro.cluster.frontend  # noqa: F401
        for cls, locks in sorted(guards.registered().items()):
            for lock, fields in sorted(locks.items()):
                print(f"{cls}: {lock} guards {', '.join(fields)}")
        return 0

    # default action: lint
    findings = lint_tree(args.root)
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump([f.__dict__ for f in findings], fh, indent=2)
    if findings:
        print(format_findings(findings))
        return 1
    print("repro.analysis: clean (0 findings)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
