"""AST lint pass over ``src/repro`` enforcing the project's runtime invariants.

Rules (see ROADMAP.md "Runtime invariants" for rationale):

* **RT001 wallclock** — calls to ``time.time``/``time.monotonic``/
  ``datetime.now`` in sim-reachable modules.  Virtual time must flow
  through injected ``time_fn``/clock parameters; a wall-clock read on a
  sim path silently breaks determinism and replay.  Live-only modules
  (``launch/``, ``chaos/live.py``, ``distributed/fault.py``) are
  allowlisted; other sites need ``# repro: allow-wallclock(<reason>)``.
  ``time.perf_counter`` is *not* flagged: it is the live-path duration
  idiom and never doubles as a timestamp.  Bare references (e.g. the
  ``time_fn=time.monotonic`` injection default) are not calls and are
  allowed — injection is exactly the sanctioned pattern.
* **RT002 unbounded** — ``deque()`` without ``maxlen`` and append-only
  log lists (``self.<x>_log = []`` in ``__init__``).  Every long-lived
  log in this repo is bounded (``log_cap`` + dropped counters); work
  queues that are drained each tick carry
  ``# repro: allow-unbounded(<reason>)``.
* **RT003 unseeded** — ``random.*`` / ``np.random.*`` global-state calls.
  Determinism is the substrate of every benchmark compare gate; all
  randomness goes through seeded ``random.Random(seed)`` /
  ``np.random.default_rng(seed)`` instances.  ``jax.random`` key
  threading is exempt (explicitly seeded by construction).
* **RT004 span-schema** — span emission call sites
  (``tracer.decision(KIND, ...)``, ``add_span(tid, KIND, ...)``,
  ``spans=[(KIND, t0, t1, attrs)]``) checked against
  ``repro.obs.trace.SCHEMA``: the kind must exist and the required
  attributes must be present in the literal attrs.  Catches
  schema drift at lint time instead of in a Perfetto viewer.
* **RT005 thread-hygiene** — ``Thread(...)`` without an explicit
  ``daemon`` flag, ``.wait()`` with no timeout inside a loop, and bare
  ``except:``.  A non-daemon thread wedges interpreter exit; an
  untimed wait in a loop is unkillable unless every setter is audited
  (sites that *are* audited carry ``# repro: allow-wait(<reason>)``).
* **RT006 guarded-by** — attributes annotated ``# guarded-by: _lock``
  that are written in a method body which never enters a
  ``with self._lock`` block.  The static shadow of
  :mod:`repro.analysis.guards`; catches the common case without
  running anything.

Suppression: ``# repro: allow-<alias>(<reason>)`` on the offending
line.  A pragma with an empty reason, or one that suppresses nothing,
is itself a finding (RT000) — stale pragmas rot.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import Dict, List, Optional, Sequence, Set, Tuple

# ---------------------------------------------------------------------------
# Rule registry

RULES: Dict[str, str] = {
    "RT000": "pragma hygiene: malformed/unused allow-pragma or empty reason",
    "RT001": "wall-clock call in sim-reachable module (inject time_fn instead)",
    "RT002": "unbounded growth: deque() without maxlen / append-only log list",
    "RT003": "unseeded randomness: random.*/np.random.* global-state call",
    "RT004": "span emission does not match repro.obs.trace.SCHEMA",
    "RT005": "thread hygiene: non-daemon Thread / untimed wait in loop / bare except",
    "RT006": "guarded-by attribute written without entering its lock",
}

# pragma alias -> rule it suppresses
PRAGMA_ALIASES: Dict[str, str] = {
    "wallclock": "RT001",
    "unbounded": "RT002",
    "unseeded": "RT003",
    "span": "RT004",
    "thread": "RT005",
    "wait": "RT005",
    "guard": "RT006",
}

# Modules (paths relative to the lint root, '/'-separated) that are
# live-only by construction: they exist to touch the real clock.
WALLCLOCK_ALLOWLIST: Tuple[str, ...] = (
    "launch/",
    "chaos/live.py",
    "distributed/fault.py",
)

_WALLCLOCK_TIME_ATTRS = {"time", "monotonic", "time_ns", "monotonic_ns"}
_WALLCLOCK_DT_ATTRS = {"now", "utcnow", "today"}
_LOG_NAME_RE = re.compile(r"(^|_)(log|logs|history|events)($|_)")
_PRAGMA_RE = re.compile(r"#\s*repro:\s*allow-([a-z0-9_-]+)\s*(?:\(([^)]*)\))?")
_GUARDED_BY_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")

# Seeded constructors that make the *result* deterministic; calling these
# is the sanctioned way to obtain randomness.
_RANDOM_MODULE_OK = {"Random", "SystemRandom", "getstate", "setstate", "seed"}
_NP_RANDOM_OK = {"Generator", "RandomState", "default_rng", "SeedSequence",
                 "PCG64", "Philox"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def format_findings(findings: Sequence[Finding]) -> str:
    lines = [f.render() for f in findings]
    lines.append(f"{len(findings)} finding(s)")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Comment scanning (pragmas + guarded-by annotations)


def _scan_comments(source: str):
    """Return (pragmas, guarded) keyed by line number.

    pragmas: line -> list of (alias, reason, used-flag-list)
    guarded: line -> lock attribute name
    """
    pragmas: Dict[int, List[List]] = {}
    guarded: Dict[int, str] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            line = tok.start[0]
            for m in _PRAGMA_RE.finditer(tok.string):
                alias, reason = m.group(1), (m.group(2) or "").strip()
                pragmas.setdefault(line, []).append([alias, reason, False])
            m = _GUARDED_BY_RE.search(tok.string)
            if m:
                guarded[line] = m.group(1)
    except tokenize.TokenError:
        pass
    return pragmas, guarded


# ---------------------------------------------------------------------------
# Span schema resolution helpers


def _load_schema():
    """SCHEMA and {CONSTANT_NAME: span_name} from repro.obs.trace."""
    try:
        from repro.obs import trace as _trace
    except Exception:  # pragma: no cover - lint must run without jax etc.
        return {}, {}
    schema = dict(getattr(_trace, "SCHEMA", {}))
    consts = {
        name: val
        for name, val in vars(_trace).items()
        if name.isupper() and isinstance(val, str)
    }
    return schema, consts


def _resolve_kind(node: ast.AST, consts: Dict[str, str]) -> Optional[str]:
    """Span-kind expression -> span name string, or None if unresolvable."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.Attribute) and node.attr in consts:
        return consts[node.attr]
    if isinstance(node, ast.Name) and node.id in consts:
        return consts[node.id]
    return None


def _dict_literal_keys(node: ast.AST) -> Optional[Set[str]]:
    if isinstance(node, ast.Dict):
        keys = set()
        for k in node.keys:
            if k is None:  # **spread: can't see inside
                return None
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                keys.add(k.value)
            else:
                return None
        return keys
    if isinstance(node, ast.Constant) and node.value is None:
        return set()
    return None


# ---------------------------------------------------------------------------
# The per-file visitor


class _FileLint(ast.NodeVisitor):
    def __init__(self, rel: str, source: str, schema, consts,
                 wallclock_allowed: bool):
        self.rel = rel
        self.schema = schema
        self.consts = consts
        self.wallclock_allowed = wallclock_allowed
        self.findings: List[Finding] = []
        self.pragmas, self.guarded_comments = _scan_comments(source)

        # import aliases
        self.time_names: Set[str] = set()
        self.datetime_mod_names: Set[str] = set()
        self.datetime_cls_names: Set[str] = set()
        self.random_names: Set[str] = set()
        self.from_random_fns: Set[str] = set()
        self.np_names: Set[str] = set()
        self.jax_names: Set[str] = set()
        self.threading_names: Set[str] = set()
        self.thread_cls_names: Set[str] = set()
        self.deque_names: Set[str] = set()
        self.collections_names: Set[str] = set()

        # structural state
        self._loop_depth = 0
        self._class_stack: List[str] = []
        self._func_stack: List[ast.AST] = []
        self._local_dicts: List[Dict[str, Set[str]]] = []
        # class name -> {attr: lock} collected from __init__ comments
        self._guarded_attrs: Dict[str, Dict[str, str]] = {}

    # -- reporting ----------------------------------------------------------

    def _emit(self, rule: str, line: int, message: str) -> None:
        for entry in self.pragmas.get(line, ()):
            alias, _reason, _ = entry
            if PRAGMA_ALIASES.get(alias) == rule:
                entry[2] = True  # mark used
                return
        self.findings.append(Finding(rule, self.rel, line, message))

    def finish(self) -> List[Finding]:
        # RT000: every pragma must be used and carry a reason.
        for line, entries in sorted(self.pragmas.items()):
            for alias, reason, used in entries:
                if alias not in PRAGMA_ALIASES:
                    self.findings.append(Finding(
                        "RT000", self.rel, line,
                        f"unknown pragma alias 'allow-{alias}' "
                        f"(known: {', '.join(sorted(PRAGMA_ALIASES))})"))
                    continue
                if not used:
                    self.findings.append(Finding(
                        "RT000", self.rel, line,
                        f"pragma 'allow-{alias}' suppresses nothing on this "
                        "line — remove it"))
                    continue
                if not reason:
                    self.findings.append(Finding(
                        "RT000", self.rel, line,
                        f"pragma 'allow-{alias}' needs a reason: "
                        f"# repro: allow-{alias}(<why this is safe>)"))
        self.findings.sort(key=lambda f: (f.line, f.rule))
        return self.findings

    # -- imports ------------------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            name = a.asname or a.name.split(".")[0]
            if a.name == "time" or a.name.startswith("time."):
                self.time_names.add(name)
            elif a.name == "datetime":
                self.datetime_mod_names.add(name)
            elif a.name == "random":
                self.random_names.add(name)
            elif a.name in ("numpy", "numpy.random"):
                self.np_names.add(name)
            elif a.name == "jax" or a.name.startswith("jax."):
                self.jax_names.add(name)
            elif a.name == "threading":
                self.threading_names.add(name)
            elif a.name == "collections":
                self.collections_names.add(name)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        for a in node.names:
            name = a.asname or a.name
            if mod == "datetime" and a.name == "datetime":
                self.datetime_cls_names.add(name)
            elif mod == "time":
                if a.name in _WALLCLOCK_TIME_ATTRS:
                    self.time_names.add(name)  # flagged as bare-call below
            elif mod == "random":
                self.from_random_fns.add(name)
            elif mod == "threading" and a.name == "Thread":
                self.thread_cls_names.add(name)
            elif mod == "collections" and a.name == "deque":
                self.deque_names.add(name)
            elif mod in ("numpy", "numpy.random") and a.name == "random":
                self.np_names.add(name)
        self.generic_visit(node)

    # -- structure tracking --------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node.name)
        self._collect_guarded(node)
        self.generic_visit(node)
        cls = self._class_stack.pop()
        self._check_guarded_writes(node, self._guarded_attrs.get(cls, {}))

    def _visit_func(self, node) -> None:
        self._func_stack.append(node)
        self._local_dicts.append({})
        self.generic_visit(node)
        self._local_dicts.pop()
        self._func_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def _visit_loop(self, node) -> None:
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_While = _visit_loop
    visit_For = _visit_loop

    def visit_ExceptHandler(self, node: ast.ExceptHandler) -> None:
        if node.type is None:
            self._emit("RT005", node.lineno,
                       "bare 'except:' swallows KeyboardInterrupt/SystemExit "
                       "— catch Exception at most")
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        # track `name = {...literal...}` for RT004 attrs resolution
        if (self._local_dicts and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            keys = _dict_literal_keys(node.value)
            scope = self._local_dicts[-1]
            if keys is not None:
                scope[node.targets[0].id] = keys
            else:
                scope.pop(node.targets[0].id, None)
        self._check_unbounded_log_list(node)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            shim = ast.Assign(targets=[node.target], value=node.value)
            ast.copy_location(shim, node)
            self._check_unbounded_log_list(shim)
        self.generic_visit(node)

    # -- RT002: unbounded log lists -----------------------------------------

    def _check_unbounded_log_list(self, node: ast.Assign) -> None:
        if not (self._class_stack and self._func_stack):
            return
        fn = self._func_stack[-1]
        if getattr(fn, "name", "") != "__init__":
            return
        for tgt in node.targets:
            if (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"
                    and isinstance(node.value, ast.List)
                    and not node.value.elts
                    and _LOG_NAME_RE.search(tgt.attr)):
                self._emit("RT002", node.lineno,
                           f"append-only log list 'self.{tgt.attr} = []' — "
                           "use collections.deque(maxlen=...) with a dropped "
                           "counter")

    # -- guarded-by (RT006) --------------------------------------------------

    def _collect_guarded(self, cls: ast.ClassDef) -> None:
        attrs: Dict[str, str] = {}
        for item in cls.body:
            if not (isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and item.name == "__init__"):
                continue
            for stmt in ast.walk(item):
                if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                    continue
                lock = self.guarded_comments.get(stmt.lineno)
                if not lock:
                    continue
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                for tgt in targets:
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        attrs[tgt.attr] = lock
        if attrs:
            self._guarded_attrs[cls.name] = attrs

    @staticmethod
    def _written_attrs(fn: ast.AST) -> Dict[str, int]:
        """self-attributes stored to in fn body -> first write line."""
        out: Dict[str, int] = {}

        def note(attr_node: ast.AST) -> None:
            tgt = attr_node
            # unwrap subscript stores: self.x[k] = v
            while isinstance(tgt, ast.Subscript):
                tgt = tgt.value
            if (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"):
                out.setdefault(tgt.attr, tgt.lineno)

        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    note(t)
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                note(stmt.target)
            elif isinstance(stmt, ast.Delete):
                for t in stmt.targets:
                    note(t)
        return out

    @staticmethod
    def _locks_entered(fn: ast.AST) -> Set[str]:
        locks: Set[str] = set()
        for stmt in ast.walk(fn):
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    expr = item.context_expr
                    if (isinstance(expr, ast.Attribute)
                            and isinstance(expr.value, ast.Name)
                            and expr.value.id == "self"):
                        locks.add(expr.attr)
        return locks

    def _check_guarded_writes(self, cls: ast.ClassDef,
                              attrs: Dict[str, str]) -> None:
        if not attrs:
            return
        for item in cls.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if item.name == "__init__":
                continue
            writes = self._written_attrs(item)
            entered = self._locks_entered(item)
            for attr, line in sorted(writes.items(), key=lambda kv: kv[1]):
                lock = attrs.get(attr)
                if lock and lock not in entered:
                    self._emit("RT006", line,
                               f"'{cls.name}.{item.name}' writes "
                               f"'self.{attr}' (guarded-by {lock}) without "
                               f"entering 'with self.{lock}'")

    # -- calls: RT001 / RT002-deque / RT003 / RT004 / RT005 ------------------

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute):
            self._check_attr_call(node, fn)
        elif isinstance(fn, ast.Name):
            self._check_name_call(node, fn)
        self.generic_visit(node)

    def _check_attr_call(self, node: ast.Call, fn: ast.Attribute) -> None:
        base = fn.value
        # RT001: time.time() / time.monotonic()
        if (isinstance(base, ast.Name) and base.id in self.time_names
                and fn.attr in _WALLCLOCK_TIME_ATTRS):
            if not self.wallclock_allowed:
                self._emit("RT001", node.lineno,
                           f"'{base.id}.{fn.attr}()' in sim-reachable module "
                           "— inject a time_fn/clock instead")
        # RT001: datetime.now()/utcnow()/today(), incl. datetime.datetime.now()
        if fn.attr in _WALLCLOCK_DT_ATTRS:
            is_dt = (isinstance(base, ast.Name)
                     and base.id in self.datetime_cls_names)
            is_dt = is_dt or (isinstance(base, ast.Attribute)
                              and base.attr == "datetime"
                              and isinstance(base.value, ast.Name)
                              and base.value.id in self.datetime_mod_names)
            if is_dt and not self.wallclock_allowed:
                self._emit("RT001", node.lineno,
                           f"'datetime.{fn.attr}()' in sim-reachable module "
                           "— inject a time_fn/clock instead")
        # RT002: collections.deque() without maxlen
        if (fn.attr == "deque" and isinstance(base, ast.Name)
                and base.id in self.collections_names):
            self._check_deque(node)
        # RT003: random.* / np.random.*
        if isinstance(base, ast.Name) and base.id in self.random_names:
            if fn.attr not in _RANDOM_MODULE_OK:
                self._emit("RT003", node.lineno,
                           f"'{base.id}.{fn.attr}()' uses the global RNG — "
                           "thread a seeded random.Random(seed) instead")
        if (isinstance(base, ast.Attribute) and base.attr == "random"
                and isinstance(base.value, ast.Name)):
            root = base.value.id
            if root in self.np_names and fn.attr not in _NP_RANDOM_OK:
                self._emit("RT003", node.lineno,
                           f"'{root}.random.{fn.attr}()' uses numpy's global "
                           "RNG — use np.random.default_rng(seed)")
            # jax.random.* is exempt: keys are seeded by construction
        # RT004: tracer.decision(KIND, ...) / add_span(tid, KIND, ...)
        if fn.attr in ("decision", "add_span"):
            self._check_span_call(node, fn.attr)
        if fn.attr in ("request", "finish_request"):
            self._check_spans_kwarg(node)
        # RT005: Thread without daemon via threading.Thread(...)
        if (fn.attr == "Thread" and isinstance(base, ast.Name)
                and base.id in self.threading_names):
            self._check_thread(node)
        # RT005: .wait() with no timeout inside a loop
        if (fn.attr == "wait" and self._loop_depth > 0
                and not node.args and not node.keywords):
            self._emit("RT005", node.lineno,
                       "'.wait()' without timeout inside a loop — pass a "
                       "timeout so the loop can observe shutdown")

    def _check_name_call(self, node: ast.Call, fn: ast.Name) -> None:
        if fn.id in self.deque_names:
            self._check_deque(node)
        if fn.id in self.thread_cls_names:
            self._check_thread(node)
        if fn.id in self.from_random_fns:
            self._emit("RT003", node.lineno,
                       f"'{fn.id}()' (from random import ...) uses the "
                       "global RNG — thread a seeded random.Random(seed)")
        if fn.id in self.time_names and fn.id in _WALLCLOCK_TIME_ATTRS:
            if not self.wallclock_allowed:
                self._emit("RT001", node.lineno,
                           f"'{fn.id}()' (from time import ...) in "
                           "sim-reachable module — inject a time_fn/clock")

    def _check_deque(self, node: ast.Call) -> None:
        has_maxlen = any(kw.arg == "maxlen" for kw in node.keywords)
        has_maxlen = has_maxlen or len(node.args) >= 2
        if not has_maxlen:
            self._emit("RT002", node.lineno,
                       "'deque()' without maxlen — bound it or pragma it as "
                       "a drained work queue")

    def _check_thread(self, node: ast.Call) -> None:
        if not any(kw.arg == "daemon" for kw in node.keywords):
            self._emit("RT005", node.lineno,
                       "Thread(...) without explicit daemon= — background "
                       "threads must be daemonized (or deliberately not, "
                       "with a pragma)")

    # -- RT004 helpers -------------------------------------------------------

    def _span_required(self, kind_node: ast.AST, line: int,
                       what: str) -> Optional[Tuple[str, Tuple[str, ...]]]:
        if not self.schema:
            return None
        kind = _resolve_kind(kind_node, self.consts)
        if kind is None:
            return None  # dynamic kind: out of static reach
        if kind not in self.schema:
            self._emit("RT004", line,
                       f"{what} emits unknown span kind '{kind}' — add it to "
                       "repro.obs.trace.SCHEMA first")
            return None
        return kind, tuple(self.schema[kind])

    def _check_span_call(self, node: ast.Call, method: str) -> None:
        kind_idx = 0 if method == "decision" else 1
        if len(node.args) <= kind_idx:
            return
        res = self._span_required(node.args[kind_idx], node.lineno,
                                  f"'{method}()'")
        if res is None:
            return
        kind, required = res
        if any(kw.arg is None for kw in node.keywords):
            return  # **attrs: can't see inside
        present = {kw.arg for kw in node.keywords}
        missing = [a for a in required if a not in present]
        if missing:
            self._emit("RT004", node.lineno,
                       f"'{method}({kind})' missing required attr(s) "
                       f"{missing} per SCHEMA")

    def _check_spans_kwarg(self, node: ast.Call) -> None:
        for kw in node.keywords:
            if kw.arg != "spans" or not isinstance(kw.value, ast.List):
                continue
            for elt in kw.value.elts:
                if not isinstance(elt, ast.Tuple) or len(elt.elts) != 4:
                    continue
                res = self._span_required(elt.elts[0], elt.lineno,
                                          "spans=[...] entry")
                if res is None:
                    continue
                kind, required = res
                if not required:
                    continue
                keys = self._attrs_keys(elt.elts[3])
                if keys is None:
                    continue  # unresolvable attrs expression
                missing = [a for a in required if a not in keys]
                if missing:
                    self._emit("RT004", elt.lineno,
                               f"spans entry '{kind}' missing required "
                               f"attr(s) {missing} per SCHEMA")

    def _attrs_keys(self, node: ast.AST) -> Optional[Set[str]]:
        keys = _dict_literal_keys(node)
        if keys is not None:
            return keys
        if isinstance(node, ast.Name) and self._local_dicts:
            return self._local_dicts[-1].get(node.id)
        return None


# ---------------------------------------------------------------------------
# Entry points


def lint_file(path: str, rel: Optional[str] = None,
              schema_pair=None) -> List[Finding]:
    rel = (rel or os.path.basename(path)).replace(os.sep, "/")
    with open(path, "r", encoding="utf-8") as fh:
        source = fh.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding("RT000", rel, exc.lineno or 0,
                        f"file does not parse: {exc.msg}")]
    if schema_pair is None:
        schema_pair = _load_schema()
    schema, consts = schema_pair
    allowed = any(
        rel == p or (p.endswith("/") and rel.startswith(p))
        for p in WALLCLOCK_ALLOWLIST)
    visitor = _FileLint(rel, source, schema, consts, allowed)
    visitor.visit(tree)
    return visitor.finish()


def default_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_tree(root: Optional[str] = None) -> List[Finding]:
    root = os.path.abspath(root or default_root())
    findings: List[Finding] = []
    schema_pair = _load_schema()
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__", ".git"))
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            if rel.startswith("analysis/"):
                continue  # the toolkit itself names the patterns it hunts
            findings.extend(lint_file(path, rel, schema_pair))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
