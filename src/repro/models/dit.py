"""DiT (Diffusion Transformer, Peebles & Xie) with adaLN-zero conditioning.

Assigned `dit-l2`: patch 2, 24 layers, d_model 1024, 16 heads, over VAE
latents (img_res/8).  Elastic width/depth apply as in ViT; the diffusion-
native latency knob is the sampler step count (see runtime governor).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core.types import ElasticSpace, is_static
from repro.distributed import wsc


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    name: str
    img_res: int = 256
    patch: int = 2
    in_channels: int = 4          # VAE latent channels
    n_layers: int = 24
    d_model: int = 1024
    n_heads: int = 16
    n_classes: int = 1000
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "none"
    elastic: ElasticSpace = ElasticSpace()

    @property
    def latent_res(self) -> int:
        return self.img_res // 8

    @property
    def d_ff(self) -> int:
        return self.d_model * 4

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def timestep_embedding(t: jax.Array, dim: int, max_period: float = 10000.0):
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def _block_init(key, cfg: DiTConfig) -> dict:
    ks = jax.random.split(key, 3)
    d_head = cfg.d_model // cfg.n_heads
    return {
        "ln1": L.layernorm_init(cfg.d_model, cfg.pdtype()),
        "attn": L.attention_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_heads,
                                 d_head, qkv_bias=True, dtype=cfg.pdtype()),
        "ln2": L.layernorm_init(cfg.d_model, cfg.pdtype()),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=False, bias=True,
                          dtype=cfg.pdtype()),
        # adaLN-zero: 6 x d_model modulation from conditioning (zero-init)
        "ada": {"kernel": jnp.zeros((cfg.d_model, 6 * cfg.d_model), cfg.pdtype()),
                "bias": jnp.zeros((6 * cfg.d_model,), cfg.pdtype())},
    }


def dit_init(key, cfg: DiTConfig) -> dict:
    ks = jax.random.split(key, 7)
    np_ = (cfg.latent_res // cfg.patch) ** 2
    params = {
        "patch_embed": L.conv_init(ks[0], cfg.patch, cfg.in_channels,
                                   cfg.d_model, bias=True, dtype=cfg.pdtype()),
        "pos": jax.random.normal(ks[1], (np_, cfg.d_model), cfg.pdtype()) * 0.02,
        "t_mlp1": L.dense_init(ks[2], 256, cfg.d_model, dtype=cfg.pdtype()),
        "t_mlp2": L.dense_init(ks[3], cfg.d_model, cfg.d_model, dtype=cfg.pdtype()),
        "y_embed": L.embedding_init(ks[4], cfg.n_classes + 1, cfg.d_model,
                                    cfg.pdtype()),
        "final_ln": L.layernorm_init(cfg.d_model, cfg.pdtype()),
        "final": L.dense_init(ks[5], cfg.d_model,
                              cfg.patch * cfg.patch * cfg.in_channels * 2,
                              dtype=cfg.pdtype()),
        "final_ada": {"kernel": jnp.zeros((cfg.d_model, 2 * cfg.d_model),
                                          cfg.pdtype()),
                      "bias": jnp.zeros((2 * cfg.d_model,), cfg.pdtype())},
    }
    keys = jax.random.split(ks[6], cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: _block_init(k, cfg))(keys)
    return params


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None]) + shift[:, None]


def dit_apply(params, latents, t, y, cfg: DiTConfig, *, E=None):
    """latents (B,H,W,C), t (B,), y (B,) labels -> noise/var pred (B,H,W,2C)."""
    E = dict(E or {})
    a_model = E.get("a_model")
    a_layers = E.get("a_layers")
    B = latents.shape[0]
    cdt = cfg.cdtype()

    x = L.conv_apply(params["patch_embed"], latents.astype(cdt),
                     stride=cfg.patch, padding="VALID")
    hw = x.shape[1]
    x = x.reshape(B, -1, cfg.d_model) + params["pos"].astype(cdt)[None]

    temb = timestep_embedding(t, 256).astype(cdt)
    c = L.dense_apply(params["t_mlp2"],
                      jax.nn.silu(L.dense_apply(params["t_mlp1"], temb)))
    c = c + L.embedding_apply(params["y_embed"], y, dtype=cdt)
    c = jax.nn.silu(c)

    if a_model is not None:
        if is_static(a_model):
            x, c = x[..., : int(a_model)], c[..., : int(a_model)]
        else:
            from repro.core.elastic import mask_dim
            x, c = mask_dim(x, a_model, -1), mask_dim(c, a_model, -1)
    x = wsc(x, ("pod", "data"), None, None)

    stack = params["layers"]
    if a_layers is not None and is_static(a_layers):
        stack = jax.tree_util.tree_map(lambda p: p[: int(a_layers)], stack)
        a_layers = None

    d_head = cfg.d_model // cfg.n_heads
    am = a_model

    def ada(pp, cc, n_chunks):
        # modulation params: keep full width then slice/mask per chunk
        out = dense_like(pp, cc)
        return jnp.split(out, n_chunks, axis=-1)

    def dense_like(pp, cc):
        w = pp["kernel"]
        if am is not None and is_static(am):
            n_chunks = w.shape[1] // w.shape[0]
            w = w.reshape(w.shape[0], n_chunks, w.shape[0])[: int(am), :, : int(am)]
            w = w.reshape(int(am), n_chunks * int(am))
            b = pp["bias"].reshape(n_chunks, -1)[:, : int(am)].reshape(-1)
            return cc @ w.astype(cc.dtype) + b.astype(cc.dtype)
        y0 = cc @ pp["kernel"].astype(cc.dtype) + pp["bias"].astype(cc.dtype)
        if am is not None:
            from repro.core.elastic import active_mask
            n_chunks = pp["kernel"].shape[1] // pp["kernel"].shape[0]
            m = active_mask(am, cfg.d_model, y0.dtype)
            y0 = y0 * jnp.tile(m, n_chunks)
        return y0

    def body(carry, xs):
        h = carry
        lp, idx = xs
        gate = None
        if a_layers is not None:
            gate = (idx < a_layers).astype(h.dtype)
        mods = ada(lp["ada"], c, 6)
        sh1, sc1, g1, sh2, sc2, g2 = mods
        hn = _modulate(L.layernorm_apply(lp["ln1"], h, a=am), sh1, sc1)
        att, _ = L.attention_apply(lp["attn"], hn, n_heads=cfg.n_heads,
                                   n_kv=cfg.n_heads, d_head=d_head,
                                   causal=False, rope_theta=None,
                                   a_model=am, a_heads=E.get("a_heads"))
        att = att * g1[:, None]
        h = h + (att if gate is None else att * gate)
        hn = _modulate(L.layernorm_apply(lp["ln2"], h, a=am), sh2, sc2)
        ff = L.mlp_apply(lp["mlp"], hn, a_model=am, a_ff=E.get("a_ff"),
                         act="gelu")
        ff = ff * g2[:, None]
        h = h + (ff if gate is None else ff * gate)
        return wsc(h, ("pod", "data"), None, None), None

    fn = body
    if cfg.remat != "none":
        fn = jax.checkpoint(body, prevent_cse=False)
    n = jax.tree_util.tree_leaves(stack)[0].shape[0]
    x, _ = jax.lax.scan(fn, x, (stack, jnp.arange(n)))

    sh, sc = ada(params["final_ada"], c, 2)
    x = _modulate(L.layernorm_apply(params["final_ln"], x, a=am), sh, sc)
    out = L.dense_apply(params["final"], x, a_in=am)
    # unpatchify
    p_, C = cfg.patch, cfg.in_channels * 2
    grid = cfg.latent_res // p_
    out = out.reshape(B, grid, grid, p_, p_, C)
    out = out.transpose(0, 1, 3, 2, 4, 5).reshape(B, grid * p_, grid * p_, C)
    return out
