"""Mixture-of-Experts layer with three dispatch strategies.

* ``einsum`` — GShard/Switch-style one-hot dispatch.  Fully GSPMD-
  partitionable (experts on the ``model`` mesh axis, tokens on ``data``).
  Faithful baseline; its dispatch einsums are O(group_size) more FLOPs than
  the expert matmuls — the roofline analysis exposes this and the ``a2a``
  path removes it.
* ``a2a`` — production path: shard_map with sort-based token permutation
  and explicit ``all_to_all`` over the expert (model) axis, MaxText-style.
* ``dense`` — every expert on every token, combine by gate weight.  Only
  for tiny smoke tests and as the numerics oracle for the other two.

Elastic knobs (the paper's technique extended to MoE): ``a_experts``
restricts routing to the first n experts (masked or sliced), ``top_k`` and
``a_ff`` (per-expert hidden width) shrink compute.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.elastic import active_mask, take_dim
from repro.core.layers import dense_init, mlp_init, mlp_apply
from repro.core.types import is_static
from repro.distributed.ctx import shard_map


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                     # per-expert hidden
    n_shared: int = 0             # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    group_size: int = 256         # einsum dispatch group
    dispatch: str = "einsum"      # einsum | a2a | dense
    expert_axis: str = "model"    # mesh axis experts are sharded over


def moe_init(key, d_model: int, cfg: MoEConfig, *, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    E, f = cfg.n_experts, cfg.d_ff
    s = 1.0 / math.sqrt(d_model)
    p = {
        "router": dense_init(ks[0], d_model, E, bias=False, dtype=jnp.float32),
        "wi": jax.random.normal(ks[1], (E, d_model, f), dtype) * s,
        "wg": jax.random.normal(ks[2], (E, d_model, f), dtype) * s,
        "wo": jax.random.normal(ks[3], (E, f, d_model), dtype) * (1.0 / math.sqrt(f)),
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(ks[4], d_model, cfg.d_ff * cfg.n_shared,
                               gated=True, dtype=dtype)
    return p


def _router(p, x, cfg: MoEConfig, a_experts, top_k: int):
    """probs (..., E) fp32 with inactive experts masked out; top-k indices."""
    logits = (x.astype(jnp.float32) @ p["router"]["kernel"])
    E = cfg.n_experts
    if a_experts is not None:
        if is_static(a_experts) and int(a_experts) == E:
            pass
        else:
            neg = jnp.finfo(jnp.float32).min
            logits = jnp.where(jnp.arange(E) < a_experts, logits, neg)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, top_k)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)
    return probs, top_vals, top_idx


def _aux_loss(probs, top_idx, cfg: MoEConfig):
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    E = cfg.n_experts
    f = jnp.mean(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=tuple(
        range(top_idx.ndim - 1)) + (top_idx.ndim - 1,))
    pbar = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return E * jnp.sum(f * pbar)


def _expert_ffn(p, h, *, a_ff=None, slice_e=None):
    """h: (E, C, d) -> (E, C, d) SwiGLU per expert (einsum over stacked E)."""
    wi, wg, wo = p["wi"], p["wg"], p["wo"]
    if slice_e is not None:
        wi, wg, wo = wi[:slice_e], wg[:slice_e], wo[:slice_e]
    if a_ff is not None and is_static(a_ff):
        wi, wg, wo = wi[..., :a_ff], wg[..., :a_ff], wo[:, :a_ff]
    up = jnp.einsum("ecd,edf->ecf", h, wi.astype(h.dtype))
    gate = jnp.einsum("ecd,edf->ecf", h, wg.astype(h.dtype))
    hid = jax.nn.silu(gate) * up
    if a_ff is not None and not is_static(a_ff):
        hid = hid * active_mask(a_ff, hid.shape[-1], hid.dtype)
    return jnp.einsum("ecf,efd->ecd", hid, wo.astype(h.dtype))


# ---------------------------------------------------------------------------
# dense dispatch (oracle)
# ---------------------------------------------------------------------------

def _moe_dense(p, x, cfg, a_experts, top_k, a_ff):
    B, S, d = x.shape
    probs, top_vals, top_idx = _router(p, x, cfg, a_experts, top_k)
    E = cfg.n_experts
    toks = x.reshape(1, B * S, d).repeat(E, 0).reshape(E, B * S, d)
    outs = _expert_ffn(p, toks, a_ff=a_ff)                      # (E, BS, d)
    comb = jnp.zeros((B * S, E), jnp.float32)
    comb = comb.at[jnp.arange(B * S)[:, None],
                   top_idx.reshape(B * S, -1)].add(top_vals.reshape(B * S, -1))
    y = jnp.einsum("te,etd->td", comb.astype(x.dtype), outs)
    return y.reshape(B, S, d), _aux_loss(probs, top_idx, cfg)


# ---------------------------------------------------------------------------
# GShard einsum dispatch
# ---------------------------------------------------------------------------

def _moe_einsum(p, x, cfg, a_experts, top_k, a_ff, slice_e):
    B, S, d = x.shape
    # group over FLATTENED tokens: decode-style shapes (B x 1) form one
    # group of B tokens instead of B groups of 1, whose per-(group, expert)
    # capacity floor would pad expert compute ~E/top_k times.
    T = B * S
    g = min(cfg.group_size, T)
    while T % g:           # fall back to the largest divisor of T
        g -= 1
    G = T // g
    xg = x.reshape(G, g, d)
    probs, top_vals, top_idx = _router(p, xg, cfg, a_experts, top_k)
    E = cfg.n_experts if slice_e is None else slice_e
    if slice_e is not None:
        top_idx = jnp.minimum(top_idx, E - 1)   # indices already < E by masking
    # capacity always derives from the FULL expert count so that sliced and
    # masked sub-networks drop exactly the same tokens (slice == mask).
    C = max(4, int(math.ceil(g * top_k * cfg.capacity_factor / cfg.n_experts)))

    oh = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)           # (G,g,k,E)
    # position of each slot within its expert, counted over (token, k) slots
    ohf = oh.reshape(G, g * top_k, E)
    pos = (jnp.cumsum(ohf, axis=1) - ohf)                        # slots before
    loc = jnp.sum(pos * ohf, axis=-1).astype(jnp.int32)          # (G, g*k)
    keep = (loc < C).astype(jnp.float32).reshape(G, g, top_k)
    loc_oh = jax.nn.one_hot(loc.reshape(G, g, top_k), C, dtype=jnp.float32)
    gates = top_vals * keep                                      # (G,g,k)
    # combine (G,g,E,C) = sum_k gate_k * onehot_E * onehot_C
    combine = jnp.einsum("ngke,ngkc->ngec", oh * gates[..., None], loc_oh)
    combine = combine.astype(x.dtype)
    dispatch = (combine > 0).astype(x.dtype)
    ein = jnp.einsum("ngd,ngec->encd", xg, dispatch)             # (E,G,C,d)...
    expert_in = ein.reshape(E, G * C, d)
    expert_out = _expert_ffn(p, expert_in, a_ff=a_ff, slice_e=slice_e)
    expert_out = expert_out.reshape(E, G, C, d)
    y = jnp.einsum("ngec,encd->ngd", combine, expert_out)
    return y.reshape(B, S, d), _aux_loss(probs, top_idx, cfg)


# ---------------------------------------------------------------------------
# shard_map all-to-all dispatch (production EP)
# ---------------------------------------------------------------------------

def _moe_a2a_local(p_local, x_local, cfg: MoEConfig, a_experts, top_k, a_ff,
                   axis: str, n_shards: int):
    """Per-device body under shard_map.

    x_local: (T_loc, d) local tokens; p_local expert weights hold the local
    expert block (E_loc, d, f); router weights replicated.
    """
    T, d = x_local.shape
    E = cfg.n_experts
    E_loc = E // n_shards
    probs, top_vals, top_idx = _router(p_local, x_local, cfg, a_experts, top_k)
    # flatten (token, k) slots and sort by destination expert
    flat_e = top_idx.reshape(-1)                                  # (T*k,)
    flat_g = top_vals.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), top_k)
    order = jnp.argsort(flat_e)                                   # stable
    se, sg, st = flat_e[order], flat_g[order], flat_t[order]
    # position within expert after sort
    C = max(4, int(math.ceil(T * top_k * cfg.capacity_factor / E)))
    one = jax.nn.one_hot(se, E, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(one, axis=0) - one)[jnp.arange(se.shape[0]), se]
    keep = pos_in_e < C
    # send buffer (E, C, d); dropped tokens scatter to a scratch row
    send = jnp.zeros((E * C + 1, d), x_local.dtype)
    slot = jnp.where(keep, se * C + pos_in_e, E * C)
    send = send.at[slot].set(x_local[st])
    send = send[:-1].reshape(n_shards, E_loc * C, d)
    recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)       # (n, E_loc*C, d)
    recv = recv.reshape(n_shards, E_loc, C, d).transpose(1, 0, 2, 3) \
               .reshape(E_loc, n_shards * C, d)
    out = _expert_ffn(p_local, recv, a_ff=a_ff)                    # (E_loc, n*C, d)
    back = out.reshape(E_loc, n_shards, C, d).transpose(1, 0, 2, 3) \
              .reshape(n_shards, E_loc * C, d)
    got = jax.lax.all_to_all(back, axis, 0, 0, tiled=False)
    got = got.reshape(E * C, d)
    got = jnp.concatenate([got, jnp.zeros((1, d), got.dtype)], 0)
    slot_out = jnp.where(keep, se * C + pos_in_e, E * C)
    gathered = got[slot_out]                                       # (T*k, d)
    w = jnp.where(keep, sg, 0.0).astype(x_local.dtype)
    y = jnp.zeros((T, d), x_local.dtype).at[st].add(gathered * w[:, None])
    return y, _aux_loss(probs, top_idx, cfg)


def moe_apply(p: dict, x: jax.Array, cfg: MoEConfig, *,
              a_experts=None, top_k: Optional[int] = None, a_ff=None,
              a_model=None, mesh=None, data_axes=("data",)) -> tuple:
    """Returns (y (B,S,d), aux_loss).  Shared experts added on top."""
    top_k = top_k or cfg.top_k
    slice_e = None
    if a_experts is not None and is_static(a_experts) and int(a_experts) < cfg.n_experts:
        slice_e = int(a_experts)

    if cfg.dispatch == "dense":
        y, aux = _moe_dense(p, x, cfg, a_experts, top_k, a_ff)
    elif cfg.dispatch == "einsum" or mesh is None:
        y, aux = _moe_einsum(p, x, cfg, a_experts, top_k, a_ff, slice_e)
    elif cfg.dispatch == "a2a":
        B, S, d = x.shape
        ax = cfg.expert_axis
        n_shards = mesh.shape[ax]
        E = cfg.n_experts
        if S % n_shards:
            # decode-like shapes can't sequence-shard over the expert axis;
            # fall back to the einsum dispatch
            y, aux = _moe_einsum(p, x, cfg, a_experts, top_k, a_ff, slice_e)
            if "shared" in p:
                y = y + mlp_apply(p["shared"], x, a_model=a_model, a_ff=None)
            return y, aux

        def body(pr, pw, pg, po, xl):
            # xl: (B_loc, S/n_shards, d) — tokens split over the expert
            # axis too (sequence parallelism for the MoE block), so each
            # chip dispatches a distinct token slice and experts see their
            # true load instead of n_shards replicas.
            pl = {"router": {"kernel": pr}, "wi": pw, "wg": pg, "wo": po}
            xf = xl.reshape(-1, d)
            y, aux = _moe_a2a_local(pl, xf, cfg, a_experts, top_k, a_ff,
                                    ax, n_shards)
            return y.reshape(xl.shape), jnp.array([[aux]])  # keep shard dims

        batch_spec = P(tuple(data_axes), ax, None)
        y, aux = shard_map(
            body, mesh=mesh,
            in_specs=(P(None, None), P(ax, None, None), P(ax, None, None),
                      P(ax, None, None), batch_spec),
            out_specs=(batch_spec, P(tuple(data_axes), ax)),
            check_vma=False,
        )(p["router"]["kernel"], p["wi"], p["wg"], p["wo"], x)
        aux = jnp.mean(aux)
    else:
        raise ValueError(cfg.dispatch)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, a_model=a_model, a_ff=None)
    return y, aux
