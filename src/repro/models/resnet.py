"""ResNet (bottleneck) with slimmable width via switchable BatchNorm.

Channel scaling follows the slimmable-networks recipe: a discrete set of
width settings, each with its own BN statistics (calibrated post-training).
Depth scaling drops trailing blocks per stage.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core.types import ElasticSpace, round_channels


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    depths: Tuple[int, ...] = (3, 8, 36, 3)
    width: int = 64
    n_classes: int = 1000
    img_res: int = 224
    width_settings: Tuple[float, ...] = (1.0,)   # slimmable widths
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    elastic: ElasticSpace = ElasticSpace()

    def stage_channels(self, i: int) -> int:
        return self.width * (2 ** i) * 4          # bottleneck expansion 4

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def _bottleneck_init(key, c_in, c_mid, c_out, n_set, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "conv1": L.conv_init(ks[0], 1, c_in, c_mid, dtype=dtype),
        "bn1": L.sbn_init(c_mid, n_set, dtype),
        "conv2": L.conv_init(ks[1], 3, c_mid, c_mid, dtype=dtype),
        "bn2": L.sbn_init(c_mid, n_set, dtype),
        "conv3": L.conv_init(ks[2], 1, c_mid, c_out, dtype=dtype),
        "bn3": L.sbn_init(c_out, n_set, dtype),
    }
    if c_in != c_out:
        p["proj"] = L.conv_init(ks[3], 1, c_in, c_out, dtype=dtype)
        p["bn_proj"] = L.sbn_init(c_out, n_set, dtype)
    return p


def resnet_init(key, cfg: ResNetConfig) -> dict:
    n_set = len(cfg.width_settings)
    ks = jax.random.split(key, 3 + len(cfg.depths))
    params = {
        "stem": L.conv_init(ks[0], 7, 3, cfg.width, dtype=cfg.pdtype()),
        "bn_stem": L.sbn_init(cfg.width, n_set, cfg.pdtype()),
        "fc": L.dense_init(ks[1], cfg.stage_channels(len(cfg.depths) - 1),
                           cfg.n_classes, dtype=cfg.pdtype()),
    }
    c_in = cfg.width
    for s, depth in enumerate(cfg.depths):
        c_out = cfg.stage_channels(s)
        c_mid = c_out // 4
        blocks = []
        bkeys = jax.random.split(ks[2 + s], depth)
        for b in range(depth):
            blocks.append(_bottleneck_init(bkeys[b], c_in, c_mid, c_out,
                                           n_set, cfg.pdtype()))
            c_in = c_out
        params[f"stage{s}"] = blocks
    return params


def _bottleneck_apply(p, x, *, stride, setting, train, widths, stats):
    """widths = (a_mid, a_out) active channels (static, from width setting)."""
    a_mid, a_out = widths

    def bn(pp, name, h, a):
        y, st = L.sbn_apply(pp[name], h, setting=setting, train=train, a=a)
        if train and stats is not None:
            stats.append((name, st))
        return y

    h = L.conv_apply(p["conv1"], x, a_out=a_mid)
    h = jax.nn.relu(bn(p, "bn1", h, a_mid))
    h = L.conv_apply(p["conv2"], h, stride=stride, a_in=a_mid, a_out=a_mid)
    h = jax.nn.relu(bn(p, "bn2", h, a_mid))
    h = L.conv_apply(p["conv3"], h, a_in=a_mid, a_out=a_out)
    h = bn(p, "bn3", h, a_out)
    if "proj" in p:
        sc = L.conv_apply(p["proj"], x, stride=stride, a_out=a_out)
        sc = bn(p, "bn_proj", sc, a_out)
    else:
        sc = x if stride == 1 else x[:, ::stride, ::stride]
    return jax.nn.relu(h + sc)


def resnet_apply(params, images, cfg: ResNetConfig, *, setting: int = 0,
                 depth_mult: float = 1.0, train: bool = False,
                 collect_stats: bool = False):
    """images (B,H,W,3) -> (logits, stats|None).

    ``setting`` indexes cfg.width_settings (slimmable width + its BN set);
    ``depth_mult`` drops trailing non-transition blocks per stage.
    """
    wm = cfg.width_settings[setting]
    stats = [] if (train and collect_stats) else None
    x = images.astype(cfg.cdtype())
    a_stem = round_channels(cfg.width, wm, 8)
    h = L.conv_apply(params["stem"], x, stride=2, a_out=a_stem)
    hbn, st = L.sbn_apply(params["bn_stem"], h, setting=setting, train=train,
                          a=a_stem)
    if stats is not None:
        stats.append(("bn_stem", st))
    h = jax.nn.relu(hbn)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    prev_a = a_stem
    for s, depth in enumerate(cfg.depths):
        c_out = cfg.stage_channels(s)
        a_mid = round_channels(c_out // 4, wm, 8)
        a_out = round_channels(c_out, wm, 8)
        n_active = max(1, int(round(depth * depth_mult)))
        for b in range(depth):
            if b >= n_active and b > 0:
                continue  # layer scaling: drop trailing blocks
            stride = 2 if (b == 0 and s > 0) else 1
            blk = params[f"stage{s}"][b]
            h = _bottleneck_apply(blk, h, stride=stride, setting=setting,
                                  train=train, widths=(a_mid, a_out),
                                  stats=stats)
        prev_a = a_out
    pooled = jnp.mean(h, axis=(1, 2))
    logits = L.dense_apply(params["fc"], pooled, a_in=prev_a)
    return logits, stats
