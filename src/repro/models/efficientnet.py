"""EfficientNet (MBConv + SE) with compound scaling — the assigned
`efficientnet-b7` (width_mult 2.0, depth_mult 3.1, 600px).

EfficientNet *is* a statically-scaled family; the paper's dynamic technique
adds runtime width settings (slimmable, switchable BN) and depth settings
on top of the compound-scaled B7 supernet.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core.types import ElasticSpace, round_channels

# (expand_ratio, channels, repeats, stride, kernel) — EfficientNet-B0 stages
_B0_STAGES = (
    (1, 16, 1, 1, 3),
    (6, 24, 2, 2, 3),
    (6, 40, 2, 2, 5),
    (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5),
    (6, 192, 4, 2, 5),
    (6, 320, 1, 1, 3),
)


@dataclasses.dataclass(frozen=True)
class EffNetConfig:
    name: str
    width_mult: float = 1.0
    depth_mult: float = 1.0
    img_res: int = 224
    n_classes: int = 1000
    se_ratio: float = 0.25
    width_settings: Tuple[float, ...] = (1.0,)   # runtime slimmable widths
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    elastic: ElasticSpace = ElasticSpace()

    def round_filters(self, c: int) -> int:
        c = c * self.width_mult
        new_c = max(8, int(c + 4) // 8 * 8)
        if new_c < 0.9 * c:
            new_c += 8
        return new_c

    def round_repeats(self, r: int) -> int:
        return int(math.ceil(r * self.depth_mult))

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def _mbconv_init(key, c_in, c_out, expand, ksize, se_ratio, n_set, dtype):
    ks = jax.random.split(key, 6)
    c_mid = c_in * expand
    c_se = max(1, int(c_in * se_ratio))
    p = {}
    if expand != 1:
        p["expand"] = L.conv_init(ks[0], 1, c_in, c_mid, dtype=dtype)
        p["bn0"] = L.sbn_init(c_mid, n_set, dtype)
    p["dw"] = L.conv_init(ks[1], ksize, c_mid, c_mid, groups=c_mid, dtype=dtype)
    p["bn1"] = L.sbn_init(c_mid, n_set, dtype)
    p["se_reduce"] = L.conv_init(ks[2], 1, c_mid, c_se, bias=True, dtype=dtype)
    p["se_expand"] = L.conv_init(ks[3], 1, c_se, c_mid, bias=True, dtype=dtype)
    p["project"] = L.conv_init(ks[4], 1, c_mid, c_out, dtype=dtype)
    p["bn2"] = L.sbn_init(c_out, n_set, dtype)
    return p


def effnet_init(key, cfg: EffNetConfig) -> dict:
    n_set = len(cfg.width_settings)
    stem_c = cfg.round_filters(32)
    head_c = cfg.round_filters(1280)
    ks = jax.random.split(key, 4 + len(_B0_STAGES))
    params = {
        "stem": L.conv_init(ks[0], 3, 3, stem_c, dtype=cfg.pdtype()),
        "bn_stem": L.sbn_init(stem_c, n_set, cfg.pdtype()),
        "head": L.conv_init(ks[1], 1, cfg.round_filters(_B0_STAGES[-1][1]),
                            head_c, dtype=cfg.pdtype()),
        "bn_head": L.sbn_init(head_c, n_set, cfg.pdtype()),
        "fc": L.dense_init(ks[2], head_c, cfg.n_classes, dtype=cfg.pdtype()),
    }
    c_in = stem_c
    for s, (expand, c, r, stride, ksz) in enumerate(_B0_STAGES):
        c_out = cfg.round_filters(c)
        blocks = []
        bkeys = jax.random.split(ks[3 + s], cfg.round_repeats(r))
        for b in range(cfg.round_repeats(r)):
            blocks.append(_mbconv_init(bkeys[b], c_in, c_out, expand, ksz,
                                       cfg.se_ratio, n_set, cfg.pdtype()))
            c_in = c_out
        params[f"stage{s}"] = blocks
    return params


def _mbconv_apply(p, x, *, expand, ksize, stride, setting, train, wm, stats,
                  a_kernel=None):
    c_in_full = x.shape[-1]

    def bn(name, h, a):
        y, st = L.sbn_apply(p[name], h, setting=setting, train=train, a=a)
        if stats is not None:
            stats.append((name, st))
        return y

    h = x
    if "expand" in p:
        c_mid_full = p["expand"]["kernel"].shape[-1]
        a_mid = round_channels(c_mid_full, wm, 8)
        h = L.conv_apply(p["expand"], h, a_out=a_mid)
        h = jax.nn.silu(bn("bn0", h, a_mid))
    else:
        c_mid_full = c_in_full
        a_mid = h.shape[-1]
    h = L.conv_apply(p["dw"], h, stride=stride, groups=h.shape[-1],
                     a_in=a_mid if "expand" in p else None,
                     a_out=a_mid if "expand" in p else None,
                     a_kernel=a_kernel)
    h = jax.nn.silu(bn("bn1", h, a_mid if "expand" in p else None))
    # squeeze-excite (kernel dims sliced to match the active mid width)
    se = jnp.mean(h, axis=(1, 2), keepdims=True)
    se = jax.nn.silu(L.conv_apply(p["se_reduce"], se, a_in=se.shape[-1]))
    se = jax.nn.sigmoid(L.conv_apply(p["se_expand"], se, a_out=h.shape[-1]))
    h = h * se
    c_out_full = p["project"]["kernel"].shape[-1]
    a_out = round_channels(c_out_full, wm, 8)
    h = L.conv_apply(p["project"], h, a_in=h.shape[-1], a_out=a_out)
    h = bn("bn2", h, a_out)
    if stride == 1 and h.shape[-1] == x.shape[-1]:
        h = h + x
    return h


def effnet_apply(params, images, cfg: EffNetConfig, *, setting: int = 0,
                 depth_mult: float = 1.0, kernel_size=None,
                 train: bool = False, collect_stats: bool = False):
    """images (B,H,W,3) -> (logits, stats|None)."""
    wm = cfg.width_settings[setting]
    stats = [] if (train and collect_stats) else None
    x = images.astype(cfg.cdtype())
    stem_full = params["stem"]["kernel"].shape[-1]
    a_stem = round_channels(stem_full, wm, 8)
    h = L.conv_apply(params["stem"], x, stride=2, a_out=a_stem)
    hb, st = L.sbn_apply(params["bn_stem"], h, setting=setting, train=train,
                         a=a_stem)
    if stats is not None:
        stats.append(("bn_stem", st))
    h = jax.nn.silu(hb)
    for s, (expand, c, r, stride, ksz) in enumerate(_B0_STAGES):
        blocks = params[f"stage{s}"]
        n_active = max(1, int(round(len(blocks) * depth_mult)))
        for b, blk in enumerate(blocks):
            if b >= n_active and b > 0:
                continue
            ak = None
            if kernel_size is not None and ksz > kernel_size:
                ak = kernel_size
            h = _mbconv_apply(blk, h, expand=expand, ksize=ksz,
                              stride=stride if b == 0 else 1, setting=setting,
                              train=train, wm=wm, stats=stats, a_kernel=ak)
    head_full = params["head"]["kernel"].shape[-1]
    a_head = round_channels(head_full, wm, 8)
    h = L.conv_apply(params["head"], h, a_in=h.shape[-1], a_out=a_head)
    hb, st = L.sbn_apply(params["bn_head"], h, setting=setting, train=train,
                         a=a_head)
    if stats is not None:
        stats.append(("bn_head", st))
    h = jax.nn.silu(hb)
    pooled = jnp.mean(h, axis=(1, 2))
    logits = L.dense_apply(params["fc"], pooled, a_in=a_head)
    return logits, stats
