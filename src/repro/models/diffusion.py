"""Diffusion substrate: DDPM noise schedule, training loss, DDIM sampler.

The sampler step count is a first-class latency knob for the runtime
governor (the diffusion-native analogue of the paper's depth scaling): a
50-step schedule and a distilled 4-step schedule trade quality for time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_schedule(n_train_steps: int = 1000, beta_start: float = 1e-4,
                  beta_end: float = 0.02):
    betas = jnp.linspace(beta_start, beta_end, n_train_steps, dtype=jnp.float32)
    alphas = 1.0 - betas
    abar = jnp.cumprod(alphas)
    return {"betas": betas, "alphas": alphas, "alphas_bar": abar}


def q_sample(sched, x0, t, noise):
    """Forward-noise x0 at integer timesteps t."""
    ab = sched["alphas_bar"][t]
    shape = (-1,) + (1,) * (x0.ndim - 1)
    return (jnp.sqrt(ab).reshape(shape) * x0
            + jnp.sqrt(1.0 - ab).reshape(shape) * noise)


def ddpm_loss(denoise_fn, sched, x0, key):
    """Standard epsilon-prediction MSE. denoise_fn(x_t, t) -> eps_hat."""
    kt, kn = jax.random.split(key)
    n = sched["betas"].shape[0]
    t = jax.random.randint(kt, (x0.shape[0],), 0, n)
    noise = jax.random.normal(kn, x0.shape, x0.dtype)
    x_t = q_sample(sched, x0, t, noise)
    eps = denoise_fn(x_t, t)
    eps = eps[..., : x0.shape[-1]]          # models may emit (eps, var)
    return jnp.mean(jnp.square(eps.astype(jnp.float32) - noise))


def ddim_sample(denoise_fn, sched, shape, key, *, steps: int = 50,
                eta: float = 0.0, dtype=jnp.float32):
    """DDIM sampling loop with ``steps`` model evaluations (lax control flow)."""
    n = sched["betas"].shape[0]
    ts = jnp.linspace(n - 1, 0, steps).astype(jnp.int32)
    x = jax.random.normal(key, shape, dtype)

    def body(i, x):
        t = ts[i]
        t_next = jnp.where(i + 1 < steps, ts[jnp.minimum(i + 1, steps - 1)], -1)
        ab_t = sched["alphas_bar"][t]
        ab_n = jnp.where(t_next >= 0, sched["alphas_bar"][jnp.maximum(t_next, 0)],
                         jnp.float32(1.0))
        eps = denoise_fn(x, jnp.full((shape[0],), t))
        eps = eps[..., : shape[-1]].astype(jnp.float32)
        xf = x.astype(jnp.float32)
        x0 = (xf - jnp.sqrt(1 - ab_t) * eps) / jnp.sqrt(ab_t)
        x_next = jnp.sqrt(ab_n) * x0 + jnp.sqrt(1 - ab_n) * eps
        return x_next.astype(dtype)

    return jax.lax.fori_loop(0, steps, body, x)
