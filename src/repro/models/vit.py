"""ViT / DeiT encoder with elastic width/depth and early-exit heads.

Covers the assigned `vit-l16` and `deit-b` (distillation token) configs and
is the backbone of the paper's own Dynamic-OFA vision experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core.types import ElasticSpace, is_static
from repro.distributed import wsc


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    distill_token: bool = False      # DeiT
    exit_layers: Tuple[int, ...] = ()  # early-exit heads (layer scaling)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "none"
    elastic: ElasticSpace = ElasticSpace()

    @property
    def n_tokens(self) -> int:
        n = (self.img_res // self.patch) ** 2 + 1
        return n + 1 if self.distill_token else n

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def _block_init(key, cfg: ViTConfig) -> dict:
    k1, k2 = jax.random.split(key)
    d_head = cfg.d_model // cfg.n_heads
    return {
        "ln1": L.layernorm_init(cfg.d_model, cfg.pdtype()),
        "attn": L.attention_init(k1, cfg.d_model, cfg.n_heads, cfg.n_heads,
                                 d_head, qkv_bias=True, dtype=cfg.pdtype()),
        "ln2": L.layernorm_init(cfg.d_model, cfg.pdtype()),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, gated=False, bias=True,
                          dtype=cfg.pdtype()),
    }


def vit_init(key, cfg: ViTConfig) -> dict:
    ks = jax.random.split(key, 6)
    n_special = 2 if cfg.distill_token else 1
    params = {
        "patch_embed": L.conv_init(ks[0], cfg.patch, 3, cfg.d_model, bias=True,
                                   dtype=cfg.pdtype()),
        "cls": jax.random.normal(ks[1], (n_special, cfg.d_model),
                                 cfg.pdtype()) * 0.02,
        "pos": jax.random.normal(ks[2], (cfg.n_tokens, cfg.d_model),
                                 cfg.pdtype()) * 0.02,
        "final_ln": L.layernorm_init(cfg.d_model, cfg.pdtype()),
        "head": L.dense_init(ks[3], cfg.d_model, cfg.n_classes, dtype=cfg.pdtype()),
    }
    keys = jax.random.split(ks[4], cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: _block_init(k, cfg))(keys)
    if cfg.distill_token:
        params["head_dist"] = L.dense_init(ks[5], cfg.d_model, cfg.n_classes,
                                           dtype=cfg.pdtype())
    if cfg.exit_layers:
        keys = jax.random.split(ks[5], len(cfg.exit_layers))
        params["exit_heads"] = [
            L.dense_init(k, cfg.d_model, cfg.n_classes, dtype=cfg.pdtype())
            for k in keys]
    return params


def _encode(params, x, cfg: ViTConfig, E) -> tuple:
    """images (B,H,W,3) -> (tokens (B,N,d), per-layer stacked hiddens|None)."""
    a_model = E.get("a_model")
    a_layers = E.get("a_layers")
    B = x.shape[0]
    # patch conv keeps full d_model; masking/slicing happens after pos-embed
    # so the position table stays uniform across sub-networks.
    h = L.conv_apply(params["patch_embed"], x.astype(cfg.cdtype()),
                     stride=cfg.patch, padding="VALID")
    h = h.reshape(B, -1, cfg.d_model)
    cls = params["cls"].astype(h.dtype)
    h = jnp.concatenate([jnp.tile(cls[None], (B, 1, 1)), h], axis=1)
    h = h + params["pos"].astype(h.dtype)[None, : h.shape[1]]
    if a_model is not None:
        if is_static(a_model):
            h = h[..., : int(a_model)]
        else:
            from repro.core.elastic import mask_dim
            h = mask_dim(h, a_model, -1)
    h = wsc(h, ("pod", "data"), None, None)

    stack = params["layers"]
    if a_layers is not None and is_static(a_layers):
        stack = jax.tree_util.tree_map(lambda p: p[: int(a_layers)], stack)
        a_layers = None

    d_head = cfg.d_model // cfg.n_heads

    def body(carry, xs):
        hh = carry
        lp, idx = xs
        gate = None
        if a_layers is not None:
            gate = (idx < a_layers).astype(hh.dtype)
        hn = L.layernorm_apply(lp["ln1"], hh, a=a_model)
        att, _ = L.attention_apply(lp["attn"], hn, n_heads=cfg.n_heads,
                                   n_kv=cfg.n_heads, d_head=d_head,
                                   causal=False, rope_theta=None,
                                   a_model=a_model, a_heads=E.get("a_heads"))
        hh = hh + (att if gate is None else att * gate)
        hn = L.layernorm_apply(lp["ln2"], hh, a=a_model)
        ff = L.mlp_apply(lp["mlp"], hn, a_model=a_model, a_ff=E.get("a_ff"),
                         act="gelu")
        hh = hh + (ff if gate is None else ff * gate)
        return wsc(hh, ("pod", "data"), None, None), (hh if cfg.exit_layers else 0)

    fn = body
    if cfg.remat != "none":
        fn = jax.checkpoint(body, prevent_cse=False)
    n = jax.tree_util.tree_leaves(stack)[0].shape[0]
    h, hiddens = jax.lax.scan(fn, h, (stack, jnp.arange(n)))
    return h, (hiddens if cfg.exit_layers else None)


def vit_apply(params: dict, images: jax.Array, cfg: ViTConfig, *, E=None,
              return_exits: bool = False):
    """Returns (logits (B,n_classes), aux) — aux carries exit logits/distill."""
    E = dict(E or {})
    a_model = E.get("a_model")
    h, hiddens = _encode(params, images, cfg, E)
    h = L.layernorm_apply(params["final_ln"], h, a=a_model)
    logits = L.dense_apply(params["head"], h[:, 0], a_in=a_model)
    aux = {}
    if cfg.distill_token:
        aux["logits_dist"] = L.dense_apply(params["head_dist"], h[:, 1],
                                           a_in=a_model)
    if return_exits and cfg.exit_layers and hiddens is not None:
        outs = []
        for i, layer in enumerate(cfg.exit_layers):
            hexit = hiddens[layer][:, 0]
            outs.append(L.dense_apply(params["exit_heads"][i], hexit,
                                      a_in=a_model))
        aux["exit_logits"] = outs
    return logits, aux
