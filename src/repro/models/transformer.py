"""Decoder-only LM (dense and MoE) with scan-over-layers and elastic knobs.

Covers the four assigned LM architectures (kimi-k2, deepseek-moe-16b,
qwen1.5-110b, granite-20b): GQA/MQA, optional QKV bias, SwiGLU or plain
FFN, optional MoE blocks with ``first_k_dense`` leading dense layers.

Elastic (the paper's technique): width (d_ff / heads), depth (layer
scaling), and for MoE archs expert-count / top-k scaling.  Masked mode
serves supernet training; sliced mode serves the runtime governor.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core.elastic import active_mask
from repro.core.types import ElasticSpace, is_static
from repro.distributed import wsc
from repro.models.moe import MoEConfig, moe_apply, moe_init


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    gated_mlp: bool = True
    act: str = "silu"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    first_k_dense: int = 0
    d_ff_dense: Optional[int] = None     # FFN width of leading dense layers
    attn_impl: str = "ref"               # ref | blocked_scan | blocked_causal
    decode_impl: str = "xla"             # xla | sharded (two-pass softmax)
    block_q: int = 512
    block_kv: int = 512
    remat: str = "none"                  # none | full | dots
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    elastic: ElasticSpace = ElasticSpace()

    @property
    def n_moe_layers(self) -> int:
        return self.n_layers - self.first_k_dense if self.moe else 0

    @property
    def n_dense_layers(self) -> int:
        return self.first_k_dense if self.moe else self.n_layers

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_layer_init(key, cfg: LMConfig) -> dict:
    k1, k2 = jax.random.split(key)
    d_ff = cfg.d_ff_dense or cfg.d_ff
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.pdtype()),
        "attn": L.attention_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.d_head, qkv_bias=cfg.qkv_bias,
                                 dtype=cfg.pdtype()),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.pdtype()),
        "mlp": L.mlp_init(k2, cfg.d_model, d_ff, gated=cfg.gated_mlp,
                          dtype=cfg.pdtype()),
    }


def _moe_layer_init(key, cfg: LMConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.pdtype()),
        "attn": L.attention_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.d_head, qkv_bias=cfg.qkv_bias,
                                 dtype=cfg.pdtype()),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.pdtype()),
        "moe": moe_init(k2, cfg.d_model, cfg.moe, dtype=cfg.pdtype()),
    }


def lm_init(key, cfg: LMConfig) -> dict:
    ks = jax.random.split(key, 4)
    params = {"embed": L.embedding_init(ks[0], cfg.vocab_size, cfg.d_model,
                                        cfg.pdtype()),
              "final_norm": L.rmsnorm_init(cfg.d_model, cfg.pdtype())}
    if cfg.n_dense_layers:
        keys = jax.random.split(ks[1], cfg.n_dense_layers)
        params["dense_layers"] = jax.vmap(
            lambda k: _dense_layer_init(k, cfg))(keys)
    if cfg.n_moe_layers:
        keys = jax.random.split(ks[2], cfg.n_moe_layers)
        params["moe_layers"] = jax.vmap(lambda k: _moe_layer_init(k, cfg))(keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[3], cfg.d_model, cfg.vocab_size,
                                         bias=False, dtype=cfg.pdtype())
    return params


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _block(h, lp, cfg: LMConfig, E, *, is_moe: bool, layer_gate=None,
           kv_cache=None, return_kv: bool, mesh, positions=None):
    """One transformer block.  Returns (h, aux_loss, new_cache)."""
    a_model = E.get("a_model")
    a_ff = E.get("a_ff")
    a_heads = E.get("a_heads")
    hn = L.rmsnorm_apply(lp["ln1"], h, a=a_model, eps=cfg.norm_eps)
    attn_out, new_cache = L.attention_apply(
        lp["attn"], hn, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        d_head=cfg.d_head, causal=True, positions=positions,
        rope_theta=cfg.rope_theta, a_model=a_model, a_heads=a_heads,
        kv_cache=kv_cache, impl=cfg.attn_impl, block_q=cfg.block_q,
        block_kv=cfg.block_kv, return_kv=return_kv,
        decode_impl=cfg.decode_impl, mesh=mesh)
    if layer_gate is not None:
        attn_out = attn_out * layer_gate
    h = h + attn_out
    h = wsc(h, ("pod", "data"), None, None)
    hn = L.rmsnorm_apply(lp["ln2"], h, a=a_model, eps=cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if is_moe:
        ff, aux = moe_apply(lp["moe"], hn, cfg.moe, a_experts=E.get("a_experts"),
                            top_k=E.get("top_k"), a_ff=a_ff, a_model=a_model,
                            mesh=mesh, data_axes=("pod", "data") if mesh is not None
                            and "pod" in mesh.axis_names else ("data",))
    else:
        ff = L.mlp_apply(lp["mlp"], hn, a_model=a_model,
                         a_ff=E.get("a_ff_dense", a_ff), act=cfg.act)
    if layer_gate is not None:
        ff = ff * layer_gate
    h = h + ff
    return wsc(h, ("pod", "data"), None, None), aux, new_cache


def _stack(h, stacked, cfg: LMConfig, E, *, is_moe: bool, offset: int,
           caches=None, return_kv: bool, mesh):
    """scan over a homogeneous stack of layers with optional depth gating.

    In sliced mode (static a_layers) the caller has already sliced
    ``stacked``; here depth gating only handles the masked (traced) case.
    """
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    a_layers = E.get("a_layers")
    dyn_depth = a_layers is not None and not is_static(a_layers)

    def body(carry, xs):
        h = carry
        if caches is None:
            lp, idx = xs
            cache_l = None
        else:
            lp, cache_l, idx = xs
        gate = None
        if dyn_depth:
            gate = (idx + offset < a_layers).astype(h.dtype)
        positions = None
        h, aux, new_cache = _block(h, lp, cfg, E, is_moe=is_moe,
                                   layer_gate=gate, kv_cache=cache_l,
                                   return_kv=return_kv, mesh=mesh,
                                   positions=positions)
        out = (aux,) if new_cache is None else (aux, new_cache)
        return h, out

    fn = body
    if cfg.remat != "none":
        policy = {
            "full": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.checkpoint_dots,
            # weight matmuls only — batched attention-score dots recompute
            "dots_nb": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        }[cfg.remat]
        fn = jax.checkpoint(body, policy=policy, prevent_cse=False)

    idxs = jnp.arange(n)
    xs = (stacked, idxs) if caches is None else (stacked, caches, idxs)
    h, outs = jax.lax.scan(fn, h, xs)
    aux = jnp.sum(outs[0])
    new_caches = outs[1] if len(outs) > 1 else None
    return h, aux, new_caches


def _slice_stack(stacked, n: int):
    return jax.tree_util.tree_map(lambda x: x[:n], stacked)


def lm_apply(params: dict, tokens: jax.Array, cfg: LMConfig, *, E=None,
             caches=None, return_kv: bool = False, mesh=None):
    """tokens (B,S) int32 -> logits (B,S,V).

    Returns (logits, aux_loss, new_caches).  ``caches`` is a dict
    {"dense": stacked cache, "moe": stacked cache} for decode;
    ``return_kv`` makes prefill also emit caches.
    """
    E = dict(E or {})
    a_model = E.get("a_model")
    a_layers = E.get("a_layers")

    # static depth slicing: distribute active layers over the two stacks
    dense_stack = params.get("dense_layers")
    moe_stack = params.get("moe_layers")
    if a_layers is not None and is_static(a_layers):
        n_active = int(a_layers)
        nd = min(cfg.n_dense_layers, n_active)
        nm = max(0, n_active - cfg.n_dense_layers)
        if dense_stack is not None:
            dense_stack = _slice_stack(dense_stack, nd)
        if moe_stack is not None:
            moe_stack = _slice_stack(moe_stack, nm)
        E["a_layers"] = None

    h = L.embedding_apply(params["embed"], tokens, a=a_model,
                          dtype=cfg.cdtype())
    h = wsc(h, ("pod", "data"), None, None)

    aux = jnp.zeros((), jnp.float32)
    new_caches = {}
    if dense_stack is not None and jax.tree_util.tree_leaves(dense_stack):
        h, a, nc = _stack(h, dense_stack, cfg, E, is_moe=False, offset=0,
                          caches=None if caches is None else caches["dense"],
                          return_kv=return_kv, mesh=mesh)
        aux = aux + a
        new_caches["dense"] = nc
    if moe_stack is not None and jax.tree_util.tree_leaves(moe_stack):
        h, a, nc = _stack(h, moe_stack, cfg, E, is_moe=True,
                          offset=cfg.n_dense_layers,
                          caches=None if caches is None else caches["moe"],
                          return_kv=return_kv, mesh=mesh)
        aux = aux + a
        new_caches["moe"] = nc

    h = L.rmsnorm_apply(params["final_norm"], h, a=a_model, eps=cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.embedding_attend(params["embed"], h, a=a_model)
    else:
        logits = L.dense_apply(params["lm_head"], h, a_in=a_model)
    logits = wsc(logits, ("pod", "data"), None, "model")
    return logits, aux * (cfg.moe.router_aux_weight if cfg.moe else 0.0), \
        (new_caches or None)


def make_decode_caches(cfg: LMConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16, filled: int = 0):
    """Allocate stacked KV caches for decode (len marks the fill point)."""
    def one(n_layers):
        return {
            "k": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads,
                            cfg.d_head), dtype),
            "v": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads,
                            cfg.d_head), dtype),
            "len": jnp.full((n_layers,), filled, jnp.int32),
        }
    out = {}
    if cfg.n_dense_layers:
        out["dense"] = one(cfg.n_dense_layers)
    if cfg.n_moe_layers:
        out["moe"] = one(cfg.n_moe_layers)
    return out
