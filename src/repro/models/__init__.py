"""Architecture zoo — all elastic-aware (the paper's dynamic-DNN knobs).

transformer — decoder LMs: dense + MoE, GQA/MQA, scan-over-layers
moe         — top-k routing: dense-oracle / GShard-einsum / shard_map-a2a
vit         — ViT / DeiT (distill token, early-exit heads)
resnet / efficientnet — slimmable convnets with switchable BN
unet / dit  — diffusion backbones; diffusion.py has schedules + DDIM
"""
