"""SDXL-style UNet (ResBlocks + cross-attention transformer stages).

Assigned `unet-sdxl`: ch=320, ch_mult=(1,2,4), 2 res blocks per stage,
transformer depth (1,2,10) [stage0 has no attention in SDXL — depth applies
to stages 1 and 2], ctx_dim 2048.  Text/pooled conditioning enters as
precomputed stub embeddings per the assignment brief.

Elastic knobs: transformer-depth scaling (layer scaling inside attention
stages), FFN width scaling in the transformer blocks, and the sampler step
count at the runtime level.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core.types import ElasticSpace
from repro.models.dit import timestep_embedding


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    name: str
    img_res: int = 1024
    in_channels: int = 4
    ch: int = 320
    ch_mult: Tuple[int, ...] = (1, 2, 4)
    n_res_blocks: int = 2
    transformer_depth: Tuple[int, ...] = (0, 2, 10)   # per stage (0 = no attn)
    ctx_dim: int = 2048
    d_head: int = 64
    pooled_dim: int = 1280
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    elastic: ElasticSpace = ElasticSpace()

    @property
    def latent_res(self) -> int:
        return self.img_res // 8

    @property
    def temb_dim(self) -> int:
        return self.ch * 4

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


# --- blocks ----------------------------------------------------------------

def _resblock_init(key, c_in, c_out, temb_dim, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "gn1": L.groupnorm_init(c_in, dtype),
        "conv1": L.conv_init(ks[0], 3, c_in, c_out, bias=True, dtype=dtype),
        "temb": L.dense_init(ks[1], temb_dim, c_out, dtype=dtype),
        "gn2": L.groupnorm_init(c_out, dtype),
        "conv2": L.conv_init(ks[2], 3, c_out, c_out, bias=True, dtype=dtype),
    }
    if c_in != c_out:
        p["skip"] = L.conv_init(ks[3], 1, c_in, c_out, bias=True, dtype=dtype)
    return p


def _resblock_apply(p, x, temb):
    h = jax.nn.silu(L.groupnorm_apply(p["gn1"], x))
    h = L.conv_apply(p["conv1"], h)
    h = h + L.dense_apply(p["temb"], jax.nn.silu(temb))[:, None, None]
    h = jax.nn.silu(L.groupnorm_apply(p["gn2"], h))
    h = L.conv_apply(p["conv2"], h)
    skip = L.conv_apply(p["skip"], x) if "skip" in p else x
    return h + skip


def _basic_tblock_init(key, d, ctx_dim, d_head, dtype):
    ks = jax.random.split(key, 4)
    heads = d // d_head
    return {
        "ln1": L.layernorm_init(d, dtype),
        "attn1": L.attention_init(ks[0], d, heads, heads, d_head, dtype=dtype),
        "ln2": L.layernorm_init(d, dtype),
        # cross-attn: kv projected from ctx_dim
        "q2": L.dense_init(ks[1], d, d, bias=False, dtype=dtype),
        "kv2": L.dense_init(ks[2], ctx_dim, 2 * d, bias=False, dtype=dtype),
        "o2": L.dense_init(ks[3], d, d, bias=False, dtype=dtype),
        "ln3": L.layernorm_init(d, dtype),
        "mlp": L.mlp_init(jax.random.fold_in(key, 7), d, d * 4, gated=True,
                          bias=True, dtype=dtype),
    }


def _basic_tblock_apply(p, x, ctx, *, heads, d_head, a_ff=None):
    # self-attention
    hn = L.layernorm_apply(p["ln1"], x)
    att, _ = L.attention_apply(p["attn1"], hn, n_heads=heads, n_kv=heads,
                               d_head=d_head, causal=False, rope_theta=None)
    x = x + att
    # cross-attention over ctx tokens
    hn = L.layernorm_apply(p["ln2"], x)
    q = L.dense_apply(p["q2"], hn)
    kv = L.dense_apply(p["kv2"], ctx.astype(x.dtype))
    k, v = jnp.split(kv, 2, axis=-1)
    B, S, d = q.shape
    T = k.shape[1]
    qh = q.reshape(B, S, heads, d_head)
    kh = k.reshape(B, T, heads, d_head)
    vh = v.reshape(B, T, heads, d_head)
    scores = jnp.einsum("bshd,bthd->bhst", qh, kh).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d_head))
    w = jax.nn.softmax(scores, -1).astype(x.dtype)
    att = jnp.einsum("bhst,bthd->bshd", w, vh).reshape(B, S, d)
    x = x + L.dense_apply(p["o2"], att)
    # geglu-style FF
    hn = L.layernorm_apply(p["ln3"], x)
    x = x + L.mlp_apply(p["mlp"], hn, a_ff=a_ff, act="gelu")
    return x


def _transformer2d_init(key, c, depth, ctx_dim, d_head, dtype):
    ks = jax.random.split(key, depth + 2)
    return {
        "gn": L.groupnorm_init(c, dtype),
        "proj_in": L.dense_init(ks[0], c, c, bias=True, dtype=dtype),
        "blocks": [_basic_tblock_init(ks[1 + i], c, ctx_dim, d_head, dtype)
                   for i in range(depth)],
        "proj_out": {"kernel": jnp.zeros((c, c), dtype),
                     "bias": jnp.zeros((c,), dtype)},
    }


def _transformer2d_apply(p, x, ctx, *, d_head, depth_mult=1.0, a_ff=None):
    B, H, W, C = x.shape
    heads = C // d_head
    h = L.groupnorm_apply(p["gn"], x)
    h = h.reshape(B, H * W, C)
    h = L.dense_apply(p["proj_in"], h)
    n_active = max(1, int(round(len(p["blocks"]) * depth_mult)))
    for blk in p["blocks"][:n_active]:
        h = _basic_tblock_apply(blk, h, ctx, heads=heads, d_head=d_head,
                                a_ff=a_ff)
    h = L.dense_apply(p["proj_out"], h)
    return x + h.reshape(B, H, W, C)


# --- full UNet ---------------------------------------------------------------

def unet_init(key, cfg: UNetConfig) -> dict:
    dt = cfg.pdtype()
    ks = iter(jax.random.split(key, 256))
    td = cfg.temb_dim
    params = {
        "conv_in": L.conv_init(next(ks), 3, cfg.in_channels, cfg.ch, bias=True,
                               dtype=dt),
        "t_mlp1": L.dense_init(next(ks), cfg.ch, td, dtype=dt),
        "t_mlp2": L.dense_init(next(ks), td, td, dtype=dt),
        "pool_mlp": L.dense_init(next(ks), cfg.pooled_dim, td, dtype=dt),
        "gn_out": L.groupnorm_init(cfg.ch, dt),
        "conv_out": L.conv_init(next(ks), 3, cfg.ch, cfg.in_channels, bias=True,
                                dtype=dt),
    }
    chs = [cfg.ch * m for m in cfg.ch_mult]
    # down path
    down = []
    skip_chs = [cfg.ch]
    c_prev = cfg.ch
    for s, c in enumerate(chs):
        stage = {"res": [], "attn": []}
        for b in range(cfg.n_res_blocks):
            stage["res"].append(_resblock_init(next(ks), c_prev, c, td, dt))
            c_prev = c
            if cfg.transformer_depth[s]:
                stage["attn"].append(_transformer2d_init(
                    next(ks), c, cfg.transformer_depth[s], cfg.ctx_dim,
                    cfg.d_head, dt))
            skip_chs.append(c)
        if s < len(chs) - 1:
            stage["down"] = L.conv_init(next(ks), 3, c, c, bias=True, dtype=dt)
            skip_chs.append(c)
        down.append(stage)
    params["down"] = down
    # mid
    params["mid"] = {
        "res1": _resblock_init(next(ks), chs[-1], chs[-1], td, dt),
        "attn": _transformer2d_init(next(ks), chs[-1], cfg.transformer_depth[-1],
                                    cfg.ctx_dim, cfg.d_head, dt),
        "res2": _resblock_init(next(ks), chs[-1], chs[-1], td, dt),
    }
    # up path
    up = []
    for s in reversed(range(len(chs))):
        c = chs[s]
        stage = {"res": [], "attn": []}
        for b in range(cfg.n_res_blocks + 1):
            c_skip = skip_chs.pop()
            stage["res"].append(_resblock_init(next(ks), c_prev + c_skip, c,
                                               td, dt))
            c_prev = c
            if cfg.transformer_depth[s]:
                stage["attn"].append(_transformer2d_init(
                    next(ks), c, cfg.transformer_depth[s], cfg.ctx_dim,
                    cfg.d_head, dt))
        if s > 0:
            stage["up"] = L.conv_init(next(ks), 3, c, c, bias=True, dtype=dt)
        up.append(stage)
    params["up"] = up
    return params


def unet_apply(params, latents, t, ctx, pooled, cfg: UNetConfig, *, E=None):
    """latents (B,h,w,4), t (B,), ctx (B,77,ctx_dim), pooled (B,pooled_dim)
    -> noise prediction (B,h,w,4)."""
    E = dict(E or {})
    depth_mult = E.get("depth_mult", 1.0)
    a_ff = E.get("a_ff")
    cdt = cfg.cdtype()
    x = latents.astype(cdt)
    ctx = ctx.astype(cdt)

    temb = timestep_embedding(t, cfg.ch).astype(cdt)
    temb = L.dense_apply(params["t_mlp2"],
                         jax.nn.silu(L.dense_apply(params["t_mlp1"], temb)))
    temb = temb + L.dense_apply(params["pool_mlp"], pooled.astype(cdt))

    h = L.conv_apply(params["conv_in"], x)
    skips = [h]
    for s, stage in enumerate(params["down"]):
        for b, res in enumerate(stage["res"]):
            h = _resblock_apply(res, h, temb)
            if stage["attn"]:
                h = _transformer2d_apply(stage["attn"][b], h, ctx,
                                         d_head=cfg.d_head,
                                         depth_mult=depth_mult, a_ff=a_ff)
            skips.append(h)
        if "down" in stage:
            h = L.conv_apply(stage["down"], h, stride=2)
            skips.append(h)

    h = _resblock_apply(params["mid"]["res1"], h, temb)
    h = _transformer2d_apply(params["mid"]["attn"], h, ctx, d_head=cfg.d_head,
                             depth_mult=depth_mult, a_ff=a_ff)
    h = _resblock_apply(params["mid"]["res2"], h, temb)

    for stage in params["up"]:
        for b, res in enumerate(stage["res"]):
            skip = skips.pop()
            h = _resblock_apply(res, jnp.concatenate([h, skip], -1), temb)
            if stage["attn"]:
                h = _transformer2d_apply(stage["attn"][b], h, ctx,
                                         d_head=cfg.d_head,
                                         depth_mult=depth_mult, a_ff=a_ff)
        if "up" in stage:
            B, H, W, C = h.shape
            h = jax.image.resize(h, (B, H * 2, W * 2, C), "nearest")
            h = L.conv_apply(stage["up"], h)

    h = jax.nn.silu(L.groupnorm_apply(params["gn_out"], h))
    return L.conv_apply(params["conv_out"], h)
