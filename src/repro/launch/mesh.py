"""Production mesh definitions.

Functions, not module-level constants — importing this module never touches
jax device state.  The dry-run (and only the dry-run) forces 512 host
devices via XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh helper (tests use (1,1) or (2,2) CPU meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(n_devices: int | None = None):
    """Small local mesh over however many devices exist (smoke/serving)."""
    n = n_devices or len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
