"""Production mesh definitions.

Functions, not module-level constants — importing this module never touches
jax device state.  The dry-run (and only the dry-run) forces 512 host
devices via XLA_FLAGS before any jax import.

Version compat: ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
``jax.make_mesh``) only exist on newer JAX; the floor this repo supports is
0.4.37, where ``jax.make_mesh`` exists but takes no ``axis_types``.  All
mesh construction goes through :func:`make_mesh` so the rest of the code
(and the tests) never touch the version-dependent surface.
"""
from __future__ import annotations

import jax


def _auto_axis_types(n_axes: int):
    """``(AxisType.Auto,) * n_axes`` on JAX versions that have it, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n_axes


def make_mesh(shape, axes, *, devices=None):
    """Version-portable ``jax.make_mesh`` (tests use (1,1)/(2,2) CPU meshes).

    Passes ``axis_types=Auto`` where supported; on JAX 0.4.x (no
    ``AxisType``) it falls back to a plain mesh, which has the same Auto
    semantics there.  Falls back again to a hand-built ``Mesh`` if
    ``jax.make_mesh`` itself is absent (pre-0.4.35).
    """
    shape = tuple(shape)
    axes = tuple(axes)
    axis_types = _auto_axis_types(len(axes))
    if hasattr(jax, "make_mesh"):
        kwargs = {}
        if devices is not None:
            kwargs["devices"] = devices
        if axis_types is not None:
            kwargs["axis_types"] = axis_types
        return jax.make_mesh(shape, axes, **kwargs)
    import numpy as np
    devs = np.asarray(devices if devices is not None
                      else jax.devices()[: int(np.prod(shape))])
    return jax.sharding.Mesh(devs.reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None):
    """Small local mesh over however many devices exist (smoke/serving)."""
    n = n_devices or len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
