"""Serving launcher: the paper's deployed system.

``python -m repro.launch.serve --arch dynamic-ofa-supernet --smoke``

Brings up the DynamicServer (sub-network executable cache + dynamic
batching) with the JointGovernor in the loop, drives it with the paper's
workload trace (changing latency targets, thermal throttling, co-running
apps) and prints the monitor summary next to the Linux-governor baselines.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.types import SubnetSpec
from repro.runtime import (Constraints, DynamicServer, JointGovernor, Monitor,
                           PerformanceGovernor, SchedutilGovernor,
                           StaticPrunedGovernor, measured_lut, model_lut,
                           paper_trace, run_governor)
from repro.runtime import hwmodel as hm


def build_server(arch, cfg, *, max_batch=8):
    key = jax.random.PRNGKey(0)
    if arch.arch_id.startswith(("deit", "vit", "dynamic-ofa")):
        from repro.models.vit import vit_apply, vit_init
        params = vit_init(key, cfg)
        dims = {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
                "n_heads": cfg.n_heads, "n_layers": cfg.n_layers}
        apply_fn = lambda p, x, E: vit_apply(p, x, cfg, E=E)[0]
    else:
        raise SystemExit("serve launcher: vision transformer archs only "
                         "(the paper serves image classification)")
    return DynamicServer(apply_fn, params, dims, max_batch=max_batch)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dynamic-ofa-supernet")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--trace-steps", type=int, default=200)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.make_smoke() if args.smoke else arch.make_config()
    server = build_server(arch, cfg)

    # Pareto subnets of the elastic space
    specs = list(dict.fromkeys(
        [cfg.elastic.max_spec(), cfg.elastic.min_spec()]
        + list(cfg.elastic.enumerate(limit=24))))
    x = np.random.default_rng(0).normal(
        size=(server.max_batch, cfg.img_res, cfg.img_res, 3)).astype(np.float32)

    # measured LUT on this host (freq modelled; latency real wall-clock)
    def measure(spec, hw):
        lat = server.measure(spec, x) / hw.freq
        terms = hm.RooflineTerms(lat / 1e3, 0.0, 0.0)
        return lat, hm.step_energy_mj(terms, hw)

    lut = measured_lut(specs, measure)
    print(f"profiled {len(lut.points)} operating points over "
          f"{len(specs)} subnets")

    full = SubnetSpec()
    base_ms = np.median([p.latency_ms for p in lut.points
                         if p.subnet == full])
    governors = {
        "joint (paper)": JointGovernor(lut),
        "performance": PerformanceGovernor(lut, full),
        "schedutil": SchedutilGovernor(lut, full),
        "static-pruned": StaticPrunedGovernor(
            lut, worst_case=Constraints(target_latency_ms=base_ms * 0.8,
                                        chips_available=1)),
    }
    print(f"\nworkload trace: {args.trace_steps} steps, base target "
          f"{base_ms:.2f}ms")
    for name, gov in governors.items():
        mon = run_governor(gov, paper_trace(args.trace_steps, chips=1,
                                            base_target_ms=base_ms))
        print(f"  {name:16s} {mon.summary()}")

    # serve real batched requests through the governor
    gov = governors["joint (paper)"]
    constraints = lambda: Constraints(target_latency_ms=base_ms,
                                      chips_available=1)
    server.governor = gov
    server.start(constraints_fn=constraints)
    futs = [server.submit(x[0]) for _ in range(args.requests)]
    outs = [f.get(timeout=30) for f in futs]
    server.stop()
    lats = [o["latency_ms"] for o in outs]
    print(f"\nserved {len(outs)} requests  p50={np.percentile(lats,50):.1f}ms "
          f"p99={np.percentile(lats,99):.1f}ms  "
          f"subnets used: {sorted(set(o['subnet'] for o in outs))}")
    print(f"switches: {len(server.switch_log)}")


if __name__ == "__main__":
    main()
