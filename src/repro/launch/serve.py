"""Serving launcher: the paper's deployed system.

``python -m repro.launch.serve --arch dynamic-ofa-supernet --smoke``

Brings up the DynamicServer (sub-network executable cache + bucketed
continuous batching + pipelined dispatch) with the JointGovernor in the
loop, drives it with the paper's workload trace (changing latency
targets, thermal throttling, co-running apps) and prints the monitor
summary next to the Linux-governor baselines.

Serving data-path knobs (mirrored by ``DynamicServer``):

* ``--max-batch N``   — batching ceiling; the bucket ladder is the powers
  of two up to N (requests are padded only to the nearest bucket);
* ``--no-buckets``    — pad every batch to max_batch (old data path, the
  baseline ``bench_traffic`` compares against);
* ``--no-pipeline``   — dispatch synchronously instead of overlapping
  batch N+1's host-side stacking with batch N's device time.

Cluster / trace knobs (``--trace`` mode):

* ``--nodes N``       — scale the SLO classes out over N arbiter-governed
  nodes behind the cluster front-end (``repro.cluster``);
* ``--router p2c|round_robin|least_loaded`` — the routing policy;
* ``--record PATH``   — save the ACTUAL arrivals as a replayable
  schedule JSON (feed it back via ``--trace PATH``);
* ``--calibrate``     — close the measurement loop: servers record
  per-(subnet, bucket) latency EWMAs and measured tenant energy into a
  ``CalibrationStore`` the arbiter plans off (measured watts in the
  water-filling, calibrated LUT columns); ``--calibrate-out PATH``
  additionally saves the warmed store as JSON for calibrated replays;
* ``--health-interval S`` — cluster mode: run the stall-based health
  checker every S seconds (a node whose completions stay flat with
  futures outstanding is auto-failed over).

Observability (any mode):

* ``--trace-out PATH``   — record request span trees + decision spans
  through a :class:`repro.obs.Tracer` and write them as Chrome
  trace-event JSON (load in Perfetto / chrome://tracing); also prints
  the per-class p50/p95 latency decomposition;
* ``--metrics-out PATH`` — write the metrics registry snapshot
  (counters / gauges / histograms) as JSON, or Prometheus text format
  when PATH ends in ``.prom``.

The governed server warms its bucket ladder for the profiled subnets
before taking traffic, so steady-state serving performs zero cold
compiles (``server.cold_compiles`` stays 0).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.types import SubnetSpec
from repro.obs import (MetricsRegistry, TraceStreamer, Tracer, Watchtower,
                       decompose_latency, default_windows,
                       format_alerts, format_decomposition, format_profile,
                       profile_devices, quantile, write_chrome_trace)
from repro.runtime import (CalibrationStore, Constraints, DynamicServer,
                           GlobalConstraints, JointGovernor, Monitor,
                           PerformanceGovernor, ResourceArbiter,
                           SchedutilGovernor, StaticPrunedGovernor,
                           measured_lut, model_lut, paper_trace,
                           run_governor)
from repro.runtime import hwmodel as hm


def build_server(arch, cfg, *, max_batch=8, batch_buckets=True,
                 pipeline=True, calibration=None, tenant=None):
    key = jax.random.PRNGKey(0)
    if arch.arch_id.startswith(("deit", "vit", "dynamic-ofa")):
        from repro.models.vit import vit_apply, vit_init
        params = vit_init(key, cfg)
        dims = {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
                "n_heads": cfg.n_heads, "n_layers": cfg.n_layers}
        apply_fn = lambda p, x, E: vit_apply(p, x, cfg, E=E)[0]
    else:
        raise SystemExit("serve launcher: vision transformer archs only "
                         "(the paper serves image classification)")
    return DynamicServer(apply_fn, params, dims, max_batch=max_batch,
                         batch_buckets=batch_buckets, pipeline=pipeline,
                         calibration=calibration, tenant=tenant)


def run_trace_mode(args, arch, cfg, server, lut, x, base_ms):
    """``--trace``: SLO-classed request streams through the arbiter.

    Two tenants (an interactive class and a background batch class) run
    as separate DynamicServers behind one ResourceArbiter; the traffic
    layer replays a seeded arrival schedule (or a recorded one from a
    JSON file) open-loop against them and reports per-class percentile
    latency, goodput and drops.  ``--nodes N`` scales the same classes
    out over N arbiter-governed nodes behind a ``--router`` cluster
    front-end; ``--record PATH`` saves the actual arrivals as a replayable
    schedule.
    """
    from repro.traffic import (DEGRADE, SLOClass, drive_live, load_schedule,
                               onoff, poisson)

    need_tracer = (args.trace_out or args.stream_trace or args.profile_out
                   or args.alerts_out)
    tracer = Tracer() if need_tracer else None
    metrics = (MetricsRegistry()
               if (args.metrics_out or args.alerts_out) else None)
    dur = args.trace_duration
    streamer = (TraceStreamer(args.stream_trace).attach(tracer)
                if args.stream_trace else None)
    watchtower = None
    if args.alerts_out:
        # burn windows scaled so the trace duration is one SLO day; the
        # live driver feeds/evaluates it as futures resolve
        watchtower = Watchtower(
            {"interactive": 0.99, "batch": 0.95},
            windows=default_windows(dur / 86400.0),
            tracer=tracer, registry=metrics, hist_name="engine_request_ms")
    rate = args.requests / dur
    a_batch = poisson(max(rate / 2, 0.5), dur, seed=1)
    if args.trace == "poisson":
        a_int = poisson(rate, dur, seed=0)
    elif args.trace == "bursty":
        a_int = onoff(2.0 * rate, dur, on_s=dur / 6, off_s=dur / 6, seed=0)
    elif args.trace == "diurnal":
        from repro.traffic import diurnal
        a_int = diurnal(2.0 * rate, dur, period_s=dur / 2, seed=0)
    else:
        loaded = load_schedule(args.trace)   # recorded schedule replay
        if isinstance(loaded, dict):
            # multi-stream recording (drive_live --record): replay every
            # class it holds, falling back to the defaults for the rest
            a_int = loaded.get("interactive", poisson(rate, dur, seed=0))
            a_batch = loaded.get("batch", a_batch)
        else:
            a_int = loaded

    classes = [
        SLOClass("interactive", deadline_ms=base_ms * 8, priority=2),
        SLOClass("batch", deadline_ms=base_ms * 30, priority=0,
                 drop_policy=DEGRADE),
    ]
    streams = {"interactive": a_int, "batch": a_batch}
    # warm each bucket ladder for every profiled subnet (the arbiter's
    # governors pick from the LUT): the live trace pays zero cold compiles
    warm = list(dict.fromkeys(p.subnet for p in lut.points))
    store = CalibrationStore() if args.calibrate else None

    if args.nodes > 1:
        from repro.cluster import Cluster, ClusterNode
        nodes = [ClusterNode(name=f"node{i}",
                             g_fn=lambda t: GlobalConstraints(total_chips=2))
                 for i in range(args.nodes)]
        cluster = Cluster(nodes, router=args.router,
                          health_interval_s=args.health_interval,
                          rebalance_interval_s=args.rebalance_interval,
                          tracer=tracer, metrics=metrics)
        if store is not None:
            for node in nodes:
                node.arbiter.calibration = store

        for c in classes:
            def mk_server(node, _name=c.name):
                s = build_server(arch, cfg, max_batch=server.max_batch,
                                 batch_buckets=server.batch_buckets,
                                 pipeline=server.pipeline,
                                 calibration=store, tenant=_name)
                s.warm(warm, example_input=x[0])
                return s

            placed = cluster.register(c.name, lut,
                                      target_latency_ms=c.service_target_ms,
                                      priority=c.priority,
                                      make_server=mk_server)
            print(f"  {c.name}: placed on {placed}")
        report = drive_live(
            classes, cluster.ports(), cluster, streams, lambda name: x[0],
            g_fn=lambda: GlobalConstraints(total_chips=2),
            record_path=args.record, watchtower=watchtower)
        print(f"\ncluster trace mode [{args.trace}] x{args.nodes} nodes, "
              f"router={args.router}: {len(a_int)} interactive + "
              f"{len(a_batch)} batch arrivals over {dur:.1f}s")
        for name, cs in report.classes.items():
            print(f"  {name:12s} {cs.summary()}")
        print(f"  routed       {report.arbiter['routed']}")
        if args.health_interval is not None:
            print(f"  health-failed nodes: "
                  f"{report.arbiter.get('health_failed', [])}")
        if args.rebalance_interval is not None:
            print(f"  migrations:   {report.arbiter.get('migrations', [])}")
            print(f"  preempted:    {report.arbiter.get('preempted', [])}")
        _report_calibration(store, args)
        _emit_obs(args, tracer, cluster.metrics, watchtower=watchtower,
                  streamer=streamer)
        return

    batch_server = build_server(arch, cfg, max_batch=server.max_batch,
                                batch_buckets=server.batch_buckets,
                                pipeline=server.pipeline,
                                calibration=store, tenant="batch")
    if store is not None:
        # the profiling server becomes the interactive tenant: tag it so
        # its measured energy lands under the right calibration row
        server.calibration, server.tenant = store, "interactive"
    servers = {"interactive": server, "batch": batch_server}
    for s in servers.values():
        s.warm(warm, example_input=x[0])
    arbiter = ResourceArbiter(interval_s=0.05, calibration=store,
                              tracer=tracer, metrics=metrics)
    for c in classes:
        # two modelled 1-chip slices: the measured LUT profiles chips=1,
        # so a 2-chip pool lets both tenants hold a slice at once
        arbiter.register(c.name, lut, target_latency_ms=c.service_target_ms,
                         priority=c.priority, server=servers[c.name])
    report = drive_live(
        classes, servers, arbiter, streams, lambda name: x[0],
        g_fn=lambda: GlobalConstraints(total_chips=2),
        record_path=args.record, tracer=tracer, metrics=metrics,
        watchtower=watchtower)
    print(f"\ntrace mode [{args.trace}] {len(a_int)} interactive + "
          f"{len(a_batch)} batch arrivals over {dur:.1f}s")
    for name, cs in report.classes.items():
        print(f"  {name:12s} {cs.summary()}")
    print(f"  arbiter      {report.arbiter}")
    if args.record:
        print(f"  recorded actual arrivals -> {args.record}")
    _report_calibration(store, args)
    _emit_obs(args, tracer, arbiter.metrics, watchtower=watchtower,
              streamer=streamer)


def _emit_obs(args, tracer, metrics, watchtower=None, streamer=None):
    """Write --trace-out / --metrics-out / --alerts-out / --profile-out
    artifacts, close the --stream-trace stream, and print the per-class
    latency decomposition for the retained traces."""
    if streamer is not None:
        n = streamer.close(tracer)
        print(f"  streamed {n} trace events -> {streamer.path}")
    if tracer is not None and args.trace_out:
        n = write_chrome_trace(tracer, args.trace_out)
        print(f"  trace: {len(tracer.requests())} request trees retained "
              f"({tracer.dropped} evicted), {n} events -> {args.trace_out}")
        decomp = decompose_latency(tracer)
        if decomp:
            print(format_decomposition(decomp))
    if watchtower is not None and args.alerts_out:
        with open(args.alerts_out, "w") as f:
            text = format_alerts(watchtower.alerts)
            f.write(text + ("\n" if text else ""))
        print(f"  {len(watchtower.alerts)} SLO alerts "
              f"(time-in-SLO {watchtower.summary()['time_in_slo']}) "
              f"-> {args.alerts_out}")
    if tracer is not None and getattr(args, "profile_out", None):
        prof = profile_devices(tracer)
        with open(args.profile_out, "w") as f:
            f.write(format_profile(prof) + "\n")
        print(f"  device profile: {len(prof)} (subnet, bucket) rows "
              f"-> {args.profile_out}")
    if metrics is not None and args.metrics_out:
        text = (metrics.to_prometheus()
                if args.metrics_out.endswith(".prom")
                else metrics.to_json())
        with open(args.metrics_out, "w") as f:
            f.write(text)
        print(f"  metrics snapshot -> {args.metrics_out}")


def _report_calibration(store, args):
    if store is None:
        return
    s = store.summary()
    print(f"  calibration: {len(s['latency'])} (subnet, bucket) latency "
          f"columns, power rows: {s['power']}")
    if args.calibrate_out:
        store.save(args.calibrate_out)
        print(f"  calibration store saved -> {args.calibrate_out}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dynamic-ofa-supernet")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--trace-steps", type=int, default=200)
    ap.add_argument("--trace", default=None,
                    help="SLO traffic mode: poisson | bursty | diurnal | "
                         "path to a recorded schedule JSON")
    ap.add_argument("--trace-duration", type=float, default=5.0,
                    help="seconds of arrival schedule in --trace mode")
    ap.add_argument("--nodes", type=int, default=1,
                    help="cluster mode: N arbiter-governed nodes behind "
                         "the router (--trace only)")
    ap.add_argument("--router", default="p2c",
                    choices=["p2c", "round_robin", "least_loaded"],
                    help="cluster routing policy for --nodes > 1")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="record the ACTUAL --trace arrivals to a "
                         "replayable schedule JSON")
    ap.add_argument("--calibrate", action="store_true",
                    help="close the measurement loop: record measured "
                         "(subnet, bucket) latency + tenant energy and "
                         "let the arbiter plan off it")
    ap.add_argument("--calibrate-out", default=None, metavar="PATH",
                    help="save the warmed CalibrationStore as JSON "
                         "(implies nothing without --calibrate)")
    ap.add_argument("--health-interval", type=float, default=None,
                    metavar="S",
                    help="cluster mode: stall-based health check every "
                         "S seconds (auto-failover of wedged nodes)")
    ap.add_argument("--rebalance-interval", type=float, default=None,
                    metavar="S",
                    help="cluster mode: run the global placement engine "
                         "every S seconds (migration-cost-priced replica "
                         "rebalancing + cross-node preemption)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record request span trees + decision spans and "
                         "write Chrome trace-event JSON (open in Perfetto "
                         "or chrome://tracing); prints the p50/p95 "
                         "latency decomposition")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics snapshot as JSON (Prometheus "
                         "text format when PATH ends in .prom)")
    ap.add_argument("--stream-trace", default=None, metavar="PATH",
                    help="stream trace events to PATH as requests retire "
                         "(incremental Perfetto JSON — loadable mid-run "
                         "or after a crash)")
    ap.add_argument("--alerts-out", default=None, metavar="PATH",
                    help="--trace mode: run the SLO watchtower (burn-rate "
                         "alerts + attribution) against the live run and "
                         "write the alert log to PATH")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="write the per-(subnet, bucket) device profile "
                         "(analytic FLOPs, MXU utilisation, roofline "
                         "position) from retained DEVICE spans to PATH")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="batching ceiling (bucket ladder = powers of two)")
    ap.add_argument("--no-buckets", action="store_true",
                    help="pad every batch to max_batch (baseline data path)")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="synchronous dispatch (no host/device overlap)")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.make_smoke() if args.smoke else arch.make_config()
    server = build_server(arch, cfg, max_batch=args.max_batch,
                          batch_buckets=not args.no_buckets,
                          pipeline=not args.no_pipeline)

    # Pareto subnets of the elastic space
    specs = list(dict.fromkeys(
        [cfg.elastic.max_spec(), cfg.elastic.min_spec()]
        + list(cfg.elastic.enumerate(limit=24))))
    x = np.random.default_rng(0).normal(
        size=(server.max_batch, cfg.img_res, cfg.img_res, 3)).astype(np.float32)

    # measured LUT on this host (freq modelled; latency real wall-clock)
    def measure(spec, hw):
        lat = server.measure(spec, x) / hw.freq
        terms = hm.RooflineTerms(lat / 1e3, 0.0, 0.0)
        return lat, hm.step_energy_mj(terms, hw)

    lut = measured_lut(specs, measure)
    print(f"profiled {len(lut.points)} operating points over "
          f"{len(specs)} subnets")

    full = SubnetSpec()
    base_ms = np.median([p.latency_ms for p in lut.points
                         if p.subnet == full])
    if args.trace:
        run_trace_mode(args, arch, cfg, server, lut, x, base_ms)
        return
    governors = {
        "joint (paper)": JointGovernor(lut),
        "performance": PerformanceGovernor(lut, full),
        "schedutil": SchedutilGovernor(lut, full),
        "static-pruned": StaticPrunedGovernor(
            lut, worst_case=Constraints(target_latency_ms=base_ms * 0.8,
                                        chips_available=1)),
    }
    print(f"\nworkload trace: {args.trace_steps} steps, base target "
          f"{base_ms:.2f}ms")
    for name, gov in governors.items():
        mon = run_governor(gov, paper_trace(args.trace_steps, chips=1,
                                            base_target_ms=base_ms))
        print(f"  {name:16s} {mon.summary()}")

    # serve real batched requests through the governor; warm the bucket
    # ladder for every profiled subnet (anything the governor may pick)
    # so steady state starts compile-free
    gov = governors["joint (paper)"]
    constraints = lambda: Constraints(target_latency_ms=base_ms,
                                      chips_available=1)
    server.governor = gov
    tracer = (Tracer() if (args.trace_out or args.stream_trace
                           or args.profile_out) else None)
    metrics = MetricsRegistry() if args.metrics_out else None
    streamer = (TraceStreamer(args.stream_trace).attach(tracer)
                if args.stream_trace else None)
    if tracer is not None:
        server.tracer = tracer
    if metrics is not None:
        server.metrics = metrics
    server.warm(specs, example_input=x[0])
    server.start(constraints_fn=constraints)
    futs = [server.submit(x[0]) for _ in range(args.requests)]
    outs = [f.get(timeout=30) for f in futs]
    server.stop()
    lats = [o["latency_ms"] for o in outs]
    print(f"\nserved {len(outs)} requests  p50={quantile(lats,50):.1f}ms "
          f"p99={quantile(lats,99):.1f}ms  "
          f"subnets used: {sorted(set(o['subnet'] for o in outs))}")
    print(f"switches: {len(server.switch_log)} "
          f"(dropped {server.switch_log_dropped} log entries), "
          f"cold compiles while serving: {server.cold_compiles}, "
          f"buckets: {server.buckets}, pipeline: {server.pipeline}")
    _emit_obs(args, tracer, metrics, streamer=streamer)


if __name__ == "__main__":
    main()
