import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count on first init.  Only this entry point forces 512 host devices; smoke
tests and benchmarks see the real device count.

For every cell we record, into benchmarks/results/dryrun/<cell>.json:
  * memory_analysis()  — per-device bytes (proves it fits / flags overflow)
  * cost_analysis()    — per-device HLO FLOPs & bytes (roofline terms)
  * collective bytes   — parsed from the post-SPMD HLO text
  * MODEL_FLOPS        — analytic useful-compute yardstick

Usage:
  python -m repro.launch.dryrun                     # all cells, both meshes
  python -m repro.launch.dryrun --arch qwen1.5-110b --shape train_4k
  python -m repro.launch.dryrun --mesh single        # 16x16 only
  python -m repro.launch.dryrun --variant a2a        # MoE all-to-all path
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import get_arch, list_archs
from repro.distributed import use_mesh
from repro.launch import roofline as rl
from repro.launch.flops import model_flops
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.runtime.hwmodel import HwState, roofline

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             variant: str = "base", overrides=None, force: bool = False,
             accum=None, kv_dtype="bfloat16", drop_tp: bool = False,
             batch_all: bool = False, fsdp: bool = True, subnet=None):
    mesh_tag = "pod2" if multi_pod else "pod1"
    out_path = RESULTS / f"{arch_id}__{shape_name}__{mesh_tag}__{variant}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        if rec.get("status") == "ok":   # failures are retried after fixes
            print(f"[cached] {out_path.name}: ok")
            return rec

    arch = get_arch(arch_id)
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_tag,
           "variant": variant, "status": "error"}
    t0 = time.time()
    try:
        import jax.numpy as jnp
        mesh = make_production_mesh(multi_pod=multi_pod)
        cfg_overrides = dict(overrides or {})
        with use_mesh(mesh):
            cell = build_cell(arch, shape_name, mesh=mesh,
                              cfg_overrides=cfg_overrides or None,
                              accum=accum, kv_dtype=jnp.dtype(kv_dtype),
                              drop_tp=drop_tp, batch_all=batch_all,
                              fsdp=fsdp,
                              subnet_E=(json.loads(subnet) if subnet
                                        else None))
            lowered = cell.lower(mesh)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        cost = rl.cost_summary(compiled)          # XLA's own (loop bodies x1)
        mem = rl.memory_summary(compiled)
        from repro.launch.hlo_analysis import analyze_hlo
        hlo = analyze_hlo(compiled.as_text())     # trip-count-aware
        n_chips = mesh.size
        mf = model_flops(arch, cell.cfg, cell.shape)
        hw = HwState(chips=n_chips, freq=1.0)
        terms = roofline(hlo["flops"], hlo["traffic_bytes"],
                         hlo["coll_bytes_total"], hw)

        rec.update(
            status="ok", chips=n_chips,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            kind=cell.kind,
            flops_per_dev=hlo["flops"], bytes_per_dev=hlo["traffic_bytes"],
            coll_bytes_per_dev=hlo["coll_bytes_total"],
            coll_detail={k: v for k, v in hlo["coll_bytes"].items() if v},
            top_ops=hlo["top_ops"],
            xla_cost_analysis=cost,
            memory=mem,
            model_flops_global=mf,
            model_flops_per_dev=mf / n_chips,
            useful_ratio=(mf / n_chips) / hlo["flops"] if hlo["flops"] else 0,
            t_compute=terms.t_compute, t_memory=terms.t_memory,
            t_collective=terms.t_collective, t_total=terms.t_total,
            bottleneck=terms.bottleneck,
            hbm_gb_per_dev=mem["per_device_total"] / 1e9,
            fits_v5e=mem["per_device_total"] < 16e9,
        )
        print(f"[ok] {out_path.name}: compile={rec['compile_s']}s "
              f"bottleneck={rec['bottleneck']} t={rec['t_total']:.4f}s "
              f"hbm={rec['hbm_gb_per_dev']:.1f}GB useful={rec['useful_ratio']:.2f}")
    except Exception as e:  # noqa: BLE001 — record failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {out_path.name}: {rec['error'][:200]}")

    RESULTS.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--moe-dispatch", default=None,
                    help="override MoE dispatch (einsum|a2a)")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable); values "
                         "parsed as python literals where possible")
    ap.add_argument("--accum", type=int, default=None,
                    help="grad-accumulation override")
    ap.add_argument("--kv-dtype", default="bfloat16",
                    help="decode KV-cache dtype (e.g. int8 for quantised)")
    ap.add_argument("--drop-tp", action="store_true",
                    help="replicate over the model axis (DP-only serving)")
    ap.add_argument("--batch-all", action="store_true",
                    help="serve with the batch spread over every mesh axis")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="disable ZeRO-3 param sharding (serving configs)")
    ap.add_argument("--subnet", default=None,
                    help='JSON dict of static active dims, e.g. '
                         '\'{"a_model":384,"a_layers":6}\' — the paper\'s '
                         'sub-network knob applied to the dry-run cell')
    ap.add_argument("--skip-assigned", action="store_true",
                    help="skip the paper's own supernet config")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else [
        a for a in list_archs()
        if not (args.skip_assigned and a == "dynamic-ofa-supernet")]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch_id in archs:
        arch = get_arch(arch_id)
        shapes = [args.shape] if args.shape else list(arch.shapes)
        for shape_name in shapes:
            for mp in meshes:
                overrides = {}
                if args.moe_dispatch and arch.family == "lm" \
                        and arch.make_config().moe is not None:
                    cfg = arch.make_config()
                    overrides["moe"] = dataclasses.replace(
                        cfg.moe, dispatch=args.moe_dispatch)
                for kv in args.set:
                    k, v = kv.split("=", 1)
                    try:
                        import ast
                        v = ast.literal_eval(v)
                    except (ValueError, SyntaxError):
                        pass
                    overrides[k] = v
                rec = run_cell(arch_id, shape_name, mp, variant=args.variant,
                               overrides=overrides, force=args.force,
                               accum=args.accum, kv_dtype=args.kv_dtype,
                               drop_tp=args.drop_tp, batch_all=args.batch_all,
                               fsdp=not args.no_fsdp, subnet=args.subnet)
                n_fail += rec["status"] != "ok"
    print(f"\ndone; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
