"""Analytic MODEL_FLOPS per cell — the 'useful compute' yardstick.

§Roofline reports MODEL_FLOPS / HLO_FLOPs to expose remat recompute,
dispatch-einsum waste and padding.  Formulas:

  LM train    : 6·N_active·T + 3·(4·H·Dh)·S·T·L   (causal attention half)
  LM prefill  : 2·N_active·T + (4·H·Dh)·S·T·L / 2
  LM decode   : 2·N_active·B + 4·B·L·H·Dh·S_cache
  ViT/DiT     : token-matmul params x tokens (+ attention quadratic term)
  CNNs        : conv MAC walk over the stage geometry
  UNet        : conv + transformer walk over the stage geometry

N_active counts MoE experts at top_k (+shared) of n_experts.
"""
from __future__ import annotations

import math


# --- LM ----------------------------------------------------------------------

def lm_param_counts(cfg):
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    attn = d * H * Dh + 2 * d * K * Dh + H * Dh * d
    def ffn(f, gated):
        return (3 if gated else 2) * d * f
    n_body_act = 0.0
    n_body_tot = 0.0
    if cfg.moe:
        E, k, ns, fe = (cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.n_shared,
                        cfg.moe.d_ff)
        per_expert = 3 * d * fe
        moe_act = d * E + k * per_expert + ns * 3 * d * (fe * ns if False else fe)
        moe_act = d * E + (k + ns) * per_expert
        moe_tot = d * E + (E + ns) * per_expert
        n_moe = cfg.n_moe_layers
        n_dense = cfg.n_dense_layers
        fd = cfg.d_ff_dense or cfg.d_ff
        n_body_act = (n_moe * (attn + moe_act)
                      + n_dense * (attn + ffn(fd, cfg.gated_mlp)))
        n_body_tot = (n_moe * (attn + moe_tot)
                      + n_dense * (attn + ffn(fd, cfg.gated_mlp)))
    else:
        per = attn + ffn(cfg.d_ff, cfg.gated_mlp)
        n_body_act = n_body_tot = cfg.n_layers * per
    unemb = cfg.d_model * cfg.vocab_size
    return {"body_active": n_body_act, "body_total": n_body_tot,
            "unembed": unemb, "embed": unemb}


def lm_model_flops(cfg, kind: str, B: int, S: int) -> float:
    n = lm_param_counts(cfg)
    N_act = n["body_active"] + n["unembed"]
    L, H, Dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    if kind == "train":
        T = B * S
        return 6.0 * N_act * T + 3.0 * (4 * H * Dh) * S * T * L / 2
    if kind == "prefill":
        T = B * S
        return 2.0 * N_act * T + (4 * H * Dh) * S * T * L / 2
    # decode: one token against an S-entry cache
    return 2.0 * N_act * B + 4.0 * B * L * H * Dh * S


# --- ViT / DiT ---------------------------------------------------------------

def vit_model_flops(cfg, kind: str, B: int, img_res: int) -> float:
    tok = (img_res // cfg.patch) ** 2 + (2 if getattr(cfg, "distill_token",
                                                      False) else 1)
    d, L = cfg.d_model, cfg.n_layers
    per_tok = L * (4 * d * d + 2 * d * cfg.d_ff)       # attn + (plain) mlp
    attn_quad = L * 4 * d * tok                         # per token: 4·d·tok
    patch = cfg.patch * cfg.patch * 3 * d
    fwd = 2.0 * B * tok * (per_tok + patch) + 2.0 * B * tok * attn_quad
    return fwd * (3.0 if kind == "train" else 1.0)


def dit_model_flops(cfg, kind: str, B: int) -> float:
    tok = (cfg.latent_res // cfg.patch) ** 2
    d, L = cfg.d_model, cfg.n_layers
    per_tok = L * (4 * d * d + 2 * d * cfg.d_ff + 6 * d * d)   # + adaLN
    attn_quad = L * 4 * d * tok
    fwd = 2.0 * B * tok * (per_tok + attn_quad / 1.0)
    return fwd * (3.0 if kind == "train" else 1.0)


# --- CNNs ---------------------------------------------------------------------

def resnet_model_flops(cfg, kind: str, B: int, img_res: int) -> float:
    macs = 0.0
    r = img_res // 2                       # stem stride 2
    macs += r * r * 49 * 3 * cfg.width
    r = r // 2                             # maxpool
    c_in = cfg.width
    for s, depth in enumerate(cfg.depths):
        c_out = cfg.stage_channels(s)
        c_mid = c_out // 4
        for b in range(depth):
            stride = 2 if (b == 0 and s > 0) else 1
            r_out = r // stride
            macs += r * r * c_in * c_mid               # 1x1
            macs += r_out * r_out * 9 * c_mid * c_mid  # 3x3 (stride)
            macs += r_out * r_out * c_mid * c_out      # 1x1
            if c_in != c_out:
                macs += r_out * r_out * c_in * c_out
            c_in, r = c_out, r_out
    macs += c_in * cfg.n_classes
    fwd = 2.0 * B * macs
    return fwd * (3.0 if kind == "train" else 1.0)


def effnet_model_flops(cfg, kind: str, B: int, img_res: int) -> float:
    from repro.models.efficientnet import _B0_STAGES
    macs = 0.0
    r = img_res // 2
    stem = cfg.round_filters(32)
    macs += r * r * 9 * 3 * stem
    c_in = stem
    for (expand, c, reps, stride, k) in _B0_STAGES:
        c_out = cfg.round_filters(c)
        for b in range(cfg.round_repeats(reps)):
            st = stride if b == 0 else 1
            c_mid = c_in * expand
            r_out = r // st
            if expand != 1:
                macs += r * r * c_in * c_mid
            macs += r_out * r_out * k * k * c_mid          # depthwise
            c_se = max(1, int(c_in * 0.25))
            macs += c_mid * c_se * 2                        # SE
            macs += r_out * r_out * c_mid * c_out
            c_in, r = c_out, r_out
    head = cfg.round_filters(1280)
    macs += r * r * c_in * head + head * cfg.n_classes
    fwd = 2.0 * B * macs
    return fwd * (3.0 if kind == "train" else 1.0)


# --- UNet ----------------------------------------------------------------------

def unet_model_flops(cfg, kind: str, B: int, img_res: int) -> float:
    macs = 0.0
    r = img_res // 8
    chs = [cfg.ch * m for m in cfg.ch_mult]
    macs += r * r * 9 * cfg.in_channels * cfg.ch

    def res_macs(r, cin, cout):
        return r * r * (9 * cin * cout + 9 * cout * cout
                        + (cin * cout if cin != cout else 0)) \
            + cfg.temb_dim * cout

    def tblock_macs(r, c, depth):
        tok = r * r
        # self-attn proj + quadratic + cross-attn q/o + geglu mlp (x4, gated)
        per = depth * (4 * c * c + 4 * c * tok + 2 * c * c + 12 * c * c)
        return tok * per + 2 * c * c * tok + 77 * cfg.ctx_dim * 2 * c * depth

    c_prev = cfg.ch
    skips = [cfg.ch]
    for s, c in enumerate(chs):
        for b in range(cfg.n_res_blocks):
            macs += res_macs(r, c_prev, c)
            c_prev = c
            if cfg.transformer_depth[s]:
                macs += tblock_macs(r, c, cfg.transformer_depth[s])
            skips.append(c)
        if s < len(chs) - 1:
            macs += r * r // 4 * 9 * c * c
            skips.append(c)
            r //= 2
    macs += 2 * res_macs(r, chs[-1], chs[-1])
    macs += tblock_macs(r, chs[-1], cfg.transformer_depth[-1])
    for s in reversed(range(len(chs))):
        c = chs[s]
        for b in range(cfg.n_res_blocks + 1):
            c_skip = skips.pop()
            macs += res_macs(r, c_prev + c_skip, c)
            c_prev = c
            if cfg.transformer_depth[s]:
                macs += tblock_macs(r, c, cfg.transformer_depth[s])
        if s > 0:
            r *= 2
            macs += r * r * 9 * c * c
    macs += r * r * 9 * cfg.ch * cfg.in_channels
    fwd = 2.0 * B * macs
    return fwd * (3.0 if kind == "train" else 1.0)


# --- dispatch -------------------------------------------------------------------

def model_flops(arch, cfg, shape) -> float:
    fam, kind = arch.family, shape.kind
    if fam == "lm":
        return lm_model_flops(cfg, {"train": "train", "prefill": "prefill",
                                    "decode": "decode"}[kind],
                              shape.global_batch, shape.seq_len)
    if fam == "diffusion":
        k = "train" if kind == "diff_train" else "gen"
        if arch.arch_id.startswith("dit"):
            return dit_model_flops(cfg, k, shape.global_batch)
        return unet_model_flops(cfg, k, shape.global_batch, shape.img_res)
    k = "train" if kind == "vis_train" else "serve"
    if arch.arch_id.startswith(("deit", "vit", "dynamic-ofa")):
        return vit_model_flops(cfg, k, shape.global_batch, shape.img_res)
    if arch.arch_id.startswith("resnet"):
        return resnet_model_flops(cfg, k, shape.global_batch, shape.img_res)
    return effnet_model_flops(cfg, k, shape.global_batch, shape.img_res)
