"""Step builders: (arch x shape x mesh) -> jit-able step + specs.

One entry point, :func:`build_cell`, returns everything the dry-run, the
trainer and the benchmarks need for a cell:

  * ``fn``            the step function (train / prefill / decode / denoise /
                      serve), closing over the model config,
  * ``args``          ShapeDtypeStruct stand-ins for every input,
  * ``in_shardings`` / ``out_shardings``  PartitionSpec trees (cleaned
                      against the mesh at jit time).

No device allocation happens here — params enter as ShapeDtypeStructs via
``jax.eval_shape`` so trillion-parameter configs lower on a laptop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ArchDef, ShapeSpec
from repro.core.distill import ce_loss
from repro.distributed.sharding import (clean_spec, opt_specs_like,
                                        param_specs, to_named)
from repro.models import diffusion as diff
from repro.optim import clip_by_global_norm, make_optimizer

BATCH = ("pod", "data")

# Grad-accumulation defaults: microbatches per step, chosen so per-device
# activation memory fits 16 GB v5e HBM at the production mesh (see
# EXPERIMENTS.md §Dry-run).  Overridable via build_cell(accum=...).
ACCUM_DEFAULTS = {
    ("qwen1.5-110b", "train_4k"): 16,
    ("kimi-k2-1t-a32b", "train_4k"): 16,
    ("granite-20b", "train_4k"): 16,
    ("deepseek-moe-16b", "train_4k"): 4,
    ("unet-sdxl", "train_1024"): 2,
    ("unet-sdxl", "train_256"): 2,
    ("dit-l2", "train_1024"): 2,
}


def _accum_grads(loss_fn, params, batch, accum: int):
    """Microbatched grad accumulation: scan over ``accum`` chunks of the
    global batch; grads accumulate in fp32 with the params' sharding."""
    if accum <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def split(x):
        return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

    mbs = jax.tree_util.tree_map(split, batch)
    g0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        gsum, lsum = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        gsum = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), gsum, g)
        return (gsum, lsum + loss), None

    (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)), mbs)
    grads = jax.tree_util.tree_map(lambda g: g / accum, gsum)
    return lsum / accum, grads


@dataclasses.dataclass
class Cell:
    arch: ArchDef
    cfg: Any
    shape: ShapeSpec
    fn: Any
    args: tuple                 # ShapeDtypeStructs
    in_specs: tuple             # PartitionSpec trees (aligned with args)
    out_specs: Any
    kind: str

    def jit(self, mesh):
        from jax.sharding import NamedSharding
        in_s = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, clean_spec(s, mesh)),
            self.in_specs, is_leaf=lambda x: isinstance(x, P))
        out_s = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, clean_spec(s, mesh)),
            self.out_specs, is_leaf=lambda x: isinstance(x, P))
        return jax.jit(self.fn, in_shardings=in_s, out_shardings=out_s)

    def lower(self, mesh):
        return self.jit(mesh).lower(*self.args)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _n_batch_shards(mesh) -> int:
    if mesh is None:
        return 1
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a in BATCH]))


def _image_spec(B: int, spatial: int, mesh, ndim: int = 4):
    """Batch-leading image/latent spec: shard batch when divisible, else
    shard the height dim spatially (GSPMD halo-exchanges convs), else
    replicate.  Returns (tensor_spec, scalar_batch_spec)."""
    shards = _n_batch_shards(mesh)
    if B % shards == 0:
        return (P(BATCH, *([None] * (ndim - 1))), P(BATCH))
    if spatial % shards == 0:
        return (P(None, BATCH, *([None] * (ndim - 2))), P(None))
    return (P(*([None] * ndim)), P(None))


def _replicate_like(tree):
    return jax.tree_util.tree_map(lambda x: P(*([None] * x.ndim)), tree)


def _metric_specs(metrics_tree):
    return jax.tree_util.tree_map(lambda x: P(), metrics_tree)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch: ArchDef, cfg, shape: ShapeSpec, *, mesh=None,
             fsdp_axes=BATCH, opt_hp=None, subnet_E=None,
             accum: int = 1, kv_dtype=jnp.bfloat16) -> Cell:
    from repro.models.transformer import lm_apply, lm_init, make_decode_caches

    key = jax.random.PRNGKey(0)
    pshapes = jax.eval_shape(lambda: lm_init(key, cfg))
    pspecs = param_specs(pshapes, "lm", fsdp_axes=fsdp_axes)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        init_fn, update_fn = make_optimizer(arch.optimizer, **(opt_hp or {}))
        oshapes = jax.eval_shape(init_fn, pshapes)
        ospecs = opt_specs_like(pspecs, oshapes, pshapes)

        def train_step(params, opt, batch, step):
            def loss_fn(p, mb):
                logits, aux, _ = lm_apply(p, mb["tokens"], cfg, E=subnet_E,
                                          mesh=mesh)
                return ce_loss(logits, mb["labels"]) + aux
            loss, grads = _accum_grads(loss_fn, params, batch, accum)
            grads, gn = clip_by_global_norm(grads, 1.0)
            params, opt = update_fn(params, grads, opt, step)
            return params, opt, {"loss": loss, "gnorm": gn}

        batch_sds = {"tokens": _sds((B, S), jnp.int32),
                     "labels": _sds((B, S), jnp.int32)}
        batch_spec = {"tokens": P(BATCH, None), "labels": P(BATCH, None)}
        args = (pshapes, oshapes, batch_sds, _sds((), jnp.int32))
        in_specs = (pspecs, ospecs, batch_spec, P())
        out_specs = (pspecs, ospecs, {"loss": P(), "gnorm": P()})
        return Cell(arch, cfg, shape, train_step, args, in_specs, out_specs,
                    "train")

    if shape.kind == "prefill":
        def prefill(params, tokens):
            logits, _, _ = lm_apply(params, tokens, cfg, E=subnet_E, mesh=mesh)
            return logits[:, -1, :]
        args = (pshapes, _sds((B, S), jnp.int32))
        in_specs = (pspecs, P(BATCH, None))
        out_specs = P(BATCH, "model")
        return Cell(arch, cfg, shape, prefill, args, in_specs, out_specs,
                    "prefill")

    # decode: one new token against a seq_len KV cache
    cshapes = jax.eval_shape(
        lambda: make_decode_caches(cfg, B, S, dtype=kv_dtype,
                                   filled=S - 1))
    n_data = 16  # production data-axis width; cleaned specs adapt smaller
    if B >= n_data:
        seq_axes = ("model",)
        b_axes = BATCH
    else:
        seq_axes = ("pod", "data", "model")
        b_axes = None

    cspecs = jax.tree_util.tree_map(
        lambda x: (P(None, b_axes, seq_axes, None, None) if x.ndim == 5
                   else P(None)), cshapes)

    def decode(params, caches, tokens):
        logits, _, caches = lm_apply(params, tokens, cfg, E=subnet_E,
                                     caches=caches, mesh=mesh)
        return logits[:, -1, :], caches

    args = (pshapes, cshapes, _sds((B, 1), jnp.int32))
    in_specs = (pspecs, cspecs, P(b_axes, None))
    out_specs = (P(b_axes, "model"), cspecs)
    return Cell(arch, cfg, shape, decode, args, in_specs, out_specs, "decode")


# ---------------------------------------------------------------------------
# Diffusion cells (DiT / UNet)
# ---------------------------------------------------------------------------

def _diff_cell(arch: ArchDef, cfg, shape: ShapeSpec, *, mesh=None,
               fsdp_axes=BATCH, opt_hp=None, subnet_E=None,
               accum: int = 1, batch_all: bool = False) -> Cell:
    is_dit = arch.arch_id.startswith("dit")
    if is_dit:
        from repro.models.dit import dit_apply, dit_init
        cfg = dataclasses.replace(cfg, img_res=shape.img_res)
        init = functools.partial(dit_init, jax.random.PRNGKey(0), cfg)
        lat = (shape.global_batch, cfg.latent_res, cfg.latent_res,
               cfg.in_channels)
        lat_spec, b_spec = _image_spec(shape.global_batch, cfg.latent_res,
                                       mesh)
        cond_sds = {"y": _sds((shape.global_batch,), jnp.int32)}
        cond_spec = {"y": b_spec}

        def denoise(params, latents, t, cond):
            return dit_apply(params, latents, t, cond["y"], cfg, E=subnet_E)
    else:
        from repro.models.unet import unet_apply, unet_init
        cfg = dataclasses.replace(cfg, img_res=shape.img_res)
        init = functools.partial(unet_init, jax.random.PRNGKey(0), cfg)
        lat = (shape.global_batch, cfg.latent_res, cfg.latent_res,
               cfg.in_channels)
        lat_spec, b_spec = _image_spec(shape.global_batch, cfg.latent_res,
                                       mesh)
        cond_sds = {"ctx": _sds((shape.global_batch, 77, cfg.ctx_dim),
                                jnp.bfloat16),
                    "pooled": _sds((shape.global_batch, cfg.pooled_dim),
                                   jnp.bfloat16)}
        cond_spec = {"ctx": P(*b_spec, None, None),
                     "pooled": P(*b_spec, None)}

        def denoise(params, latents, t, cond):
            return unet_apply(params, latents, t, cond["ctx"], cond["pooled"],
                              cfg, E=subnet_E)

    pshapes = jax.eval_shape(init)
    pspecs = param_specs(pshapes, "vision", fsdp_axes=fsdp_axes)
    B = shape.global_batch
    if batch_all:
        # pure data parallelism: batch over every axis, weights replicated
        all_ax = ("pod", "data", "model")
        lat_spec, b_spec = (P(all_ax, None, None, None), P(all_ax))
        if is_dit:
            cond_spec = {"y": b_spec}
        else:
            cond_spec = {"ctx": P(*b_spec, None, None),
                         "pooled": P(*b_spec, None)}
    sched = diff.make_schedule()

    if shape.kind == "diff_train":
        init_fn, update_fn = make_optimizer(arch.optimizer, **(opt_hp or {}))
        oshapes = jax.eval_shape(init_fn, pshapes)
        ospecs = opt_specs_like(pspecs, oshapes, pshapes)

        def train_step(params, opt, batch, step):
            def loss_fn(p, mb):
                x_t = diff.q_sample(sched, mb["latents"], mb["t"], mb["noise"])
                eps = denoise(p, x_t, mb["t"], mb["cond"])
                eps = eps[..., : mb["latents"].shape[-1]]
                return jnp.mean(jnp.square(eps.astype(jnp.float32)
                                           - mb["noise"].astype(jnp.float32)))
            loss, grads = _accum_grads(loss_fn, params, batch, accum)
            grads, gn = clip_by_global_norm(grads, 1.0)
            params, opt = update_fn(params, grads, opt, step)
            return params, opt, {"loss": loss, "gnorm": gn}

        batch_sds = {"latents": _sds(lat, jnp.float32),
                     "noise": _sds(lat, jnp.float32),
                     "t": _sds((B,), jnp.int32), "cond": cond_sds}
        batch_spec = {"latents": lat_spec, "noise": lat_spec,
                      "t": b_spec, "cond": cond_spec}
        args = (pshapes, oshapes, batch_sds, _sds((), jnp.int32))
        in_specs = (pspecs, ospecs, batch_spec, P())
        out_specs = (pspecs, ospecs, {"loss": P(), "gnorm": P()})
        return Cell(arch, cfg, shape, train_step, args, in_specs, out_specs,
                    "train")

    # one denoising step of the sampler (x steps = full generation)
    def gen_step(params, latents, t, cond):
        return denoise(params, latents, t, cond)

    args = (pshapes, _sds(lat, jnp.bfloat16), _sds((B,), jnp.int32), cond_sds)
    in_specs = (pspecs, lat_spec, b_spec, cond_spec)
    out_specs = lat_spec
    return Cell(arch, cfg, shape, gen_step, args, in_specs, out_specs,
                "denoise")


# ---------------------------------------------------------------------------
# Vision cells
# ---------------------------------------------------------------------------

def _vis_cell(arch: ArchDef, cfg, shape: ShapeSpec, *, mesh=None,
              fsdp_axes=(), opt_hp=None, subnet_E=None,
              accum: int = 1, batch_all: bool = False) -> Cell:
    fam = arch.arch_id
    B, r = shape.global_batch, shape.img_res

    if fam.startswith(("deit", "vit", "dynamic-ofa")):
        from repro.models.vit import vit_apply, vit_init
        if r != cfg.img_res:
            cfg = dataclasses.replace(cfg, img_res=r)
        init = functools.partial(vit_init, jax.random.PRNGKey(0), cfg)

        def fwd(params, images):
            logits, _ = vit_apply(params, images, cfg, E=subnet_E)
            return logits
    elif fam.startswith("resnet"):
        from repro.models.resnet import resnet_apply, resnet_init
        init = functools.partial(resnet_init, jax.random.PRNGKey(0), cfg)

        def fwd(params, images, train=False):
            logits, _ = resnet_apply(params, images, cfg, train=train)
            return logits
    else:
        from repro.models.efficientnet import effnet_apply, effnet_init
        if r != cfg.img_res:
            cfg = dataclasses.replace(cfg, img_res=r)
        init = functools.partial(effnet_init, jax.random.PRNGKey(0), cfg)

        def fwd(params, images, train=False):
            logits, _ = effnet_apply(params, images, cfg, train=train)
            return logits

    pshapes = jax.eval_shape(init)
    pspecs = param_specs(pshapes, "vision", fsdp_axes=fsdp_axes)
    img_sds = _sds((B, r, r, 3), jnp.bfloat16)
    img_spec, vb_spec = _image_spec(B, r, mesh)
    if batch_all:
        # serving: batch over the data axes AND image height over 'model'
        # (replicated weights, halo-exchanged patch conv) — all 256 chips
        # busy without tensor-parallel collectives per layer
        img_spec, vb_spec = P(BATCH, "model", None, None), P(BATCH)

    if shape.kind == "vis_train":
        init_fn, update_fn = make_optimizer(arch.optimizer, **(opt_hp or {}))
        oshapes = jax.eval_shape(init_fn, pshapes)
        ospecs = opt_specs_like(pspecs, oshapes, pshapes)
        needs_train_flag = fam.startswith(("resnet", "efficientnet"))

        def train_step(params, opt, batch, step):
            def loss_fn(p, mb):
                if needs_train_flag:
                    logits = fwd(p, mb["images"], train=True)
                else:
                    logits = fwd(p, mb["images"])
                return ce_loss(logits, mb["labels"])
            loss, grads = _accum_grads(loss_fn, params, batch, accum)
            grads, gn = clip_by_global_norm(grads, 1.0)
            params, opt = update_fn(params, grads, opt, step)
            return params, opt, {"loss": loss, "gnorm": gn}

        batch_sds = {"images": img_sds, "labels": _sds((B,), jnp.int32)}
        batch_spec = {"images": img_spec, "labels": vb_spec}
        args = (pshapes, oshapes, batch_sds, _sds((), jnp.int32))
        in_specs = (pspecs, ospecs, batch_spec, P())
        out_specs = (pspecs, ospecs, {"loss": P(), "gnorm": P()})
        return Cell(arch, cfg, shape, train_step, args, in_specs, out_specs,
                    "train")

    def serve(params, images):
        return fwd(params, images)

    args = (pshapes, img_sds)
    in_specs = (pspecs, img_spec)
    out_specs = P(*vb_spec, None)
    return Cell(arch, cfg, shape, serve, args, in_specs, out_specs, "serve")


# ---------------------------------------------------------------------------

def _drop_axis(specs_tree, axis: str):
    """Remove one mesh axis from every PartitionSpec in a tree (e.g. serve
    small models data-parallel-only: replicate instead of tensor-parallel)."""
    def fix(spec):
        def keep(e):
            if e == axis:
                return None
            if isinstance(e, (tuple, list)):
                kept = tuple(a for a in e if a != axis)
                return kept if kept else None
            return e
        return P(*[keep(e) for e in spec])
    return jax.tree_util.tree_map(fix, specs_tree,
                                  is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: ArchDef, shape_name: str, *, smoke: bool = False,
               mesh=None, cfg_overrides: Optional[Dict] = None,
               opt_hp=None, subnet_E=None, fsdp: bool = True,
               accum: Optional[int] = None, drop_tp: bool = False,
               batch_all: bool = False,
               kv_dtype=jnp.bfloat16, smoke_batch: int = 2) -> Cell:
    cfg = arch.make_smoke() if smoke else arch.make_config()
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = arch.shape(shape_name)
    if smoke:  # reduced-shape smoke variant of the same kind
        shape = dataclasses.replace(
            shape,
            global_batch=min(shape.global_batch, smoke_batch),
            seq_len=min(shape.seq_len, 64) if shape.seq_len else 0,
            img_res=getattr(cfg, "img_res", 0) if shape.img_res else 0,
            steps=min(shape.steps, 4) if shape.steps else 0)
    if accum is None:
        accum = 1 if smoke else ACCUM_DEFAULTS.get((arch.arch_id, shape_name), 1)
    fsdp_axes = BATCH if fsdp else ()
    if arch.family == "lm":
        cell = _lm_cell(arch, cfg, shape, mesh=mesh, fsdp_axes=fsdp_axes,
                        opt_hp=opt_hp, subnet_E=subnet_E, accum=accum,
                        kv_dtype=kv_dtype)
    elif arch.family == "diffusion":
        cell = _diff_cell(arch, cfg, shape, mesh=mesh, fsdp_axes=fsdp_axes,
                          opt_hp=opt_hp, subnet_E=subnet_E, accum=accum,
                          batch_all=batch_all)
    else:
        cell = _vis_cell(
            arch, cfg, shape, mesh=mesh,
            fsdp_axes=fsdp_axes if arch.arch_id == "unet-sdxl" else (),
            opt_hp=opt_hp, subnet_E=subnet_E, accum=accum,
            batch_all=batch_all)
    if drop_tp:
        cell.in_specs = _drop_axis(cell.in_specs, "model")
        cell.out_specs = _drop_axis(cell.out_specs, "model")
    return cell
