"""Trip-count-aware HLO analysis (the dry-run 'profiler').

XLA's ``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE — an
80-layer scanned transformer reports ~1/80th of its FLOPs.  This module
parses the post-optimization HLO text instead and walks the call graph,
multiplying each computation by its execution count (``while`` trip counts
come from ``backend_config={"known_trip_count":...}``).

Counted:
  * flops      — dot + convolution (MXU work; elementwise is memory-bound
                 and shows up in the traffic term instead).  Counted in
                 every computation, including fusion-called ones.
  * traffic    — per-op operand+result bytes, as a post-fusion HBM model:
                 only 'executed' computations (entry, while bodies,
                 conditional branches) contribute; a fusion op counts its
                 operands/results once, with slicing ops capped so a
                 dynamic-slice of a stacked-params tensor doesn't count the
                 whole stack.
  * collectives— result bytes by kind (all-reduce / all-gather / ...).
  * top_ops    — largest contributors, for hillclimbing.

All numbers are per-device (the HLO is the per-partition SPMD program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s4": 1, "u4": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_SKIP_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "while", "call", "conditional", "iota",
                 "after-all", "partition-id", "replica-id", "custom-call",
                 "rng-bit-generator", "convert", "reshape", "broadcast",
                 "compare", "select", "add", "multiply", "subtract", "divide",
                 "maximum", "minimum", "exponential", "tanh", "rsqrt", "sqrt",
                 "negate", "abs", "and", "or", "not", "xor", "clamp", "sign",
                 "floor", "ceil", "log", "log-plus-one", "exponential-minus-one"}
# (bare elementwise ops appear when XLA leaves them unfused; they are tiny
#  next to fusions and skipping them avoids double counting)
_SLICING = {"dynamic-slice", "slice", "gather"}


def _shape_numel_bytes(text: str) -> Tuple[int, int]:
    n_total, b_total = 0, 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        n_total += n
        b_total += n * _DTYPE_BYTES[dt]
    return n_total, b_total


def _dims_of(shape_text: str) -> List[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


class _Instr:
    __slots__ = ("name", "shape", "op", "args", "attrs", "line")

    def __init__(self, name, shape, op, args, attrs, line):
        self.name, self.shape, self.op = name, shape, op
        self.args, self.attrs, self.line = args, attrs, line


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)\(")


def _parse_instr(line: str):
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, shape, op = m.groups()
    rest = line[m.end():]
    depth, i = 1, 0
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    arg_region, attrs = rest[: i - 1], rest[i:]
    args = re.findall(r"%([\w.\-]+)", arg_region)
    return _Instr(name, shape, op, args, attrs, line)


def parse_module(hlo: str) -> Tuple[Dict[str, List[_Instr]], str]:
    comps: Dict[str, List[_Instr]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        hdr = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{$", s)
        if hdr and not s.startswith("//"):
            cur = hdr.group(2)
            comps[cur] = []
            if hdr.group(1):
                entry = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is None or " = " not in s:
            continue
        ins = _parse_instr(s)
        if ins:
            comps[cur].append(ins)
    return comps, entry


def _dot_flops(ins: _Instr, shapes: Dict[str, str]) -> float:
    out_n, _ = _shape_numel_bytes(ins.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if not m or not ins.args:
        return 2.0 * out_n
    dims = _dims_of(shapes.get(ins.args[0], ""))
    k = 1
    for d in (m.group(1).split(",") if m.group(1) else []):
        di = int(d)
        if di < len(dims):
            k *= dims[di]
    return 2.0 * out_n * k


def _conv_flops(ins: _Instr, shapes: Dict[str, str]) -> float:
    out_n, _ = _shape_numel_bytes(ins.shape)
    if len(ins.args) < 2:
        return 2.0 * out_n
    rhs = _dims_of(shapes.get(ins.args[1], ""))
    m = re.search(r"dim_labels=[^,]*_([0-9a-z]+)->", ins.attrs)
    k = 1
    if m and rhs:
        for pos, ch in enumerate(m.group(1)):
            if (ch.isdigit() or ch == "i") and pos < len(rhs):
                k *= rhs[pos]
    return 2.0 * out_n * k


def _instr_traffic(ins: _Instr, shapes: Dict[str, str]) -> float:
    """Post-fusion HBM traffic estimate for one top-level instruction."""
    if ins.op in _SKIP_TRAFFIC:
        return 0.0
    _, out_b = _shape_numel_bytes(ins.shape)
    if ins.op in _SLICING:
        return 2.0 * out_b
    if ins.op == "dynamic-update-slice":
        upd = shapes.get(ins.args[1], "") if len(ins.args) > 1 else ""
        _, ub = _shape_numel_bytes(upd)
        return 2.0 * ub
    if ins.op == "scatter":
        return 2.0 * out_b
    if ins.op == "fusion" and "dynamic-update-slice" in ins.name:
        # in-place scan-stash write: count only the updated slice (the
        # operand(s) smaller than the carried buffer), read+write
        small = 0.0
        for a in ins.args:
            _, ab = _shape_numel_bytes(shapes.get(a, ""))
            if ab < out_b:
                small += ab
        return 2.0 * small
    in_b = 0.0
    kind = re.search(r"kind=k(\w+)", ins.attrs)
    reduction_like = ins.op in ("reduce", "reduce-window", "sort") or (
        ins.op == "fusion" and kind and kind.group(1) == "Input")
    for a in ins.args:
        _, ab = _shape_numel_bytes(shapes.get(a, ""))
        if not reduction_like and ins.op == "fusion":
            # loop fusions touch at most O(out) of each operand (slices of
            # stacked params would otherwise count the whole stack)
            ab = min(ab, 2.0 * out_b)
        in_b += ab
    return out_b + in_b


def analyze_hlo(hlo: str, top_k: int = 12) -> dict:
    comps, entry = parse_module(hlo)

    stats = {}
    exec_edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    fuse_edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for cname, instrs in comps.items():
        shapes = {i.name: i.shape for i in instrs}
        flops = 0.0
        traffic = 0.0
        coll = defaultdict(float)
        per_op = []
        for ins in instrs:
            if ins.op == "dot":
                f = _dot_flops(ins, shapes)
                flops += f
                per_op.append((f, "flops", ins.line[:140]))
            elif ins.op == "convolution":
                f = _conv_flops(ins, shapes)
                flops += f
                per_op.append((f, "flops", ins.line[:140]))
            # call graph
            if ins.op == "while":
                tm = re.search(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)',
                               ins.attrs)
                trip = float(tm.group(1)) if tm else 1.0
                for key, mult in (("body", trip), ("condition", trip)):
                    mm = re.search(rf"{key}=%?([\w.\-]+)", ins.attrs)
                    if mm:
                        exec_edges[cname].append((mm.group(1), mult))
            elif ins.op == "conditional":
                for grp in re.findall(r"branch_computations=\{([^}]*)\}",
                                      ins.attrs):
                    for c in re.findall(r"%?([\w.\-]+)", grp):
                        exec_edges[cname].append((c, 1.0))
            elif ins.op == "call":
                for c in re.findall(r"to_apply=%?([\w.\-]+)", ins.attrs):
                    exec_edges[cname].append((c, 1.0))
            else:
                for key in ("calls", "to_apply"):
                    for c in re.findall(rf"{key}=%?([\w.\-]+)", ins.attrs):
                        fuse_edges[cname].append((c, 1.0))
            # traffic & collectives (per-computation; weighted later)
            t = _instr_traffic(ins, shapes)
            traffic += t
            if t > 0 and ins.op not in ("dot", "convolution"):
                per_op.append((t, "bytes", ins.line[:140]))
            for c in _COLLECTIVES:
                if ins.op == c or ins.op == c + "-start":
                    _, out_b = _shape_numel_bytes(ins.shape)
                    coll[c] += out_b
                    per_op.append((out_b, "coll", ins.line[:140]))
        stats[cname] = {"flops": flops, "traffic": traffic, "coll": coll,
                        "per_op": per_op}

    # execution counts: flops flow through ALL edges; traffic/collectives
    # only through exec edges (fusion-called computations are materialized
    # by their fusion op, already counted at the call site).
    def propagate(edge_sets):
        counts = defaultdict(float)
        stack = [(entry, 1.0)]
        guard = 0
        while stack:
            guard += 1
            if guard > 200000:
                break
            cname, mult = stack.pop()
            counts[cname] += mult
            for edges in edge_sets:
                for callee, m in edges.get(cname, ()):
                    if callee in comps:
                        stack.append((callee, mult * m))
        return counts

    flop_counts = propagate((exec_edges, fuse_edges))
    exec_counts = propagate((exec_edges,))

    total_flops = sum(stats[c]["flops"] * n for c, n in flop_counts.items()
                      if c in stats)
    total_traffic = sum(stats[c]["traffic"] * n for c, n in exec_counts.items()
                        if c in stats)
    coll_tot = defaultdict(float)
    for c, n in exec_counts.items():
        if c not in stats:
            continue
        for k, v in stats[c]["coll"].items():
            coll_tot[k] += v * n

    contributors = []
    for c in stats:
        for val, kind, line in stats[c]["per_op"]:
            n = flop_counts.get(c, 0) if kind == "flops" else exec_counts.get(c, 0)
            if n and val * n > 0:
                contributors.append((val * n, kind, f"x{n:g} {line}"))
    contributors.sort(key=lambda t: -t[0])

    return {
        "flops": total_flops,
        "traffic_bytes": total_traffic,
        "coll_bytes": dict(coll_tot),
        "coll_bytes_total": sum(coll_tot.values()),
        "top_ops": [(round(v, 3), k, l) for v, k, l in contributors[:top_k]],
        "n_computations": len(comps),
    }
