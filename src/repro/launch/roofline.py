"""Roofline extraction from compiled dry-run artifacts.

``cost_analysis()`` provides per-device HLO FLOPs and bytes; collective
bytes are NOT in cost_analysis, so we parse the post-SPMD HLO text and sum
the operand/result sizes of every collective op.  All quantities are
per-device (the HLO is the per-partition program), matching
  collective term = collective_bytes / link_bw
(the brief's /chips with global bytes is the same quantity).
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by each collective kind (result sizes).

    ``-start`` async forms are counted; their ``-done`` halves are skipped.
    Returns {kind: bytes, ..., "total": bytes, "count": n_ops}.
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        if " = " not in line:
            continue
        lhs, rhs = line.split(" = ", 1)
        m = re.match(r"(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
                     r"([a-z0-9-]+)", rhs)
        if not m:
            continue
        ret, op = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        count += 1
        out[kind] += _shape_bytes(ret)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["count"] = count
    return out


def cost_summary(compiled) -> Dict[str, float]:
    """Per-device flops/bytes from compiled.cost_analysis()."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):   # older API returned one dict per computation
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = float(getattr(ma, k, 0) or 0)
    out["per_device_total"] = (out["argument_size_in_bytes"]
                               + out["output_size_in_bytes"]
                               + out["temp_size_in_bytes"]
                               - out["alias_size_in_bytes"])
    return out
