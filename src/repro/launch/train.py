"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Composes the full substrate: config registry -> step builder (pjit) ->
data pipeline -> checkpoint manager -> watchdog/straggler monitor ->
restart supervisor.  ``--smoke`` runs the reduced config end-to-end on
this host; the full configs are meant for the production mesh (see
scripts/launch_pod.sh for the multi-host bring-up with
``jax.distributed.initialize``).

Supernet (sandwich-rule) training for the paper's technique lives in
``--sandwich`` mode: max + min + 2 random sub-networks per step with
in-place distillation (masked mode: one executable).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.core.elastic import sandwich_specs, spec_to_dynamic
from repro.data import Prefetcher, synthetic_image_batches, synthetic_lm_batches
from repro.distributed import use_mesh
from repro.distributed.fault import (SimulatedFailure, StragglerMonitor,
                                     Watchdog, run_with_restarts)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import build_cell
from repro.optim import make_optimizer


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None, help="e.g. train_4k / cls_224")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sandwich", action="store_true",
                    help="sandwich-rule supernet training (paper technique)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--mesh", choices=("host", "pod", "multipod"),
                    default="host")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (tests recovery)")
    ap.add_argument("--coordinator", default=None,
                    help="host:port for jax.distributed.initialize")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_processes,
                                   args.process_id)

    arch = get_arch(args.arch)
    shape_name = args.shape or next(
        n for n, s in arch.shapes.items() if "train" in s.kind)
    mesh = {"host": make_host_mesh,
            "pod": lambda: make_production_mesh(multi_pod=False),
            "multipod": lambda: make_production_mesh(multi_pod=True)}[
        args.mesh]()

    with use_mesh(mesh):
        cell = build_cell(arch, shape_name, smoke=args.smoke, mesh=mesh)
        cfg = cell.cfg
        B = cell.shape.global_batch

        sandwich = None
        if args.sandwich:
            if not arch.arch_id.startswith(("deit", "vit", "dynamic-ofa")):
                raise SystemExit("--sandwich: vision-transformer archs only")
            from repro.core.supernet import make_sandwich_step
            from repro.models.vit import vit_apply
            from repro.optim import make_optimizer as _mo
            _, update_fn = _mo(arch.optimizer)
            dims = {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
                    "n_heads": cfg.n_heads, "n_layers": cfg.n_layers}
            apply_fn = lambda p, b, E: vit_apply(p, b["images"], cfg, E=E)[0]
            s_step, s_sample = make_sandwich_step(apply_fn, update_fn, dims)
            sandwich = (jax.jit(s_step), s_sample, dims)
        step_jit = cell.jit(mesh)

        # data
        if arch.family == "lm":
            def data_at(step):
                return Prefetcher(synthetic_lm_batches(
                    global_batch=B, seq_len=cell.shape.seq_len,
                    vocab=cfg.vocab_size, start_step=step))
        else:
            n_classes = getattr(cfg, "n_classes", 10)
            res = cell.shape.img_res or cfg.img_res

            def data_at(step):
                return Prefetcher(synthetic_image_batches(
                    global_batch=B, img_res=res, n_classes=n_classes,
                    start_step=step))

        manager = CheckpointManager(args.ckpt_dir,
                                    save_every=args.save_every)
        straggler = StragglerMonitor()
        watchdog = Watchdog(timeout_s=600).start()

        init_fn, _ = make_optimizer(arch.optimizer)

        def init_state():
            params = _init_params(arch, cfg)
            return {"params": params, "opt": init_fn(params)}

        def train(start_step, state):
            state = state or init_state()
            params, opt = state["params"], state["opt"]
            data = data_at(start_step)
            rng = np.random.default_rng(start_step)
            for step in range(start_step, args.steps):
                batch = next(data)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                if arch.family == "diffusion":
                    batch = _diffusionize(batch, cfg, step)
                t0 = time.time()
                if args.fail_at is not None and step == args.fail_at:
                    args.fail_at = None  # only once
                    raise SimulatedFailure(f"injected at step {step}")
                if sandwich is not None:
                    s_step, s_sample, _dims = sandwich
                    E_stack = s_sample(cfg.elastic, rng)
                    params, opt, metrics = s_step(
                        params, opt, batch, E_stack, jax.numpy.asarray(step))
                else:
                    params, opt, metrics = step_jit(
                        params, opt, batch, jax.numpy.asarray(step))
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                watchdog.beat()
                if straggler.record(step, dt):
                    print(f"[straggler] step {step} took {dt:.2f}s")
                manager.maybe_save(step, {"params": params, "opt": opt})
                if step % args.log_every == 0:
                    print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                          f"gnorm {float(metrics['gnorm']):.2f} {dt*1e3:.0f}ms")
            manager.wait()
            return {"params": params, "opt": opt}

        state, restarts = run_with_restarts(train, manager=manager)
        watchdog.stop()
        print(f"done: {args.steps} steps, {restarts} restarts, "
              f"straggler flags: {len(straggler.flags)}")
        return state


def _init_params(arch, cfg):
    key = jax.random.PRNGKey(0)
    if arch.family == "lm":
        from repro.models.transformer import lm_init
        return lm_init(key, cfg)
    if arch.family == "diffusion":
        if arch.arch_id.startswith("dit"):
            from repro.models.dit import dit_init
            return dit_init(key, cfg)
        from repro.models.unet import unet_init
        return unet_init(key, cfg)
    if arch.arch_id.startswith(("deit", "vit", "dynamic-ofa")):
        from repro.models.vit import vit_init
        return vit_init(key, cfg)
    if arch.arch_id.startswith("resnet"):
        from repro.models.resnet import resnet_init
        return resnet_init(key, cfg)
    from repro.models.efficientnet import effnet_init
    return effnet_init(key, cfg)


def _diffusionize(batch, cfg, step):
    """Vision batch -> diffusion batch (latents + noise + t + cond)."""
    import jax.numpy as jnp
    rng = np.random.default_rng((7, step))
    imgs = batch["images"]
    B = imgs.shape[0]
    res = getattr(cfg, "latent_res", imgs.shape[1] // 8)
    lat = rng.normal(size=(B, res, res, 4)).astype(np.float32)
    out = {"latents": jnp.asarray(lat),
           "noise": jnp.asarray(rng.normal(size=lat.shape).astype(np.float32)),
           "t": jnp.asarray(rng.integers(0, 1000, B).astype(np.int32))}
    if hasattr(cfg, "ctx_dim"):
        out["cond"] = {
            "ctx": jnp.asarray(rng.normal(
                size=(B, 77, cfg.ctx_dim)).astype(np.float32)),
            "pooled": jnp.asarray(rng.normal(
                size=(B, cfg.pooled_dim)).astype(np.float32))}
    else:
        out["cond"] = {"y": batch["labels"]}
    return out


if __name__ == "__main__":
    main()
