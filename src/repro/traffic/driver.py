"""Open-loop SLO-classed load drivers over the resource arbiter.

Two drivers share the same classes/arrivals/report types:

* :func:`simulate` — a deterministic discrete-event driver in virtual
  time.  Service times come from each workload's arbitrated
  :class:`OpPoint` latency through a **batching-aware service model**
  (ROADMAP item): queued requests are served in batches of up to the
  class's ``max_batch``, and one batch of ``k`` requests costs the
  power-of-two *bucket* latency for ``k`` (``service_model="bucketed"``,
  mirroring the engine's bucketed data path) or the full pad-to-max
  latency regardless of occupancy (``service_model="padded"``, the
  baseline the benchmarks compare against).  The run exercises the REAL
  arbiter code (admission_check, water-filling, preempt, set_active with
  queue depth + arrival-rate EWMA) without touching a clock or a jit
  cache — policy comparisons are exactly reproducible from the arrival
  seeds.
* :func:`drive_live` — wall-clock submission of real requests to
  :class:`DynamicServer` instances behind a started arbiter
  (``launch/serve.py --trace``).

Policies:

* ``"slo"``  — admission control at registration, per-request shedding
  for SHED classes, and mid-cycle :meth:`ResourceArbiter.preempt` when a
  request arrives for a class holding no slice;
* ``"fifo"`` — the no-admission baseline: every class admitted at equal
  priority (arbitration ties break by registration = arrival order), no
  shedding, and arrivals wait for the next constraint-clock tick.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import queue
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry, quantile
from repro.runtime.arbiter import (AdmissionError, GlobalConstraints,
                                   ResourceArbiter)
from repro.runtime.engine import DynamicServer
from repro.runtime.lut import LUT, bucket_for, bucket_latency_ms
from repro.traffic import arrivals as arr
from repro.traffic.slo import DEGRADE, SHED, SLOClass

SLO_POLICY = "slo"
FIFO_POLICY = "fifo"
POLICIES = (SLO_POLICY, FIFO_POLICY)

# service models for simulate(): how a batch of k queued requests is priced
BUCKETED_SERVICE = "bucketed"   # nearest power-of-two bucket latency
PADDED_SERVICE = "padded"       # always the full pad-to-max latency
SERVICE_MODELS = (BUCKETED_SERVICE, PADDED_SERVICE)


@dataclasses.dataclass
class ClassStats:
    """Per-class accounting: every submitted request ends in exactly one
    of rejected / dropped / failed / completed (+ pending if the sim is
    cut off)."""
    submitted: int = 0
    rejected: int = 0      # admission-rejected class
    dropped: int = 0       # shed on arrival (or unserved at horizon)
    failed: int = 0        # resolved with an error payload (node fail-stop)
    completed: int = 0
    good: int = 0          # completed within the deadline
    batches: int = 0       # serving batches dispatched (sim service model)
    batch_occupancy: int = 0   # requests summed over those batches
    retried: int = 0       # failed attempts re-submitted (reliability layer)
    hedge_wasted: int = 0  # hedge copies whose sibling answered first
    latencies_ms: List[float] = dataclasses.field(default_factory=list)

    @property
    def goodput(self) -> int:
        return self.good

    @property
    def mean_batch(self) -> float:
        """Mean serving-batch occupancy (0.0 when nothing was batched)."""
        return self.batch_occupancy / self.batches if self.batches else 0.0

    def p(self, q: float) -> float:
        return quantile(self.latencies_ms, q)

    def summary(self) -> dict:
        out = {"submitted": self.submitted, "rejected": self.rejected,
               "dropped": self.dropped, "failed": self.failed,
               "completed": self.completed,
               "goodput": self.good,
               "goodput_rate": round(self.good / self.submitted, 4)
               if self.submitted else 0.0,
               "mean_batch": round(self.mean_batch, 3)}
        if self.retried or self.hedge_wasted:
            out["retried"] = self.retried
            out["hedge_wasted"] = self.hedge_wasted
        for q in (50, 95, 99):
            # None (not NaN) when nothing completed: NaN != NaN breaks
            # report equality for deterministic-replay checks
            out[f"p{q}_ms"] = (round(self.p(q), 3)
                               if self.latencies_ms else None)
        return out


@dataclasses.dataclass
class TrafficReport:
    """What one driver run measured, per class + the arbiter's view."""
    policy: str
    classes: Dict[str, ClassStats]
    arbiter: dict = dataclasses.field(default_factory=dict)
    # retry-budget accounting when a reliability layer ran (else empty)
    reliability: dict = dataclasses.field(default_factory=dict)

    @property
    def total_goodput(self) -> int:
        return sum(s.good for s in self.classes.values())

    @property
    def total_dropped(self) -> int:
        return sum(s.dropped for s in self.classes.values())

    def summary(self) -> dict:
        out = {"policy": self.policy,
               "total_goodput": self.total_goodput,
               "total_dropped": self.total_dropped,
               "classes": {n: s.summary()
                           for n, s in self.classes.items()},
               "arbiter": self.arbiter}
        if self.reliability:
            out["reliability"] = self.reliability
        return out


def _register_classes(arbiter: ResourceArbiter, classes: Sequence[SLOClass],
                      luts: Dict[str, LUT], policy: str,
                      g0: GlobalConstraints,
                      servers: Optional[Dict[str, DynamicServer]] = None
                      ) -> Dict[str, bool]:
    """Admission phase.  Returns admitted[name]; under "slo", a class whose
    minimal share can never fit is rejected (REJECT/SHED) or re-admitted
    with its relaxed DEGRADE target; "fifo" admits everything at equal
    priority, in arrival order."""
    admitted: Dict[str, bool] = {}
    for c in classes:
        server = (servers or {}).get(c.name)
        if policy == FIFO_POLICY:
            arbiter.register(c.name, luts[c.name],
                             target_latency_ms=c.service_target_ms,
                             priority=0, server=server)
            admitted[c.name] = True
            continue
        try:
            arbiter.register(c.name, luts[c.name],
                             target_latency_ms=c.service_target_ms,
                             priority=c.priority,
                             min_accuracy=c.min_accuracy,
                             server=server, admission_under=g0)
            admitted[c.name] = True
        except AdmissionError:
            if c.drop_policy == DEGRADE:
                # never drop: serve best-effort against the relaxed target
                arbiter.register(c.name, luts[c.name],
                                 target_latency_ms=c.degraded_target_ms,
                                 priority=c.priority, server=server)
                admitted[c.name] = True
            else:
                admitted[c.name] = False
    return admitted


def _service_ms(full_ms: float, occupancy: int, max_batch: int,
                service_model: str, *, spec=None, calibration=None) -> float:
    """Cost of one serving batch of ``occupancy`` requests.

    The LUT point latency is the profiled pad-to-max (full batch) cost;
    the bucketed model pays only the nearest power-of-two bucket, the
    padded baseline always pays the full forward.  With a warmed
    :class:`repro.runtime.telemetry.CalibrationStore` (and the point's
    ``spec`` to key it) the bucket cost is the MEASURED dispatch→ready
    EWMA blended over that analytic prior — a replayed trace then
    predicts with the numbers the live engine actually observed.
    """
    if service_model == PADDED_SERVICE:
        return full_ms
    return bucket_latency_ms(full_ms, bucket_for(occupancy, max_batch),
                             max_batch, calibration=calibration, spec=spec)


def simulate(classes: Sequence[SLOClass], luts: Dict[str, LUT],
             streams: Dict[str, Sequence[float]],
             g_fn: Callable[[float], GlobalConstraints], *,
             interval_s: float = 0.1, policy: str = SLO_POLICY,
             service_model: str = BUCKETED_SERVICE,
             max_drain_s: float = 120.0,
             calibration=None, tracer=None,
             metrics: Optional[MetricsRegistry] = None) -> TrafficReport:
    """Deterministic discrete-event run of a traffic trace.

    Virtual time advances in constraint-clock epochs of ``interval_s``.
    Each epoch: (1) idle classes release their slice and the arbiter
    re-water-fills, fed each class's queue depth + arrival-rate EWMA so
    surplus chips go to the most backlogged tenant; (2) the epoch's
    arrivals are admitted / shed / preempt-served in timestamp order;
    (3) each workload serves its queue in batches of up to its class's
    ``max_batch`` — one batch of ``k`` requests costs the bucket latency
    for ``k`` under ``service_model="bucketed"`` or the full pad-to-max
    latency under ``"padded"``.  A batch locks in the service time
    current when it starts.

    ``calibration`` (a warmed :class:`repro.runtime.telemetry
    .CalibrationStore`, typically recorded by :func:`drive_live`) makes
    the replay CLOSED-LOOP: the arbiter water-fills on calibrated point
    latencies and measured tenant watts, and every batch is priced by
    the measured per-bucket EWMA instead of the analytic bucket model —
    so a recorded trace predicts the live system with measured numbers.

    ``tracer`` (a :class:`repro.obs.Tracer` built on a virtual clock)
    records the SAME span schema the live engine emits — queue /
    collect / stack / dispatch / device / complete per request plus
    arbitrate/preempt decision spans — in virtual time; host-side
    stages are zero-width points (the service model folds them into
    ``device``).  ``metrics`` receives per-class completion counters.
    """
    assert policy in POLICIES, policy
    assert service_model in SERVICE_MODELS, service_model
    by_class = {c.name: c for c in classes}
    stats = {c.name: ClassStats() for c in classes}
    m = metrics if metrics is not None else MetricsRegistry()
    completed = {c.name: m.counter("traffic_completed_total", cls=c.name)
                 for c in classes}
    arbiter = ResourceArbiter(interval_s=interval_s,
                              calibration=calibration)
    admitted = _register_classes(arbiter, classes, luts, policy, g_fn(0.0))

    events = arr.merge({n: ts for n, ts in streams.items()})
    queues = {c.name: collections.deque() for c in classes}  # repro: allow-unbounded(per-class work queue, drained every epoch; depth IS the backlog signal)
    busy_until = {c.name: 0.0 for c in classes}
    arrived_epoch = {c.name: 0 for c in classes}   # arrivals last epoch
    last_arrival = events[-1][0] if events else 0.0

    def svc_of(allocs):
        # the granted OpPoint (not just its latency): the calibrated
        # service model needs the subnet spec to key the measured columns
        return {n: a.point for n, a in allocs.items()}

    ei = 0
    t = 0.0
    while True:
        backlog = any(queues.values()) or ei < len(events)
        in_flight = any(b > t for b in busy_until.values())
        if not backlog and not in_flight:
            break
        if t > last_arrival + max_drain_s:
            break   # safety: leftover queue flushed as dropped below
        g = g_fn(t)
        for name in queues:
            if admitted[name]:
                arbiter.set_active(
                    name, bool(queues[name]) or busy_until[name] > t,
                    queue_depth=len(queues[name]),
                    arrival_rate_rps=arrived_epoch[name] / interval_s)
            arrived_epoch[name] = 0
        allocs = arbiter.tick(g)
        svc = svc_of(allocs)
        if tracer is not None:
            tracer.decision(obs.ARBITRATE, t, t,
                            tenants=len(allocs),
                            granted=sum(a.chips for a in allocs.values()))
        t_next = t + interval_s

        while ei < len(events) and events[ei][0] < t_next:
            ta, name = events[ei]
            ei += 1
            c = by_class[name]
            st = stats[name]
            st.submitted += 1
            arrived_epoch[name] += 1
            if not admitted[name]:
                st.rejected += 1
                continue
            if policy == SLO_POLICY and svc.get(name) is None:
                # arrival for a class holding no slice: preempt NOW — the
                # eviction of lower-priority tenants must not wait for the
                # next constraint clock tick
                arbiter.preempt(name, g_fn(ta))
                allocs = arbiter.last_allocations()
                svc = svc_of(allocs)
                if tracer is not None:
                    tracer.decision(obs.PREEMPT, ta, ta, for_cls=name)
            if (policy == SLO_POLICY and c.drop_policy == SHED
                    and svc.get(name) is not None):
                # predicted completion: in-flight remainder, then the queue
                # plus this request drained in batches priced by the active
                # service model at the estimated occupancy (the arrival
                # JOINS a batch — don't double-count its service)
                q_len = len(queues[name])
                occ = min(q_len + 1, c.max_batch)
                batch_ms = _service_ms(svc[name].latency_ms, occ,
                                       c.max_batch, service_model,
                                       spec=svc[name].subnet,
                                       calibration=calibration)
                n_batches = math.ceil((q_len + 1) / c.max_batch)
                eta_ms = (max(0.0, busy_until[name] - ta) * 1e3
                          + n_batches * batch_ms)
                if eta_ms > c.deadline_ms:
                    st.dropped += 1   # predicted miss: shed on arrival
                    continue
            queues[name].append(ta)

        for name, q in queues.items():
            pt = svc.get(name)
            if pt is None:
                continue   # starved this epoch; queue waits
            c = by_class[name]
            st = stats[name]
            while q:
                # clamp to t: a leftover request from a starved epoch can
                # start no earlier than the tick that granted the slice
                start = max(q[0], busy_until[name], t)
                if start >= t_next:
                    break
                # batch everything already waiting at the start instant
                k = 0
                for ta in q:
                    if ta <= start and k < c.max_batch:
                        k += 1
                    else:
                        break
                k = max(k, 1)
                done = start + _service_ms(pt.latency_ms, k, c.max_batch,
                                           service_model, spec=pt.subnet,
                                           calibration=calibration) / 1e3
                busy_until[name] = done
                st.batches += 1
                st.batch_occupancy += k
                completed[name].inc(k)
                if tracer is not None:
                    dev_attrs = {
                        "bucket": bucket_for(k, c.max_batch), "n": k,
                        "subnet": (pt.subnet.name()
                                   if hasattr(pt.subnet, "name")
                                   else str(pt.subnet))}
                for _ in range(k):
                    ta = q.popleft()
                    lat_ms = (done - ta) * 1e3
                    st.completed += 1
                    st.latencies_ms.append(lat_ms)
                    if lat_ms <= c.deadline_ms:
                        st.good += 1
                    if tracer is not None:
                        # same schema as the live engine, virtual time;
                        # host-side stages are zero-width (the service
                        # model folds them into `device`)
                        tracer.request(name, ta, done, spans=[
                            (obs.QUEUE, ta, start, None),
                            (obs.COLLECT, start, start, None),
                            (obs.STACK, start, start, None),
                            (obs.DISPATCH, start, start, None),
                            (obs.DEVICE, start, done, dev_attrs),
                            (obs.COMPLETE, done, done, None)])
        t = t_next

    for name, q in queues.items():
        stats[name].dropped += len(q)   # never served within the horizon
        q.clear()
    return TrafficReport(policy=policy, classes=stats,
                         arbiter=arbiter.summary())


def _drain_reliable(pending, by_class, servers, make_input, stats,
                    reliability, t0: float, timeout_s: float):
    """Reliability-aware drain loop for :func:`drive_live`.

    Polls outstanding futures; a FAILED attempt (error payload from a
    fail-stopped node) is re-submitted through the cluster router after
    its class's backoff — but only while the policy's attempt cap, the
    cluster-wide retry budget, and the request's own deadline all still
    allow it (a retry that could not land before the SLO deadline is
    wasted work on a degraded cluster).  The retry's span tree links to
    the first failed attempt's trace_id.  Returns the final
    ``(name, future)`` list for the normal harvest loop — each arrival
    contributes exactly one terminal future, so the accounting invariant
    (submitted == rejected+dropped+failed+completed) is untouched.
    """
    budget = reliability.budget.fresh()
    # entry: [name, fut-or-None, t_sub, attempts, retry_at, first_tid]
    live = [[name, fut, t_sub, 1, 0.0, None]
            for name, fut, t_sub in pending]
    final: List = []
    completed_seen = 0
    deadline = time.perf_counter() + timeout_s
    while live and time.perf_counter() < deadline:
        nxt: List = []
        for entry in live:
            name, fut, t_sub, attempts, retry_at, first_tid = entry
            now = time.perf_counter() - t0
            if fut is None:               # parked for backoff
                if now < retry_at:
                    nxt.append(entry)
                    continue
                links = [first_tid] if first_tid is not None else []
                nf = (servers[name].submit(make_input(name), links=links)
                      if links else servers[name].submit(make_input(name)))
                nxt.append([name, nf, t_sub, attempts, 0.0, first_tid])
                continue
            if fut.empty():
                nxt.append(entry)
                continue
            out = fut.get()
            if out.get("cancelled") and out.get("failed"):
                pol = reliability.policy_for(name)
                c = by_class[name]
                t_retry = now + pol.backoff(attempts)
                if (attempts < pol.max_attempts
                        and t_retry <= t_sub + c.deadline_ms / 1e3
                        and budget.allow(completed_seen)):
                    stats[name].retried += 1
                    tid = getattr(fut, "trace_id", None)
                    nxt.append([name, None, t_sub, attempts + 1, t_retry,
                                first_tid if first_tid is not None else tid])
                    continue
            if not out.get("cancelled"):
                completed_seen += 1
            fut.put(out)                  # hand back to the harvest loop
            final.append((name, fut))
        live = nxt
        time.sleep(0.005)
    for name, fut, *_ in live:            # timed out mid-flight / parked
        if fut is None:
            fut = _dead_live_future("retry window expired")
        final.append((name, fut))
    return final, budget


def _dead_live_future(reason: str) -> "queue.Queue":
    fut: "queue.Queue" = queue.Queue(maxsize=1)
    fut.put({"y": None, "cancelled": True, "failed": True,
             "error": reason, "latency_ms": 0.0, "subnet": None})
    return fut


class _WatchtowerFeed:
    """Wall-clock feeder for :class:`repro.obs.Watchtower` inside
    :func:`drive_live`: periodically sweeps the outstanding futures
    without consuming them (peek + put-back, the `_drain_reliable`
    idiom), classifies newly-resolved ones against their class deadline,
    feeds the watchtower one delta sample, evaluates, and forwards the
    per-class alert pressure to the arbiter/cluster — the live mirror
    of the simulator's per-epoch actuation hook."""

    def __init__(self, wt, arbiter, by_class, t0: float):
        self.wt = wt
        self.arbiter = arbiter
        self.by_class = by_class
        self.t0 = t0
        self.interval = max(0.05, min(w.short_s for w in wt.windows) / 2.0)
        self._seen: set = set()
        self._last = 0.0

    def sweep(self, pending, force: bool = False):
        now = time.perf_counter() - self.t0
        if not force and now - self._last < self.interval:
            return
        self._last = now
        delta = {cn: [0, 0] for cn in self.by_class}
        for i, (name, fut, _t_sub) in enumerate(pending):
            if i in self._seen or fut is None or fut.empty():
                continue
            try:
                out = fut.get_nowait()
            except Exception:   # raced with the harvest loop
                continue
            fut.put(out)
            self._seen.add(i)
            if out.get("cancelled"):
                good = 0
            else:
                good = int(out["latency_ms"]
                           <= self.by_class[name].deadline_ms)
            delta[name][0] += good
            delta[name][1] += 1 - good
        for cn, (g, b) in delta.items():
            if cn in self.wt.targets:
                self.wt.observe(now, cn, good=g, bad=b)
        self.wt.evaluate(now)
        if self.wt.actuate and hasattr(self.arbiter, "set_alert_pressure"):
            for cn in self.wt.targets:
                self.arbiter.set_alert_pressure(cn, self.wt.pressure(cn))


def drive_live(classes: Sequence[SLOClass],
               servers: Dict[str, DynamicServer],
               arbiter: ResourceArbiter,
               streams: Dict[str, Sequence[float]],
               make_input: Callable[[str], object], *,
               g_fn: Callable[[], GlobalConstraints],
               speed: float = 1.0, timeout_s: float = 120.0,
               record_path: Optional[str] = None, tracer=None,
               reliability=None, watchtower=None,
               metrics: Optional[MetricsRegistry] = None) -> TrafficReport:
    """Wall-clock open-loop driver: real requests to real servers.

    Classes must already be registered on ``arbiter`` with their servers
    (see ``_register_classes`` / ``launch.serve --trace``).  ``speed`` > 1
    compresses the arrival schedule; deadlines stay in real ms.  The
    arbiter clock runs for the duration and is stopped (draining the
    servers) before the report is built, so every future resolves.

    ``arbiter``/``servers`` may equally be a :class:`repro.cluster.Cluster`
    and its class ports — the duck interface is start/stop/summary and
    per-class ``.submit``.

    ``record_path`` writes the ACTUAL per-class submission times (not the
    planned schedule — sleep overshoot and submit cost shift them) as a
    multi-stream schedule JSON, so a real run becomes a regression trace:
    ``load_schedule`` feeds it back to :func:`simulate` (bit-identical
    replay) or ``launch.serve --trace <file>``.

    ``reliability`` (a :class:`repro.chaos.Reliability`) turns on the
    retry layer: failed attempts (fail-stopped replicas, chaos kills)
    are re-routed through the cluster with per-class backoff, capped by
    the policy's attempt limit, the cluster-wide retry budget, and the
    request's own deadline; retries count in ``ClassStats.retried`` and
    their span trees link to the first attempt.  (Hedging is a
    virtual-time feature — see :func:`repro.cluster.sim.simulate_cluster`.)

    ``watchtower`` (a :class:`repro.obs.Watchtower`) runs the SLO burn
    monitors against the live outcomes as they resolve: resolved futures
    are classified against their class deadline, fed as delta samples on
    the wall clock, and — when the watchtower actuates — the per-class
    alert pressure is forwarded to ``arbiter.set_alert_pressure`` (a
    plain arbiter or a :class:`repro.cluster.Cluster` alike).  The same
    instance fed by the simulator fires the same alerts.
    """
    by_class = {c.name: c for c in classes}
    stats = {c.name: ClassStats() for c in classes}
    if tracer is not None or metrics is not None:
        # wire observability down the stack: the engines emit the request
        # span trees themselves, the arbiter its arbitrate/preempt spans
        if tracer is not None and hasattr(arbiter, "tracer"):
            arbiter.tracer = tracer
        for server in servers.values():
            if tracer is not None:
                server.tracer = tracer
            if metrics is not None:
                server.metrics = metrics
    events = arr.merge({n: ts for n, ts in streams.items()})
    pending: List = []
    recorded: Dict[str, List[float]] = {c.name: [] for c in classes}
    arbiter.start(g_fn)
    try:
        t0 = time.perf_counter()
        feed = (_WatchtowerFeed(watchtower, arbiter, by_class, t0)
                if watchtower is not None else None)
        for ta, name in events:
            wait = ta / speed - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(wait)
            now = time.perf_counter() - t0
            recorded[name].append(now)
            pending.append((name, servers[name].submit(make_input(name)),
                            now))
            if feed is not None:
                feed.sweep(pending)
        rel_info: dict = {}
        if reliability is not None:
            pending, budget = _drain_reliable(
                pending, by_class, servers, make_input, stats,
                reliability, t0, timeout_s)
            pending = [(name, fut, 0.0) for name, fut in pending]
            rel_info = {"retry_granted": budget.granted,
                        "retry_denied": budget.denied}
        else:
            # wait for the fleet to drain; a starved server's requests may
            # never run — arbiter.stop() below cancels them so no get()
            # hangs
            deadline = time.perf_counter() + timeout_s
            while (time.perf_counter() < deadline
                   and any(fut.empty() for _, fut, _ in pending)):
                if feed is not None:
                    feed.sweep(pending)
                time.sleep(0.02)
        if feed is not None:
            # terminal sample: whatever resolved since the last sweep
            feed.sweep(pending, force=True)
    finally:
        arbiter.stop()
    if record_path is not None:
        arr.save_schedule(record_path, recorded,
                          meta={"kind": "drive_live", "speed": speed,
                                "classes": [c.name for c in classes]})
    for name, fut, _ in pending:
        st = stats[name]
        st.submitted += 1
        try:
            out = fut.get(timeout=5.0)
        except Exception:   # still in flight past the drain: count it lost
            st.dropped += 1
            continue
        if out.get("cancelled"):
            # a fail-stopped node's error payloads are failures, not load
            # shedding — same split the cluster simulator reports
            if out.get("failed"):
                st.failed += 1
            else:
                st.dropped += 1
            continue
        lat = out["latency_ms"]
        st.completed += 1
        st.latencies_ms.append(lat)
        if lat <= by_class[name].deadline_ms:
            st.good += 1
    return TrafficReport(policy="live", classes=stats,
                         arbiter=arbiter.summary(), reliability=rel_info)
