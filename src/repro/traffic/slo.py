"""SLO classes: the traffic layer's contract with the runtime manager.

An :class:`SLOClass` states what a request stream needs (an end-to-end
deadline), how important it is (arbitration priority), and what to do
when the machine can't keep up (drop policy).  It maps onto the runtime
layer's :class:`~repro.runtime.governor.Constraints` by reserving part of
the deadline for queueing: the arbiter plans service time against
``service_frac * deadline`` so a request that waits a little still
replies in time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.runtime.governor import Constraints

# Drop policies — what happens when the class's minimal feasible share
# cannot fit (admission) or a request is predicted to miss (shedding):
REJECT = "reject"     # admission-reject the whole class when infeasible
SHED = "shed"         # admit, but shed requests predicted to miss
DEGRADE = "degrade"   # never drop: relax the target and serve late
DROP_POLICIES = (REJECT, SHED, DEGRADE)


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One request class with a service-level objective.

    ``deadline_ms`` bounds submit->reply; ``priority`` feeds the arbiter's
    water-filling (and preemption) order; ``drop_policy`` picks the
    overload behaviour above.  ``service_frac`` is the fraction of the
    deadline budgeted for pure service — the rest absorbs queueing.
    ``max_batch`` is the class's serving batch ceiling: the batching-aware
    service model amortises one bucket-sized forward over up to this many
    queued requests (mirrors ``DynamicServer(max_batch=...)``).
    """
    name: str
    deadline_ms: float
    priority: int = 0
    drop_policy: str = SHED
    min_accuracy: Optional[float] = None
    service_frac: float = 0.5
    degrade_factor: float = 4.0   # DEGRADE: relaxed-target multiplier
    max_batch: int = 8            # serving batch ceiling (bucket ladder top)

    def __post_init__(self):
        if self.deadline_ms <= 0:
            raise ValueError(f"{self.name}: deadline_ms must be > 0")
        if self.drop_policy not in DROP_POLICIES:
            raise ValueError(f"{self.name}: drop_policy "
                             f"{self.drop_policy!r} not in {DROP_POLICIES}")
        if not 0.0 < self.service_frac <= 1.0:
            raise ValueError(f"{self.name}: service_frac must be in (0, 1]")
        if self.max_batch < 1:
            raise ValueError(f"{self.name}: max_batch must be >= 1")

    @property
    def service_target_ms(self) -> float:
        """The latency target handed to the arbiter/governor."""
        return self.deadline_ms * self.service_frac

    @property
    def degraded_target_ms(self) -> float:
        """Fallback target when a DEGRADE class fails admission."""
        return self.service_target_ms * self.degrade_factor

    def constraints(self, *, chips_available: int,
                    power_budget_w: Optional[float] = None,
                    temperature_throttle: float = 1.0,
                    share: float = 1.0) -> Constraints:
        """This class's SLO phrased as single-workload Constraints."""
        return Constraints(target_latency_ms=self.service_target_ms,
                           chips_available=chips_available,
                           power_budget_w=power_budget_w,
                           min_accuracy=self.min_accuracy,
                           temperature_throttle=temperature_throttle,
                           priority=self.priority, share=share)
