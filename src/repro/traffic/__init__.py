"""Traffic layer: SLO-classed request streams over the runtime arbiter.

The paper's Fig. 1 stacks three layers; this package is the top one and
closes the loop with the other two:

* **application layer** — the paper's "multiple concurrent workloads"
  with "dynamically changing performance targets" become request
  *streams*: seeded arrival processes (:mod:`~repro.traffic.arrivals` —
  Poisson, bursty ON-OFF, diurnal ramp, trace replay) tagged with an
  :class:`~repro.traffic.slo.SLOClass` (deadline, priority, drop
  policy);
* **runtime resource management layer** — each class's SLO maps onto the
  :class:`~repro.runtime.governor.Constraints` that the
  :class:`~repro.runtime.arbiter.ResourceArbiter` water-fills; arriving
  load exercises the arbiter's admission control (an infeasible class is
  rejected at registration) and priority preemption (a high-priority
  arrival evicts lower-priority slices mid-cycle, not at the next
  constraint-clock tick);
* **hardware layer** — requests are ultimately served by
  :class:`~repro.runtime.engine.DynamicServer` executables over the
  modelled v5e (chips x DVFS) states profiled in the LUTs.

The drivers (:mod:`~repro.traffic.driver`) run the same classes either
through a deterministic virtual-time simulation (policy comparisons,
benchmarks) or against live servers (``launch/serve.py --trace``), and
report per-class p50/p95/p99 latency, goodput and drops in a
:class:`~repro.traffic.driver.TrafficReport`.
"""
from repro.traffic.arrivals import (diurnal, load_schedule, merge, onoff,
                                    poisson, replay, save_schedule)
from repro.traffic.slo import (DEGRADE, DROP_POLICIES, REJECT, SHED,
                               SLOClass)
from repro.traffic.driver import (BUCKETED_SERVICE, FIFO_POLICY,
                                  PADDED_SERVICE, SERVICE_MODELS, SLO_POLICY,
                                  ClassStats, TrafficReport, drive_live,
                                  simulate)
