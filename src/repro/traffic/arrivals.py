"""Seeded, deterministic arrival-process generators.

Every generator maps (parameters, seed) -> a sorted array of arrival
timestamps in seconds; the same seed always yields the identical
inter-arrival sequence (asserted in tests), so SLO-policy comparisons run
on byte-identical traces.  Four processes cover the paper's
phase-changing workload conditions:

* :func:`poisson`  — memoryless steady load;
* :func:`onoff`    — bursty interrupted-Poisson (ON windows at full rate,
  OFF windows silent or trickling), the worst case for a clock-driven
  arbiter and the one preemption exists for;
* :func:`diurnal`  — sinusoidal ramp via thinning, the slow phase change
  a day of user traffic produces;
* :func:`replay`   — trace replay from a recorded schedule (list or JSON
  file written by :func:`save_schedule`).
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Sequence, Tuple, Union

import numpy as np


def poisson(rate_rps: float, horizon_s: float, *, seed: int = 0
            ) -> np.ndarray:
    """Homogeneous Poisson arrivals: exponential inter-arrival times."""
    if rate_rps <= 0 or horizon_s <= 0:
        return np.empty(0)
    rng = np.random.default_rng(seed)
    ts: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_rps)
        if t >= horizon_s:
            break
        ts.append(t)
    return np.asarray(ts)


def onoff(rate_rps: float, horizon_s: float, *, on_s: float = 1.0,
          off_s: float = 1.0, off_rate_rps: float = 0.0, seed: int = 0
          ) -> np.ndarray:
    """Bursty ON-OFF arrivals (interrupted Poisson process).

    Alternating windows: ON at ``rate_rps`` for ``on_s`` seconds, OFF at
    ``off_rate_rps`` (default silent) for ``off_s``.  One rng drawn
    sequentially across windows keeps the whole trace seed-deterministic.
    """
    rng = np.random.default_rng(seed)
    ts: List[float] = []
    t0 = 0.0
    on = True
    while t0 < horizon_s:
        span = on_s if on else off_s
        rate = rate_rps if on else off_rate_rps
        if rate > 0:
            t = t0
            while True:
                t += rng.exponential(1.0 / rate)
                if t >= min(t0 + span, horizon_s):
                    break
                ts.append(t)
        t0 += span
        on = not on
    return np.asarray(ts)


def diurnal(peak_rps: float, horizon_s: float, *, period_s: float = 60.0,
            floor: float = 0.1, seed: int = 0) -> np.ndarray:
    """Sinusoidal ramp via thinning: rate(t) sweeps floor..1 x peak.

    rate(t) = peak * (floor + (1 - floor) * (1 - cos(2*pi*t/period)) / 2)
    — starts at the floor, peaks mid-period.  Thinning a peak-rate Poisson
    stream keeps determinism exact.
    """
    rng = np.random.default_rng(seed)
    ts: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / peak_rps)
        if t >= horizon_s:
            break
        frac = floor + (1.0 - floor) * 0.5 * (
            1.0 - np.cos(2.0 * np.pi * t / period_s))
        if rng.uniform() < frac:
            ts.append(t)
    return np.asarray(ts)


def replay(schedule: Union[str, Sequence[float]]) -> np.ndarray:
    """Trace replay: a recorded schedule (sequence of seconds, or a JSON
    path written by :func:`save_schedule`) becomes an arrival stream."""
    if isinstance(schedule, str):
        loaded = load_schedule(schedule)
        if isinstance(loaded, dict):
            raise ValueError(
                f"{schedule}: multi-stream schedule; pass one of its "
                f"streams ({sorted(loaded)}) to replay()")
        return loaded
    ts = np.asarray(list(schedule), dtype=float)
    return np.sort(ts)


def save_schedule(path: str,
                  arrivals: Union[Sequence[float],
                                  Dict[str, Sequence[float]]], *,
                  meta: dict = None) -> None:
    """Record a schedule for later replay (the ``--trace`` file format).

    ``arrivals`` is one stream (sequence of seconds) or a dict of
    per-class streams — what ``drive_live(record_path=...)`` records.
    JSON floats round-trip exactly, so a replayed schedule is
    bit-identical to the recorded one.
    """
    if isinstance(arrivals, dict):
        payload = {"streams": {name: [float(t) for t in ts]
                               for name, ts in arrivals.items()},
                   "meta": meta or {}}
    else:
        payload = {"arrival_s": [float(t) for t in arrivals],
                   "meta": meta or {}}
    with open(path, "w") as f:
        json.dump(payload, f)


def load_schedule(path: str) -> Union[np.ndarray, Dict[str, np.ndarray]]:
    """Load a recorded schedule: an array for single-stream files, a
    ``{class: array}`` dict for multi-stream recordings."""
    with open(path) as f:
        d = json.load(f)
    if "streams" in d:
        return {name: np.sort(np.asarray(ts, dtype=float))
                for name, ts in d["streams"].items()}
    return np.sort(np.asarray(d["arrival_s"], dtype=float))


def merge(streams: Dict[str, Iterable[float]]) -> List[Tuple[float, str]]:
    """Merge per-class streams into one (t, class_name) order of events."""
    events = [(float(t), name) for name, ts in streams.items() for t in ts]
    events.sort()
    return events
