"""Profiled lookup table: (sub-network x hardware state) -> cost.

The paper's runtime manager works from profiled Pareto tables (its Fig. 1
"runtime resource management" layer consults algorithm and hardware knobs
jointly).  Two profile sources:

* ``model_lut``    — roofline-modelled from per-subnet analytic FLOPs/bytes,
  anchored to the dry-run's compiled roofline terms for the full network
  (CPU-only container; v5e is the target — see DESIGN.md §2).
* ``measured_lut`` — wall-clock measurement of sliced-subnet executables
  (used by the examples/benchmarks on the small supernet, where real time
  on this host is meaningful).

Accuracy per subnet: measured where we train (examples), otherwise a
surrogate fitted to the published OFA ImageNet Pareto points (Cai et al.
2020, table 1), declared as modelled in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.pareto import OpPoint
from repro.core.types import SubnetSpec
from repro.runtime import hwmodel as hm

# Published OFA ImageNet points (MFLOPs, top-1 %) — accuracy surrogate anchor.
_OFA_POINTS = ((230.0, 76.0), (389.0, 79.1), (482.0, 79.6), (595.0, 80.0))


def accuracy_surrogate(flops_ratio: float, top_acc: float = 80.0) -> float:
    """Monotone log-linear accuracy model through the OFA Pareto shape.

    ``flops_ratio`` is subnet_flops / full_flops in (0, 1].  Fitted to the
    spread of the published points: ~4 points of top-1 across a ~2.6x FLOPs
    range => slope ~9.6%/decade.
    """
    ratio = max(min(flops_ratio, 1.0), 1e-3)
    return top_acc + 9.6 * math.log10(ratio)


def subnet_flops_ratio(spec: SubnetSpec) -> float:
    """Analytic compute ratio of a subnet vs the full network.

    Width-like knobs scale matmul FLOPs linearly in each scaled dim;
    depth scales linearly.  Expert count does not change active compute
    (top_k does).  This is exact for sliced elastic transformers.
    """
    r = 1.0
    r *= spec.depth_mult
    # attention ~ heads x width; mlp ~ width x ffn.  Use an even blend.
    attn = spec.heads_mult * spec.width_mult
    mlp = spec.width_mult * spec.ffn_mult
    r *= 0.5 * attn + 0.5 * mlp
    if spec.top_k is not None and spec.top_k > 0:
        r *= 1.0  # top_k handled by caller (needs full config context)
    if spec.resolution is not None:
        r *= 1.0  # resolution handled by caller
    return r


# --- batch buckets ----------------------------------------------------------
# The serving engine pads each request batch only up to the nearest
# power-of-two bucket (1, 2, 4, ..., max_batch) instead of always padding to
# max_batch; one executable is compiled per (subnet, bucket).  The same
# ladder parameterises the traffic simulator's batching-aware service model:
# a bucket-sized forward costs a fixed dispatch/memory overhead plus a
# compute part linear in the bucket.

# Fraction of the full-batch latency that does NOT shrink with batch size
# (weight streaming, kernel launch, collectives on activations of the pad).
BUCKET_OVERHEAD_FRAC = 0.35


def bucket_ladder(max_batch: int) -> Tuple[int, ...]:
    """Power-of-two batch buckets up to (and always including) max_batch."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    out: List[int] = []
    b = 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(dict.fromkeys(out))


def bucket_for(n: int, max_batch: int) -> int:
    """Smallest bucket that fits ``n`` requests (clamped to max_batch)."""
    for b in bucket_ladder(max_batch):
        if b >= n:
            return b
    return max_batch


def _analytic_bucket_ms(full_batch_ms: float, bucket: int, max_batch: int,
                        overhead_frac: float) -> float:
    frac = overhead_frac + (1.0 - overhead_frac) * min(bucket, max_batch) \
        / max_batch
    return full_batch_ms * min(frac, 1.0)


def bucket_latency_ms(full_batch_ms: float, bucket: int, max_batch: int, *,
                      overhead_frac: float = BUCKET_OVERHEAD_FRAC,
                      calibration=None, spec: Optional[SubnetSpec] = None
                      ) -> float:
    """Latency of one bucket-sized forward, analytic or calibrated.

    ``full_batch_ms`` is the profiled pad-to-max latency (what the LUT
    stores); a smaller bucket pays the fixed overhead fraction plus the
    linearly-scaled compute part.  Monotone in ``bucket`` and equal to
    ``full_batch_ms`` at ``bucket == max_batch``.

    With a warmed :class:`repro.runtime.telemetry.CalibrationStore` (and
    the ``spec`` to key it), each rung's analytic value is only the
    *prior*: the measured dispatch→ready EWMA is blended in with a
    confidence weight on its sample count, so the column converges to
    what the serving engine actually observed.  Columns are kept
    **isotonic** — a noisy measurement must never report a larger bucket
    as faster than a smaller one (that would break ``bucket_for``
    selection and the bucketed simulators' service model), so each rung
    is clamped to at least the rung below it.
    """
    if max_batch <= 0:
        return full_batch_ms
    if calibration is None or spec is None:
        # the analytic shape is monotone by construction (affine in the
        # bucket with a non-negative slope once frac is capped at 1)
        return _analytic_bucket_ms(full_batch_ms, bucket, max_batch,
                                   overhead_frac)
    # calibrated: walk the ladder up to the requested rung, carrying the
    # running max so the returned value respects the isotonic guarantee
    out = 0.0
    target = min(bucket, max_batch)
    for b in bucket_ladder(max_batch):
        prior = _analytic_bucket_ms(full_batch_ms, b, max_batch,
                                    overhead_frac)
        out = max(out, calibration.blended_latency_ms(spec, b, prior))
        if b >= target:
            break
    return out


# Chip-tier divisors of full_chips: a ~1.33x-spaced ladder down to 1/16.
# Water-filling packs concurrent tenants poorly with only {1, 1/2, 1/4}
# tiers — a tenant that needs "a bit more than 1/4" is forced to claim
# half the machine (ROADMAP: finer chip-granularity hw_states).
_CHIP_DIVISORS: Tuple[float, ...] = (1, 4 / 3, 2, 8 / 3, 4, 16 / 3, 8, 16)


def default_hw_states(full_chips: int, *,
                      freqs: Sequence[float] = hm.FREQ_LADDER
                      ) -> List[hm.HwState]:
    """Default (chips x freq) grid for LUT builders.

    Eight chip tiers from full_chips down to full_chips/16 (deduped,
    floored at 1 chip) crossed with the DVFS ladder — fine enough slice
    quanta that the arbiter can hand small shares to small tenants.
    """
    chips = sorted({max(1, int(full_chips / d)) for d in _CHIP_DIVISORS},
                   reverse=True)
    return [hm.HwState(chips=c, freq=f) for c in chips for f in freqs]


@dataclasses.dataclass
class LUT:
    points: List[OpPoint]

    def feasible(self, *, max_latency_ms: float, chips_available: int,
                 power_budget_w: Optional[float] = None,
                 min_accuracy: Optional[float] = None,
                 max_freq: float = 1.0) -> List[OpPoint]:
        out = []
        for p in self.points:
            if p.latency_ms > max_latency_ms:
                continue
            if p.hw_state.chips > chips_available:
                continue
            if p.hw_state.freq > max_freq:
                continue
            if power_budget_w is not None:
                if hm.slice_power_w(p.hw_state) > power_budget_w:
                    continue
            if min_accuracy is not None and p.accuracy < min_accuracy:
                continue
            out.append(p)
        return out

    def bucket_latencies(self, point: OpPoint, max_batch: int,
                         calibration=None) -> Dict[int, float]:
        """Per-bucket latency columns for one operating point (inspection
        helper).

        The stored ``latency_ms`` is the pad-to-max (full batch) cost; the
        columns expand it with :func:`bucket_latency_ms`, the same model
        the batching-aware service model in ``traffic.driver.simulate``
        applies point-wise.  With a ``calibration`` store the measured
        per-bucket EWMAs are blended over the analytic prior and the
        column is isotonic-guarded (see :func:`bucket_latency_ms`).  Use
        this to tabulate a point's whole ladder (reports,
        EXPERIMENTS.md); the hot paths call :func:`bucket_latency_ms`
        directly.
        """
        # single bottom-up walk: blend each rung, carry the running max
        # (bucket_latency_ms performs the same walk for one rung; calling
        # it per rung would redo the prefix each time)
        col: Dict[int, float] = {}
        run = 0.0
        for b in bucket_ladder(max_batch):
            v = _analytic_bucket_ms(point.latency_ms, b, max_batch,
                                    BUCKET_OVERHEAD_FRAC)
            if calibration is not None:
                v = calibration.blended_latency_ms(point.subnet, b, v)
            run = max(run, v)
            col[b] = run
        return col

    def fastest(self, chips_available: int, max_freq: float = 1.0,
                power_budget_w: Optional[float] = None) -> OpPoint:
        """Lowest-latency point within the chip/power budget and freq cap.

        ``max_freq`` < 1 is a thermal throttle and ``power_budget_w`` an
        arbiter grant: a degraded pick must still respect them, so each
        cap is only relaxed (power first, then freq, then chips) if NO
        point satisfies it.
        """
        cands = [p for p in self.points if p.hw_state.chips <= chips_available]
        capped = [p for p in cands if p.hw_state.freq <= max_freq]
        if power_budget_w is not None:
            powered = [p for p in capped or cands
                       if hm.slice_power_w(p.hw_state) <= power_budget_w]
            if powered:
                return min(powered, key=lambda p: p.latency_ms)
        return min(capped or cands or self.points, key=lambda p: p.latency_ms)


def model_lut(specs: Sequence[SubnetSpec], *, full_terms: hm.RooflineTerms,
              full_chips: int,
              hw_states: Optional[Sequence[hm.HwState]] = None,
              top_accuracy: float = 80.0,
              flops_ratio_fn: Callable[[SubnetSpec], float]
              = subnet_flops_ratio) -> LUT:
    """Build a modelled LUT by scaling the full network's roofline terms.

    Compute/memory terms scale with the subnet compute ratio; the
    collective term scales with the width part only (collectives move
    activations).  Chip count scales all terms inversely (weak scaling),
    frequency scales compute only.
    """
    hw_states = list(hw_states) if hw_states is not None \
        else default_hw_states(full_chips)
    points = []
    for spec in specs:
        r = flops_ratio_fn(spec)
        r_coll = 0.5 * (spec.width_mult + spec.width_mult * spec.ffn_mult)
        for hw in hw_states:
            scale_chips = full_chips / hw.chips
            t_comp = full_terms.t_compute * r * scale_chips / hw.freq
            t_mem = full_terms.t_memory * r * scale_chips
            t_coll = full_terms.t_collective * r_coll * scale_chips
            terms = hm.RooflineTerms(t_comp, t_mem, t_coll)
            points.append(OpPoint(
                subnet=spec, hw_state=hw,
                latency_ms=terms.t_total * 1e3,
                energy_mj=hm.step_energy_mj(terms, hw),
                accuracy=accuracy_surrogate(r, top_accuracy),
            ))
    return LUT(points)


def measured_lut(specs: Sequence[SubnetSpec], measure_fn,
                 accuracy_fn=None, hw_states=None) -> LUT:
    """Build a LUT from real measurements.

    ``measure_fn(spec, hw) -> (latency_ms, energy_mj)`` — the serving engine
    provides this by timing the sliced executable;
    ``accuracy_fn(spec) -> float`` — measured (examples) or surrogate.
    """
    hw_states = list(hw_states or [hm.HwState(chips=1, freq=f)
                                   for f in hm.FREQ_LADDER])
    points = []
    for spec in specs:
        for hw in hw_states:
            lat, en = measure_fn(spec, hw)
            acc = (accuracy_fn(spec) if accuracy_fn
                   else accuracy_surrogate(subnet_flops_ratio(spec)))
            points.append(OpPoint(subnet=spec, hw_state=hw, latency_ms=lat,
                                  energy_mj=en, accuracy=acc))
    return LUT(points)
