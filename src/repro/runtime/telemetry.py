"""Telemetry store closing the measurement loop (ROADMAP "feed
measurement back" items).

The paper's runtime layer "monitors the dynamically changing algorithms'
performance targets as well as hardware resources" — but a planner that
only ever consults its *offline* profile drifts from the machine it
actually runs on.  Dynamic-OFA (Lou & Xun et al., 2021) re-profiles
per-architecture latency at runtime; this module is that feedback path
for the whole stack:

* the serving engine (:class:`repro.runtime.engine.DynamicServer`)
  records per-``(SubnetSpec, bucket)`` dispatch→ready latency EWMAs and
  per-tenant measured energy/busy integrals into a
  :class:`CalibrationStore`;
* the LUT layer (:func:`repro.runtime.lut.bucket_latency_ms`,
  :meth:`repro.runtime.lut.LUT.bucket_latencies`) blends those measured
  EWMAs into its analytic bucket columns — the analytic model is the
  *prior*, the measurement takes over as samples accumulate;
* the arbiter (:class:`repro.runtime.arbiter.ResourceArbiter`) plans its
  water-filling off the calibrated point latencies and prices each
  candidate slice with the tenant's *measured* watts
  (:meth:`CalibrationStore.power_scale`) instead of the raw modelled
  ``slice_power_w``;
* the replay simulators (``traffic.driver.simulate``,
  ``cluster.sim.simulate_cluster``) accept a warmed store so a recorded
  trace predicts with measured numbers.

Blending uses a confidence weight on sample count:

    blended = w * measured_ewma + (1 - w) * prior,   w = n / (n + K)

so one noisy batch cannot yank a column, and a well-sampled bucket
converges to its measured value.  All methods are thread-safe (the
engine's completer, the arbiter clock and report readers all touch the
store concurrently).
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Dict, Optional, Tuple

from repro.core.types import SubnetSpec

# EWMA smoothing for measured samples (matches the arbiter's arrival-rate
# beta: new = beta * old + (1 - beta) * sample)
EWMA_BETA = 0.6
# K in the confidence weight n / (n + K): how many measured samples it
# takes before measurement and prior carry equal weight
PRIOR_WEIGHT = 8.0


@dataclasses.dataclass
class _Ewma:
    """One smoothed scalar with its sample count."""
    value: float = 0.0
    count: int = 0

    def observe(self, sample: float, beta: float) -> None:
        if self.count == 0:
            self.value = sample
        else:
            self.value = beta * self.value + (1.0 - beta) * sample
        self.count += 1


class CalibrationStore:
    """Measured-performance store shared by engine, arbiter and simulators.

    Latency is keyed by ``(SubnetSpec, bucket)`` — exactly the engine's
    executable-cache key, so every dispatched batch calibrates the column
    the planner will consult for that architecture at that batch size.
    ``max_batch`` is remembered per key so a bucket observation can be
    projected to a full-batch estimate through the analytic bucket shape
    (:meth:`point_latency_ms`).

    Power is per tenant, two views:

    * :meth:`busy_power_w` — energy/busy: the board power of the slices
      the tenant actually ran on, averaged over its busy time;
    * :meth:`power_scale` — measured watts / modelled watts of the
      granted slice, EWMA-smoothed.  This is the tenant's *duty cycle*:
      a tenant granted a 200 W slice but busy 30 % of the wall clock
      draws 60 W.  The arbiter multiplies ``slice_power_w`` by it, so
      the energy objective the paper optimises is driven by observed
      energy (ROADMAP: feed measured energy back into the water-filling
      objective).
    """

    def __init__(self, *, beta: float = EWMA_BETA,
                 prior_weight: float = PRIOR_WEIGHT):
        self.beta = beta
        self.prior_weight = prior_weight
        self._lock = threading.Lock()
        # (spec, bucket) -> (_Ewma latency_ms, max_batch seen at record)
        self._latency: Dict[Tuple[SubnetSpec, int], Tuple[_Ewma, int]] = {}
        # tenant -> duty-cycle ratio EWMA (measured_w / modelled_w)
        self._power_ratio: Dict[str, _Ewma] = {}
        # tenant -> cumulative (energy_mj, busy_s)
        self._energy: Dict[str, Tuple[float, float]] = {}
        self._version = 0

    # --- latency ------------------------------------------------------------

    def note_latency(self, spec: SubnetSpec, bucket: int, latency_ms: float,
                     *, max_batch: Optional[int] = None) -> None:
        """One measured dispatch→ready batch latency (the engine's hook)."""
        if latency_ms < 0:
            return
        with self._lock:
            ewma, mb = self._latency.get((spec, bucket), (None, bucket))
            if ewma is None:
                ewma = _Ewma()
            ewma.observe(float(latency_ms), self.beta)
            self._latency[(spec, bucket)] = (
                ewma, int(max_batch) if max_batch else max(mb, bucket))
            self._version += 1

    def latency_ms(self, spec: SubnetSpec, bucket: int) -> Optional[float]:
        """Raw measured EWMA for one (spec, bucket), or None."""
        with self._lock:
            entry = self._latency.get((spec, bucket))
            return entry[0].value if entry else None

    def latency_samples(self, spec: SubnetSpec, bucket: int) -> int:
        with self._lock:
            entry = self._latency.get((spec, bucket))
            return entry[0].count if entry else 0

    def _weight(self, n: int) -> float:
        return n / (n + self.prior_weight)

    def blended_latency_ms(self, spec: SubnetSpec, bucket: int,
                           prior_ms: float) -> float:
        """Measured EWMA blended into the analytic prior by confidence."""
        with self._lock:
            entry = self._latency.get((spec, bucket))
            if entry is None:
                return prior_ms
            ewma, _ = entry
            w = self._weight(ewma.count)
            return w * ewma.value + (1.0 - w) * prior_ms

    def point_latency_ms(self, spec: SubnetSpec, prior_ms: float,
                         *, overhead_frac: Optional[float] = None) -> float:
        """Full-batch (pad-to-max) latency estimate for one subnet.

        Every measured bucket contributes: an observation at bucket ``b``
        of a ``max_batch`` ladder is projected to a full-batch estimate
        through the analytic bucket shape (divide by the bucket's cost
        fraction), then the projections are count-weighted and blended
        with the analytic ``prior_ms``.  The arbiter plans feasibility
        off this number, so its water-filling runs on measured latency
        once the serving engine has seen the subnet.
        """
        # local import: lut imports this module for the column blend
        from repro.runtime.lut import BUCKET_OVERHEAD_FRAC
        of = BUCKET_OVERHEAD_FRAC if overhead_frac is None else overhead_frac
        with self._lock:
            total_n = 0
            acc = 0.0
            for (sp, b), (ewma, mb) in self._latency.items():
                if sp != spec or mb <= 0:
                    continue
                frac = min(1.0, of + (1.0 - of) * min(b, mb) / mb)
                acc += ewma.count * (ewma.value / frac)
                total_n += ewma.count
            if not total_n:
                return prior_ms
            measured_full = acc / total_n
            w = self._weight(total_n)
            return w * measured_full + (1.0 - w) * prior_ms

    # --- power / energy -----------------------------------------------------

    def note_energy(self, tenant: str, energy_mj: float,
                    busy_s: float) -> None:
        """Accumulate one batch's measured energy/busy (the engine's hook).

        Does not bump :meth:`version`: energy totals feed power pricing
        (read fresh every arbitration), not the derived latency tables
        the version counter invalidates."""
        if energy_mj < 0 or busy_s < 0:
            return
        with self._lock:
            e, b = self._energy.get(tenant, (0.0, 0.0))
            self._energy[tenant] = (e + energy_mj, b + busy_s)

    def busy_power_w(self, tenant: str) -> Optional[float]:
        """Measured energy / busy time — watts while actually computing."""
        with self._lock:
            e, b = self._energy.get(tenant, (0.0, 0.0))
            return (e / 1e3) / b if b > 0 else None

    def note_power(self, tenant: str, measured_w: float,
                   modelled_w: float) -> None:
        """One wall-clock power observation against the granted slice's
        modelled watts (the arbiter's per-tick hook)."""
        if modelled_w <= 0 or measured_w < 0:
            return
        with self._lock:
            ratio = self._power_ratio.setdefault(tenant, _Ewma())
            ratio.observe(measured_w / modelled_w, self.beta)

    def power_scale(self, tenant: str) -> float:
        """Blended measured/modelled watts ratio (prior 1.0).

        Multiplying ``slice_power_w(hw)`` by this prices a candidate
        point at the tenant's *observed* draw — the measured-energy
        objective.  1.0 until samples accumulate.
        """
        with self._lock:
            ratio = self._power_ratio.get(tenant)
            if ratio is None or ratio.count == 0:
                return 1.0
            w = self._weight(ratio.count)
            return w * ratio.value + (1.0 - w) * 1.0

    def power_samples(self, tenant: str) -> int:
        with self._lock:
            ratio = self._power_ratio.get(tenant)
            return ratio.count if ratio else 0

    # --- bookkeeping --------------------------------------------------------

    def version(self) -> int:
        """Monotone LATENCY-observation counter.

        Derived tables (the arbiter's calibrated LUTs) key their caches
        off it; only :meth:`note_latency` bumps it, since power/energy
        observations are read fresh at use and don't invalidate any
        derived latency table."""
        with self._lock:
            return self._version

    def summary(self) -> dict:
        with self._lock:
            lat = {f"{sp.name()}/b{b}": {"ms": round(e.value, 4),
                                         "n": e.count, "max_batch": mb}
                   for (sp, b), (e, mb) in sorted(
                       self._latency.items(),
                       key=lambda kv: (kv[0][0].name(), kv[0][1]))}
            power = {}
            for tenant in set(self._power_ratio) | set(self._energy):
                row = {}
                ratio = self._power_ratio.get(tenant)
                if ratio is not None and ratio.count:
                    row["scale"] = round(ratio.value, 4)
                    row["n"] = ratio.count
                e, b = self._energy.get(tenant, (0.0, 0.0))
                if b > 0:
                    row["busy_power_w"] = round((e / 1e3) / b, 2)
                    row["energy_mj"] = round(e, 2)
                power[tenant] = row
            return {"latency": lat, "power": power,
                    "version": self._version}

    # --- persistence (bench/CLI: warm a store from a recorded run) ---------

    def save(self, path: str) -> None:
        with self._lock:
            payload = {
                "schema": 1, "beta": self.beta,
                "prior_weight": self.prior_weight,
                "latency": [
                    {"spec": dataclasses.asdict(sp), "bucket": b,
                     "ms": e.value, "n": e.count, "max_batch": mb}
                    for (sp, b), (e, mb) in self._latency.items()],
                "power_ratio": {t: {"value": r.value, "n": r.count}
                                for t, r in self._power_ratio.items()},
                "energy": {t: list(eb) for t, eb in self._energy.items()},
            }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "CalibrationStore":
        with open(path) as f:
            payload = json.load(f)
        store = cls(beta=payload.get("beta", EWMA_BETA),
                    prior_weight=payload.get("prior_weight", PRIOR_WEIGHT))
        for row in payload.get("latency", ()):
            spec = SubnetSpec(**row["spec"])
            store._latency[(spec, int(row["bucket"]))] = (
                _Ewma(value=float(row["ms"]), count=int(row["n"])),
                int(row["max_batch"]))
        for tenant, r in payload.get("power_ratio", {}).items():
            store._power_ratio[tenant] = _Ewma(value=float(r["value"]),
                                               count=int(r["n"]))
        for tenant, (e, b) in payload.get("energy", {}).items():
            store._energy[tenant] = (float(e), float(b))
        store._version = 1
        return store
