"""TPU v5e hardware model: roofline terms, DVFS ladder, energy.

This container is CPU-only; v5e is the *target*.  All latency/energy
numbers that the runtime governor uses are produced here from compiled
cost analysis (FLOPs / bytes / collective bytes), exactly the quantities
EXPERIMENTS.md §Roofline reports.

DVFS adaptation (DESIGN.md §2): mobile SoCs expose a frequency/voltage
ladder; TPUs do not expose DVFS directly, so we model a v5e-like ladder
where compute scales ~f and power ~f·V^2 (V roughly ∝ f above the knee).
The governor treats (chips, freq) as its hardware knobs — the TPU
analogues of the paper's task mapping + DVFS.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# --- v5e per-chip constants (bf16) -----------------------------------------
PEAK_FLOPS = 197e12          # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
TDP_W = 200.0                # per-chip board power at f=1.0 (modelled)
IDLE_W = 60.0                # static / uncore power (modelled)


@dataclasses.dataclass(frozen=True)
class HwState:
    """One hardware operating point (the governor's hardware knob)."""
    chips: int = 256
    freq: float = 1.0          # DVFS ladder fraction

    def name(self) -> str:
        return f"c{self.chips}-f{self.freq:g}"


# modelled v5e DVFS ladder (fractions of nominal clock)
FREQ_LADDER: Tuple[float, ...] = (0.4, 0.55, 0.7, 0.85, 1.0)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """The three §Roofline terms, in seconds (per step, per device)."""
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def t_total(self) -> float:
        # compute and memory overlap on TPU; collectives partially overlap —
        # the roofline estimate is max(compute, memory) + collective tail
        return max(self.t_compute, self.t_memory) + self.t_collective

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)


def roofline(flops_per_dev: float, bytes_per_dev: float,
             coll_bytes_per_dev: float, hw: HwState) -> RooflineTerms:
    f = hw.freq
    return RooflineTerms(
        t_compute=flops_per_dev / (PEAK_FLOPS * f),
        t_memory=bytes_per_dev / HBM_BW,          # HBM clock ~ independent
        t_collective=coll_bytes_per_dev / ICI_BW,
    )


def power_w(hw: HwState, utilization: float = 0.8) -> float:
    """Modelled per-chip power at a DVFS point: P = P_idle + P_dyn·f·V²,
    V ∝ max(f, 0.6) above the knee."""
    v = max(hw.freq, 0.6)
    return IDLE_W + (TDP_W - IDLE_W) * utilization * hw.freq * v * v


def slice_power_w(hw: HwState, utilization: float = 0.8) -> float:
    """Total board power of a hardware slice (all chips at the DVFS point).

    The unit the multi-workload arbiter budgets in: per-workload power
    shares must sum to the global budget across concurrent slices.
    """
    return power_w(hw, utilization) * hw.chips


def step_energy_mj(terms: RooflineTerms, hw: HwState,
                   utilization: float = 0.8) -> float:
    """Energy per step over the whole slice (millijoules)."""
    return power_w(hw, utilization) * hw.chips * terms.t_total * 1e3
