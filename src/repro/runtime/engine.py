"""Dynamic serving engine: the deployed half of the paper's system.

Serves a supernet through its Pareto sub-networks:

* an executable cache keyed by ``(SubnetSpec, batch bucket)`` — each
  sub-network is a separate sliced-mode jit executable over the SAME
  parameter buffers, so switching architectures costs one dictionary
  lookup (the Dynamic-OFA trick: weights stay resident, no re-deployment);
* **bucketed continuous batching**: a batch of ``k`` requests is padded
  only up to the nearest power-of-two bucket (1, 2, 4, ..., max_batch)
  instead of always paying a full-batch forward; per-bucket pad buffers
  are pre-allocated so the steady state does zero host allocation, and
  :meth:`DynamicServer.warm` pre-compiles the whole bucket ladder so it
  does zero cold compiles (``cold_compiles`` counts misses);
* **pipelined dispatch**: the serve loop is split into a *collector*
  (stacks batch N+1 and dispatches it asynchronously) and a *completer*
  (resolves futures when batch N leaves the device), so host-side batch
  assembly overlaps device compute.  ``pipeline_depth`` bounds how far
  the collector may run ahead; ``busy_s``/``measured_energy_mj``
  integrate non-overlapping dispatch→ready intervals so accounting stays
  correct under overlap;
* the runtime governor in the loop: every ``govern_every`` batches it
  re-reads the performance target + hardware state and may switch the
  active sub-network and the (modelled) DVFS point;
* wall-clock measurement hooks that feed the measured LUT, and — with a
  :class:`repro.runtime.telemetry.CalibrationStore` attached — the
  CLOSED measurement loop: every completed batch records its
  dispatch→ready latency under its ``(SubnetSpec, bucket)`` executable
  key and its measured energy/busy under the server's tenant label, the
  numbers the LUT columns and the arbiter's energy objective then plan
  off.

The worker blocks on the request queue and on pause/resume events (no
polling): an idle or paused server burns no CPU and wakes immediately.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Set, Tuple)

import jax
import numpy as np

from repro.analysis.guards import guarded_by
from repro.core.elastic import spec_to_static
from repro.core.types import SubnetSpec
from repro.obs import trace as obs
from repro.runtime import hwmodel as hm
from repro.runtime.lut import bucket_ladder

# queue token that wakes a blocked collector without carrying a request
# (pause()/stop() enqueue it so the worker never needs a poll timeout)
_WAKE = object()

# analysis hook: when set (pytest --lock-check), called right before a
# batch is handed to the device executable; the lock monitor records any
# control-plane locks still held at that point as violations — holding
# one serializes arbitration/routing behind device latency
_DISPATCH_NOTE: Optional[Callable[[], None]] = None


@dataclasses.dataclass
class Request:
    x: Any
    t_submit: float
    future: "queue.Queue"
    trace_id: Optional[int] = None   # obs: span tree begun upstream
    t_take: float = 0.0              # obs: collector pulled it off the queue


@dataclasses.dataclass
class _InFlight:
    """One dispatched batch travelling from collector to completer."""
    out: Any                   # device value (dispatch returned, not ready)
    reqs: List[Request]
    t_dispatch: float
    hw: Any                    # HwState active at dispatch
    subnet: str
    buf_key: tuple             # pad-buffer pool slot to recycle when ready
    buf: Optional[np.ndarray]  # None once returned to the pool
    spec: SubnetSpec = SubnetSpec()   # calibration key: the dispatched
    bucket: int = 0                   # (SubnetSpec, bucket) executable
    t_collect: float = 0.0     # obs: batch window closed (stacking starts)
    t_disp_ret: float = 0.0    # obs: async dispatch call returned


@guarded_by("_wake_lock", "_wake_tokens")
@guarded_by("_acct_lock", "_outstanding", "_arrivals")
class DynamicServer:
    def __init__(self, apply_fn: Callable, params, dims: Dict[str, int], *,
                 governor=None, max_batch: int = 8, timeout_ms: float = 5.0,
                 multiple_of: int = 1,
                 warm_specs: Optional[List[SubnetSpec]] = None,
                 batch_buckets: bool = True, pipeline: bool = True,
                 pipeline_depth: int = 2, example_input=None,
                 switch_log_cap: int = 1024,
                 adaptive_window: bool = False,
                 min_window_ms: float = 0.5,
                 calibration=None, tenant: Optional[str] = None,
                 tracer=None, metrics=None):
        """``apply_fn(params, x, E) -> output`` (pure; jit-able).

        ``dims`` maps knob names to full sizes (see spec_to_static).
        ``batch_buckets=False`` restores the pad-to-max data path and
        ``pipeline=False`` the synchronous dispatch-then-wait loop (the
        baselines the benchmarks compare against).  ``example_input`` is
        one request-shaped array; when given, ``warm_specs`` warms the
        whole bucket ladder (compile + one execution per bucket) instead
        of only building the jit wrappers.

        ``adaptive_window=True`` sizes the batching window from the
        arrival-rate EWMA the arbiter tracks (ROADMAP item): under load
        the collector holds the window open only about one expected
        inter-arrival time (floored at ``min_window_ms``), when traffic
        is sparse it keeps the full ``timeout_ms`` — a lone request never
        waits out a window no second request will join.

        ``calibration`` (a :class:`repro.runtime.telemetry
        .CalibrationStore`) closes the measurement loop: every completed
        batch records its dispatch→ready latency under its
        ``(SubnetSpec, bucket)`` key, and — when ``tenant`` names this
        server's workload — its measured energy/busy integral, so LUT
        columns and the arbiter's energy objective run on observed
        numbers instead of the analytic model.

        ``tracer`` (a :class:`repro.obs.Tracer`) records each request's
        span tree — queue / collect / stack / dispatch / device /
        complete — into the shared buffer; upstream layers (cluster
        frontend, traffic driver) begin the trace with the SLO class and
        pass ``trace_id`` to :meth:`submit`, or the engine begins its
        own under the tenant label.  ``metrics`` (a
        :class:`repro.obs.MetricsRegistry`) gets served/cancelled
        counters and a request-latency histogram.  Both default to None
        = zero work on the hot path; the cluster layer also sets them
        post-construction (``trace_node`` labels spans with the node).
        """
        self.apply_fn = apply_fn
        self.params = params
        self.dims = dims
        self.governor = governor
        self.max_batch = max_batch
        self.timeout_s = timeout_ms / 1e3
        self.multiple_of = multiple_of
        self.batch_buckets = batch_buckets
        self.buckets: Tuple[int, ...] = (bucket_ladder(max_batch)
                                         if batch_buckets else (max_batch,))
        self.pipeline = pipeline
        self.pipeline_depth = max(1, pipeline_depth)
        self.example_input = (None if example_input is None
                              else np.asarray(example_input))
        # cache key: (spec, bucket); bucket None is the shape-polymorphic
        # executable used by the synchronous infer()/measure() path
        self._cache: Dict[Tuple[SubnetSpec, Optional[int]], Any] = {}
        self._specs_cached: Set[SubnetSpec] = set()
        self._compiled: Set[Tuple[SubnetSpec, int]] = set()
        self._cache_lock = threading.Lock()
        # per-bucket pad-buffer free list: the completer recycles a buffer
        # only after its batch left the device, so the collector never
        # rewrites memory a pending dispatch may still alias (CPU backend
        # can zero-copy host arrays).  Steady state: zero host allocation.
        self._pad_pool: Dict[Tuple[int, tuple, str], List[np.ndarray]] = {}
        self._pad_lock = threading.Lock()
        self.adaptive_window = adaptive_window
        self.min_window_s = min_window_ms / 1e3
        self.calibration = calibration
        self.tenant = tenant
        self.tracer = tracer
        self.metrics = metrics
        self.trace_node: Optional[str] = None   # cluster sets the node label
        self._arrival_rate_rps = 0.0
        self._queue: "queue.Queue" = queue.Queue()
        # _WAKE entries in _queue (not real backlog); lock-protected because
        # pause()/stop() (arbiter clock, callers) and the worker all touch
        # it and queue_depth() feeds the arbiter's water-filling
        self._wake_tokens = 0     # guarded-by: _wake_lock
        self._wake_lock = threading.Lock()
        # unresolved futures + arrivals since the last arbiter pull; the
        # cluster layer drains on _outstanding and the arbiter's EWMA
        # feeds off take_arrival_count()
        self._outstanding = 0     # guarded-by: _acct_lock
        self._arrivals = 0        # guarded-by: _acct_lock
        self._acct_lock = threading.Lock()
        self._draining = False
        self._fail_reason: Optional[str] = None
        self._completions: Optional["queue.Queue"] = None
        self._stop = threading.Event()
        self._paused = threading.Event()
        self._resume = threading.Event()
        self._resume.set()
        self._wedged = False   # chaos: resume() defeated until unwedge()
        self._worker: Optional[threading.Thread] = None
        self._completer: Optional[threading.Thread] = None
        self.active_spec = SubnetSpec()
        self.active_point = None
        # bounded: governor churn must not grow memory without limit
        self.switch_log: Deque[dict] = collections.deque(maxlen=switch_log_cap)
        self.switch_log_cap = switch_log_cap
        self.switch_log_dropped = 0
        self.served = 0
        self.cancelled = 0
        self.cold_compiles = 0   # serve-path dispatches that had to compile
        # measured accounting: non-overlapping dispatch->ready wall-clock
        # integrated against the active hw slice's modelled power — the
        # arbiter's per-tenant MEASURED energy (vs the LUT's modelled
        # energy_mj).  _last_ready de-overlaps pipelined batches.
        self.busy_s = 0.0
        self.measured_energy_mj = 0.0
        self._last_ready = 0.0
        if warm_specs:
            self.warm(warm_specs)

    # --- executable cache ---------------------------------------------------

    def executable(self, spec: SubnetSpec, bucket: Optional[int] = None):
        # called from the worker thread AND synchronous infer()/measure()
        # callers (and, in arbiter mode, the shared constraint clock)
        with self._cache_lock:
            key = (spec, bucket)
            if key not in self._cache:
                E = spec_to_static(spec, self.dims, self.multiple_of)
                fn = jax.jit(lambda p, x: self.apply_fn(p, x, E))
                self._cache[key] = fn
                self._specs_cached.add(spec)
            return self._cache[key]

    def warm(self, specs: List[SubnetSpec], example_input=None):
        """Warm the bucket ladder for each spec.

        Builds every (spec, bucket) executable; with an example input
        (here or at construction) each one is also executed once so XLA
        compiles NOW — after this, steady-state serving performs zero cold
        compiles (``cold_compiles`` stays 0) and zero host allocations
        (pad buffers are pre-pinned per bucket).
        """
        x1 = example_input if example_input is not None else self.example_input
        if x1 is not None:
            x1 = np.asarray(x1)
            self.example_input = x1
        for spec in specs:
            for b in self.buckets:
                fn = self.executable(spec, b)
                if x1 is None:
                    continue
                key, buf = self._take_buffer(b, x1.shape, x1.dtype)
                buf[:] = 0
                jax.block_until_ready(fn(self.params, buf))
                self._give_buffer(key, buf)
                self._compiled.add((spec, b))

    def switch(self, spec: SubnetSpec, point=None):
        t0 = time.perf_counter()
        cold = spec not in self._specs_cached
        self.executable(spec)
        if len(self.switch_log) == self.switch_log_cap:
            self.switch_log_dropped += 1   # deque evicts the oldest entry
        self.switch_log.append({"spec": spec.name(), "cold": cold,
                                "ms": (time.perf_counter() - t0) * 1e3})
        self.active_spec = spec
        self.active_point = point

    # --- synchronous API ------------------------------------------------------

    def infer(self, x, spec: Optional[SubnetSpec] = None):
        spec = spec or self.active_spec
        fn = self.executable(spec)
        return jax.block_until_ready(fn(self.params, x))

    def measure(self, spec: SubnetSpec, x, iters: int = 5) -> float:
        """Median wall-clock ms for one batch under ``spec`` (post-warmup)."""
        fn = self.executable(spec)
        jax.block_until_ready(fn(self.params, x))
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(self.params, x))
            ts.append((time.perf_counter() - t0) * 1e3)
        return float(np.median(ts))

    # --- batched serving loop -------------------------------------------------

    def _cancel(self, r: Request, reason: str):
        # "failed" marks fail-stop (kill) resolutions apart from ordinary
        # cancels (stop/drain/shed) so live accounting can separate a node
        # failure from load shedding, as the cluster simulator does
        r.future.put({"y": None, "cancelled": True, "error": reason,
                      "failed": self._fail_reason is not None,
                      "latency_ms": (time.perf_counter() - r.t_submit) * 1e3,
                      "subnet": None})
        self.cancelled += 1
        if self.tracer is not None and r.trace_id is not None:
            # retain the partial tree: a retried/re-routed attempt links
            # back to this trace_id, and a link whose target was popped
            # from the buffer can never resolve in the exported trace
            self.tracer.abort_request(r.trace_id, retain=True)
        if self.metrics is not None:
            # node label: engine series from different nodes must not
            # collide in a shared cluster registry
            self.metrics.counter("engine_cancelled_total",
                                 tenant=self.tenant or "default",
                                 node=self.trace_node or "").inc()
        with self._acct_lock:
            self._outstanding = max(0, self._outstanding - 1)

    def _stop_reason(self) -> str:
        return self._fail_reason or "server stopped"

    def submit(self, x, trace_id: Optional[int] = None,
               links: Sequence[int] = ()) -> "queue.Queue":
        fut: "queue.Queue" = queue.Queue(maxsize=1)
        t_submit = time.perf_counter()
        if self.tracer is not None and trace_id is None:
            # standalone server: begin the tree here under the tenant
            # label (the cluster frontend begins it earlier, with the
            # SLO class and a route span, and hands us its trace_id).
            # ``links`` names prior attempts' trace_ids (retry/hedge).
            trace_id = self.tracer.begin_request(
                self.tenant or "default", t=t_submit, node=self.trace_node,
                links=links)
        # retry layers read the id back off the future to link attempts
        fut.trace_id = trace_id
        r = Request(x=x, t_submit=t_submit, future=fut, trace_id=trace_id)
        with self._acct_lock:
            self._outstanding += 1
            self._arrivals += 1
        if self._stop.is_set() or self._draining:
            # stopped/draining server: resolve immediately instead of
            # queueing a request no worker will ever pick up
            self._cancel(r, "server draining" if self._draining
                         and not self._stop.is_set() else self._stop_reason())
            return fut
        self._queue.put(r)
        if self._stop.is_set() and not self.is_running:
            # stop() raced the put above and its drain may have missed us;
            # drain again (queue.get is atomic, each request resolves once)
            self._drain_queue()
        return fut

    def outstanding(self) -> int:
        """Futures submitted but not yet resolved (drain watches this)."""
        with self._acct_lock:
            return self._outstanding

    def take_arrival_count(self) -> int:
        """Arrivals since the last call — the arbiter's EWMA input."""
        with self._acct_lock:
            n = self._arrivals
            self._arrivals = 0
            return n

    def note_arrival_rate(self, rps: float):
        """The arbiter pushes its smoothed per-tenant arrival rate here;
        the adaptive batching window is sized from it."""
        self._arrival_rate_rps = max(0.0, float(rps))

    def effective_timeout_s(self) -> float:
        """Current batching window: the expected inter-arrival time under
        load (floored at ``min_window_s``), the full ``timeout_s`` when
        sparse, and always ``timeout_s`` unless ``adaptive_window``."""
        rate = self._arrival_rate_rps
        if not self.adaptive_window or rate <= 0.0:
            return self.timeout_s
        return min(self.timeout_s, max(self.min_window_s, 1.0 / rate))

    def queue_depth(self) -> int:
        """Requests waiting for a batch (the arbiter's backlog signal)."""
        with self._wake_lock:
            tokens = self._wake_tokens
        return max(0, self._queue.qsize() - tokens)

    def _put_wake(self):
        with self._wake_lock:
            self._wake_tokens += 1
        self._queue.put(_WAKE)

    def _took_wake(self):
        with self._wake_lock:
            self._wake_tokens -= 1

    def _drain_queue(self):
        while True:
            try:
                r = self._queue.get_nowait()
            except queue.Empty:
                break
            if r is _WAKE:
                self._took_wake()
                continue
            self._cancel(r, self._stop_reason())

    def _collect_batch(self) -> List[Request]:
        """Block (no poll) until a request arrives, then hold the batching
        window open.  A _WAKE token (pause/stop) ends collection early."""
        reqs: List[Request] = []
        deadline = 0.0
        while len(reqs) < self.max_batch:
            if not reqs:
                r = self._queue.get()    # idle: block until work or wake
            else:
                timeout = max(0.0, deadline - time.perf_counter())
                try:
                    r = self._queue.get(timeout=timeout)
                except queue.Empty:
                    break
            if r is _WAKE:
                self._took_wake()
                break
            if not reqs:
                deadline = time.perf_counter() + self.effective_timeout_s()
            if self.tracer is not None:
                r.t_take = time.perf_counter()
            reqs.append(r)
        return reqs

    def pause(self):
        """Park the worker: requests queue up but no compute is consumed
        (the arbiter starves a workload this way — its slice is gone)."""
        if not self._paused.is_set():
            self._paused.set()
            self._resume.clear()
            self._put_wake()         # wake a collector blocked on get()

    def resume(self):
        if self._wedged:
            return   # a wedged worker silently ignores the arbiter
        if self._paused.is_set():
            self._paused.clear()
            self._resume.set()

    def wedge(self):
        """Chaos: silently hang the worker.  Requests keep queueing and
        the server stays registered/routable, but nothing completes and
        ``resume()`` is defeated until :meth:`unwedge` — the failure
        mode only the stall health check can see."""
        self._wedged = True
        self.pause()

    def unwedge(self):
        self._wedged = False
        self.resume()

    def _bucket_for(self, n: int) -> int:
        # scan the precomputed ladder: no per-dispatch allocation
        for b in self.buckets:
            if b >= n:
                return b
        return self.max_batch

    def _take_buffer(self, bucket: int, shape: tuple, dtype
                     ) -> Tuple[tuple, np.ndarray]:
        """Pop a pre-allocated staging buffer for one bucket (allocate only
        on first use; the completer gives it back once the batch is ready)."""
        key = (bucket, tuple(shape), np.dtype(dtype).str)
        with self._pad_lock:
            pool = self._pad_pool.setdefault(key, [])
            if pool:
                return key, pool.pop()
        return key, np.zeros((bucket,) + tuple(shape), dtype)

    def _give_buffer(self, key: tuple, buf: np.ndarray):
        with self._pad_lock:
            self._pad_pool[key].append(buf)

    def _dispatch(self, reqs: List[Request]) -> _InFlight:
        """Stack + pad to the nearest bucket and dispatch asynchronously."""
        t_collect = time.perf_counter() if self.tracer is not None else 0.0
        xs = [np.asarray(r.x) for r in reqs]
        n = len(xs)
        bucket = self._bucket_for(n)
        buf_key, buf = self._take_buffer(bucket, xs[0].shape, xs[0].dtype)
        for i, x in enumerate(xs):
            buf[i] = x
        if n < bucket:
            buf[n:] = 0
        spec = self.active_spec
        key = (spec, bucket)
        fn = self.executable(spec, bucket)
        if key not in self._compiled:
            self.cold_compiles += 1
            self._compiled.add(key)
        hw = getattr(self.active_point, "hw_state", None) \
            or hm.HwState(chips=1, freq=1.0)
        if _DISPATCH_NOTE is not None:
            _DISPATCH_NOTE()
        t_disp = time.perf_counter()
        out = fn(self.params, buf)       # async: returns before ready
        t_ret = time.perf_counter() if self.tracer is not None else 0.0
        return _InFlight(out=out, reqs=reqs, t_dispatch=t_disp, hw=hw,
                         subnet=spec.name(), buf_key=buf_key, buf=buf,
                         spec=spec, bucket=bucket,
                         t_collect=t_collect, t_disp_ret=t_ret)

    def _complete(self, item: _InFlight):
        """Resolve one in-flight batch: wait for the device, account the
        non-overlapping dispatch->ready interval, answer the futures."""
        out = np.asarray(jax.block_until_ready(item.out))
        if item.buf is not None:
            self._give_buffer(item.buf_key, item.buf)
            item.buf = None          # _complete_safe must not re-pool it
        t_ready = time.perf_counter()
        # clamp: completions can land out of order across the pipeline
        # (completer vs synchronous paths), and a stale _last_ready past
        # t_ready would otherwise integrate NEGATIVE busy time/energy —
        # which would corrupt the calibration loop's measured watts
        dt = max(0.0, t_ready - max(item.t_dispatch, self._last_ready))
        self._last_ready = max(self._last_ready, t_ready)
        if dt > 0:
            self.busy_s += dt
            self.measured_energy_mj += hm.slice_power_w(item.hw) * dt * 1e3
        if self.calibration is not None:
            # dispatch→ready is the batch's effective service latency
            # (under pipeline overlap it includes device queueing, which
            # is exactly what the replay simulators should price)
            self.calibration.note_latency(
                item.spec, item.bucket,
                (t_ready - item.t_dispatch) * 1e3,
                max_batch=self.max_batch)
            if self.tenant is not None and dt > 0:
                self.calibration.note_energy(
                    self.tenant, hm.slice_power_w(item.hw) * dt * 1e3, dt)
        for i, r in enumerate(item.reqs):
            r.future.put({"y": out[i],
                          "latency_ms": (t_ready - r.t_submit) * 1e3,
                          "subnet": item.subnet})
            with self._acct_lock:
                self._outstanding = max(0, self._outstanding - 1)
        self.served += len(item.reqs)
        if self.tracer is not None:
            # futures are already answered — tracing never delays callers.
            # Components partition submit→ready exactly, so the tree sums
            # to the measured latency; `complete` (ready→futures resolved)
            # is post-measurement and excluded from the total.
            t_done = time.perf_counter()
            dev_attrs = {"bucket": item.bucket, "subnet": item.subnet,
                         "n": len(item.reqs)}
            for r in item.reqs:
                if r.trace_id is None:
                    continue
                self.tracer.finish_request(
                    r.trace_id, t=t_ready, node=self.trace_node, spans=[
                        (obs.QUEUE, r.t_submit, r.t_take, None),
                        (obs.COLLECT, r.t_take, item.t_collect, None),
                        (obs.STACK, item.t_collect, item.t_dispatch, None),
                        (obs.DISPATCH, item.t_dispatch, item.t_disp_ret,
                         None),
                        (obs.DEVICE, item.t_disp_ret, t_ready, dev_attrs),
                        (obs.COMPLETE, t_ready, t_done, None)])
        if self.metrics is not None:
            tn = self.tenant or "default"
            nd = self.trace_node or ""
            self.metrics.counter("engine_served_total", tenant=tn,
                                 node=nd).inc(len(item.reqs))
            hist = self.metrics.histogram("engine_request_ms", tenant=tn,
                                          node=nd)
            for r in item.reqs:
                # exemplar: a p99 bucket names a concrete retained trace
                hist.observe((t_ready - r.t_submit) * 1e3,
                             exemplar=r.trace_id)

    def _complete_safe(self, item: _InFlight):
        """_complete, never letting an exception kill the thread: a failed
        batch (XLA runtime error, bad input shape) resolves its futures
        with an error payload instead of wedging callers forever."""
        try:
            self._complete(item)
        except Exception as e:  # noqa: BLE001 - resolve, don't wedge
            if item.buf is not None:    # not yet returned by _complete
                self._give_buffer(item.buf_key, item.buf)
                item.buf = None
            for r in item.reqs:
                if r.future.empty():
                    self._cancel(r, f"batch failed: {e!r}")

    def _completion_loop(self):
        while True:
            item = self._completions.get()
            if item is None:
                break
            self._complete_safe(item)

    def _serve_loop(self, constraints_fn=None, govern_every: int = 4):
        n_batches = 0
        carry: List[Request] = []    # batch formed, then pause/stop landed
        while not self._stop.is_set():
            if self._paused.is_set():
                self._resume.wait()  # repro: allow-wait(no spin; audited: resume() AND stop() both set _resume)
                continue
            # serve a carried-over batch first: requests must not be
            # re-queued behind later submissions (FIFO across a pause)
            reqs = carry or self._collect_batch()
            carry = []
            if self._stop.is_set():
                carry = reqs             # requeued below; stop() cancels
                break
            if self._paused.is_set():
                carry = reqs
                continue
            if not reqs:
                continue
            if self.governor is not None and constraints_fn is not None \
                    and n_batches % govern_every == 0:
                c = constraints_fn()
                point = self.governor.select(c)
                if point.subnet != self.active_spec:
                    self.switch(point.subnet, point)
                else:
                    self.active_point = point
            try:
                item = self._dispatch(reqs)
            except Exception as e:  # noqa: BLE001 - resolve, don't wedge
                for r in reqs:
                    self._cancel(r, f"dispatch failed: {e!r}")
                continue
            if self.pipeline:
                # bounded handoff: batch N+1 stacks while N is on device
                self._completions.put(item)
            else:
                self._complete_safe(item)
            n_batches += 1
        for r in carry:                  # stop() drains and cancels these
            self._queue.put(r)

    @property
    def is_running(self) -> bool:
        return self._worker is not None and self._worker.is_alive()

    def start(self, constraints_fn=None, govern_every: int = 4):
        self._stop.clear()
        self._paused.clear()
        self._resume.set()
        self._draining = False
        self._fail_reason = None
        self._last_ready = 0.0
        if self.pipeline:
            self._completions = queue.Queue(maxsize=self.pipeline_depth)
            self._completer = threading.Thread(target=self._completion_loop,
                                               daemon=True)
            self._completer.start()
        self._worker = threading.Thread(
            target=self._serve_loop, args=(constraints_fn, govern_every),
            daemon=True)
        self._worker.start()

    def drain(self, timeout_s: float = 30.0) -> bool:
        """Graceful node-drain handoff: refuse new work, let the backlog
        resolve, then stop.

        New submits resolve immediately with a ``"server draining"``
        payload (the cluster router stops sending them first); everything
        already accepted is served.  Returns True when the backlog fully
        resolved inside the timeout — False means leftovers were cancelled
        by :meth:`stop` (e.g. the server was paused/starved the whole
        time).
        """
        self._draining = True
        deadline = time.perf_counter() + timeout_s
        while self.outstanding() and time.perf_counter() < deadline:
            time.sleep(0.005)
        drained = self.outstanding() == 0
        self.stop()
        return drained

    def kill(self, reason: str = "node failed"):
        """Fail-stop: everything queued (and every racing submit) resolves
        with an error payload carrying ``reason`` — no caller ever hangs
        on a dead node.  Batches already on the device still complete and
        answer normally (fail-stop kills the node, not physics)."""
        self._fail_reason = reason
        self.stop()

    def stop(self):
        self._stop.set()
        self._resume.set()               # unpark a paused worker
        self._put_wake()                 # wake a collector blocked on get()
        worker_alive = False
        if self._worker:
            self._worker.join(timeout=60)
            worker_alive = self._worker.is_alive()
            if not worker_alive:
                self._worker = None
        if self._completer and not worker_alive:
            # the collector is joined: every dispatched batch is already in
            # the completion queue, so the sentinel lands after all of them.
            # If the worker is somehow still wedged in an in-flight dispatch
            # we leave the (daemon) pipeline running instead — its futures
            # still resolve when the device returns, and the worker exits on
            # its own once it observes _stop.
            self._completions.put(None)
            self._completer.join(timeout=5)
            self._completer = None
        # drain abandoned requests: their futures must resolve or callers
        # blocked on fut.get() hang forever (paused/never-started servers
        # accumulate queued work; the worker is joined, and a submit()
        # racing this drain re-drains after its own put)
        self._drain_queue()
