"""Dynamic serving engine: the deployed half of the paper's system.

Serves a supernet through its Pareto sub-networks:

* an executable cache keyed by SubnetSpec — each sub-network is a separate
  sliced-mode jit executable over the SAME parameter buffers, so switching
  architectures costs one dictionary lookup (the Dynamic-OFA trick: weights
  stay resident, no re-deployment);
* dynamic request batching (max batch / timeout);
* the runtime governor in the loop: every ``govern_every`` batches it
  re-reads the performance target + hardware state and may switch the
  active sub-network and the (modelled) DVFS point;
* wall-clock measurement hooks that feed the measured LUT.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.elastic import spec_to_static
from repro.core.types import SubnetSpec
from repro.runtime import hwmodel as hm


@dataclasses.dataclass
class Request:
    x: Any
    t_submit: float
    future: "queue.Queue"


class DynamicServer:
    def __init__(self, apply_fn: Callable, params, dims: Dict[str, int], *,
                 governor=None, max_batch: int = 8, timeout_ms: float = 5.0,
                 multiple_of: int = 1, warm_specs: Optional[List[SubnetSpec]]
                 = None):
        """``apply_fn(params, x, E) -> output`` (pure; jit-able).

        ``dims`` maps knob names to full sizes (see spec_to_static).
        """
        self.apply_fn = apply_fn
        self.params = params
        self.dims = dims
        self.governor = governor
        self.max_batch = max_batch
        self.timeout_s = timeout_ms / 1e3
        self.multiple_of = multiple_of
        self._cache: Dict[SubnetSpec, Any] = {}
        self._cache_lock = threading.Lock()
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._stop = threading.Event()
        self._paused = threading.Event()
        self._worker: Optional[threading.Thread] = None
        self.active_spec = SubnetSpec()
        self.active_point = None
        self.switch_log: List[dict] = []
        self.served = 0
        self.cancelled = 0
        # measured accounting: wall-clock busy time integrated against the
        # active hw slice's modelled power — the arbiter's per-tenant
        # MEASURED energy (vs the LUT's modelled energy_mj)
        self.busy_s = 0.0
        self.measured_energy_mj = 0.0
        for spec in warm_specs or []:
            self.executable(spec)

    # --- executable cache ---------------------------------------------------

    def executable(self, spec: SubnetSpec):
        # called from the worker thread AND synchronous infer()/measure()
        # callers (and, in arbiter mode, the shared constraint clock)
        with self._cache_lock:
            if spec not in self._cache:
                E = spec_to_static(spec, self.dims, self.multiple_of)
                fn = jax.jit(lambda p, x: self.apply_fn(p, x, E))
                self._cache[spec] = fn
            return self._cache[spec]

    def switch(self, spec: SubnetSpec, point=None):
        t0 = time.perf_counter()
        cold = spec not in self._cache
        self.executable(spec)
        self.switch_log.append({"spec": spec.name(), "cold": cold,
                                "ms": (time.perf_counter() - t0) * 1e3})
        self.active_spec = spec
        self.active_point = point

    # --- synchronous API ------------------------------------------------------

    def infer(self, x, spec: Optional[SubnetSpec] = None):
        spec = spec or self.active_spec
        fn = self.executable(spec)
        return jax.block_until_ready(fn(self.params, x))

    def measure(self, spec: SubnetSpec, x, iters: int = 5) -> float:
        """Median wall-clock ms for one batch under ``spec`` (post-warmup)."""
        fn = self.executable(spec)
        jax.block_until_ready(fn(self.params, x))
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(self.params, x))
            ts.append((time.perf_counter() - t0) * 1e3)
        return float(np.median(ts))

    # --- batched serving loop -------------------------------------------------

    def _cancel(self, r: Request, reason: str):
        r.future.put({"y": None, "cancelled": True, "error": reason,
                      "latency_ms": (time.perf_counter() - r.t_submit) * 1e3,
                      "subnet": None})
        self.cancelled += 1

    def submit(self, x) -> "queue.Queue":
        fut: "queue.Queue" = queue.Queue(maxsize=1)
        r = Request(x=x, t_submit=time.perf_counter(), future=fut)
        if self._stop.is_set():
            # stopped server: resolve immediately instead of queueing a
            # request no worker will ever pick up
            self._cancel(r, "server stopped")
            return fut
        self._queue.put(r)
        if self._stop.is_set() and not self.is_running:
            # stop() raced the put above and its drain may have missed us;
            # drain again (queue.get is atomic, each request resolves once)
            self._drain_queue()
        return fut

    def _drain_queue(self):
        while True:
            try:
                r = self._queue.get_nowait()
            except queue.Empty:
                break
            self._cancel(r, "server stopped")

    def _collect_batch(self) -> List[Request]:
        reqs: List[Request] = []
        deadline = None
        while len(reqs) < self.max_batch:
            timeout = None
            if reqs:
                timeout = max(0.0, deadline - time.perf_counter())
            try:
                r = self._queue.get(timeout=timeout if reqs else 0.05)
            except queue.Empty:
                break
            if not reqs:
                deadline = time.perf_counter() + self.timeout_s
            reqs.append(r)
        return reqs

    def pause(self):
        """Park the worker: requests queue up but no compute is consumed
        (the arbiter starves a workload this way — its slice is gone)."""
        self._paused.set()

    def resume(self):
        self._paused.clear()

    def _serve_loop(self, constraints_fn=None, govern_every: int = 4):
        n_batches = 0
        while not self._stop.is_set():
            if self._paused.is_set():
                self._stop.wait(0.01)
                continue
            reqs = self._collect_batch()
            if not reqs:
                continue
            if self.governor is not None and constraints_fn is not None \
                    and n_batches % govern_every == 0:
                c = constraints_fn()
                point = self.governor.select(c)
                if point.subnet != self.active_spec:
                    self.switch(point.subnet, point)
                else:
                    self.active_point = point
            xs = np.stack([np.asarray(r.x) for r in reqs])
            pad = self.max_batch - len(reqs)
            if pad:
                xs = np.concatenate([xs, np.zeros_like(xs[:1]).repeat(pad, 0)])
            t_batch = time.perf_counter()
            out = np.asarray(self.infer(xs))
            dt = time.perf_counter() - t_batch
            self.busy_s += dt
            hw = getattr(self.active_point, "hw_state", None) \
                or hm.HwState(chips=1, freq=1.0)
            self.measured_energy_mj += hm.slice_power_w(hw) * dt * 1e3
            for i, r in enumerate(reqs):
                r.future.put({"y": out[i],
                              "latency_ms": (time.perf_counter() - r.t_submit)
                              * 1e3,
                              "subnet": self.active_spec.name()})
            self.served += len(reqs)
            n_batches += 1

    @property
    def is_running(self) -> bool:
        return self._worker is not None and self._worker.is_alive()

    def start(self, constraints_fn=None, govern_every: int = 4):
        self._stop.clear()
        self._paused.clear()
        self._worker = threading.Thread(
            target=self._serve_loop, args=(constraints_fn, govern_every),
            daemon=True)
        self._worker.start()

    def stop(self):
        self._stop.set()
        if self._worker:
            self._worker.join(timeout=5)
            self._worker = None
        # drain abandoned requests: their futures must resolve or callers
        # blocked on fut.get() hang forever (paused/never-started servers
        # accumulate queued work; the worker is joined, and a submit()
        # racing this drain re-drains after its own put)
        self._drain_queue()
