"""Level-agnostic water-filling solver (the PR-6 tentpole extraction).

The paper's runtime layer "monitors dynamically changing performance
targets as well as hardware resources and constraints, and tries to meet
them by tuning the algorithm and hardware at the same time" — and the
hierarchical framing of Xun et al. (arXiv:2105.03608) runs that SAME
decision at every level of the resource hierarchy.  Before this module,
our reproduction made the decision twice with two different brains:
:class:`~repro.runtime.arbiter.ResourceArbiter` water-filled chips+watts
inside one node, while the cluster layer made ad-hoc all-or-nothing
placement calls above it.  This module is the one brain: the
water-filling core extracted out of the arbiter into pure functions over
``(demands, capacity, priced points)`` — no threads, no servers, no LUTs
— so the node-level arbiter and the cluster-level placement engine
(:mod:`repro.cluster.placement`) solve the same objective.

The objective, verbatim from the arbiter (and kept bit-identical — the
parity test in ``tests/test_waterfill.py`` replays the pre-extraction
algorithm against this one on seeded multi-tenant scenarios):

1. **min-share pass** — every demand, in priority order (ties by
   registration order), gets the *smallest* candidate under which a
   feasible point exists: minimal ``units`` (chips at node level, a
   replica's chip share at cluster level), then minimal un-priced cost,
   then maximal accuracy.  A demand with no feasible candidate falls
   back to its *fastest* best-effort candidate that fits the leftovers
   (target missed, marked infeasible).
2. **surplus passes** — pour the surplus back to a fixpoint.  Backlogged
   demands come FIRST (deepest backlog wins, then priority) and trade up
   to their *fastest* feasible candidate — surplus capacity drains
   backlog before it buys anyone accuracy.  Backlog-free demands spend
   surplus on strictly more accuracy, in priority order.

Costs are PRICED: the caller attaches whatever price multiplier its
level uses (the arbiter prices a slice's modelled watts by the tenant's
measured duty cycle; the placement engine prices a replica's watts the
same way).  The solver only ever adds and subtracts the numbers it is
given, so the caller's arithmetic — and therefore its allocations — are
unchanged by the extraction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

# Mirrors the arbiter's historical constants (imported back by it so the
# two can never drift).
MAX_FILL_PASSES = 8
# below this much pending work a demand counts as backlog-free (EWMAs
# decay geometrically and never exactly reach zero)
BACKLOG_MIN = 0.5


@dataclasses.dataclass(frozen=True)
class PricedPoint:
    """One candidate grant for one demand, priced for the solver.

    ``units`` is the indivisible capacity the grant consumes (chips for
    a node-level slice; a replica's chip share at cluster level);
    ``cost`` is what it charges the shared budget (priced watts —
    modelled slice power times the tenant's measured duty cycle);
    ``base_cost`` is the un-priced cost (modelled watts), which the
    min-share pass uses as its tie-break so pricing never changes WHICH
    minimal point is picked, only what it charges.  ``payload`` carries
    the caller's object through the solver untouched (an
    :class:`~repro.core.pareto.OpPoint`; a ``(node, point)`` pair at
    cluster level).
    """
    units: int
    cost: float
    base_cost: float
    latency_ms: float
    accuracy: float
    energy_mj: float
    payload: object = None


@dataclasses.dataclass
class Demand:
    """One consumer of the shared capacity, at either level.

    ``feasible(units_cap, cost_cap)`` enumerates candidates meeting the
    demand's own target under the caps; ``candidates(units_cap,
    cost_cap)`` enumerates everything that merely fits (the best-effort
    pool).  Both receive the cost cap in PRICED units and must apply
    their own un-pricing internally (the arbiter divides its LUT power
    filter by the tenant's duty-cycle scale) — the solver never
    converts, it only budgets.
    """
    name: str
    feasible: Callable[[int, float], Sequence[PricedPoint]]
    candidates: Callable[[int, float], Sequence[PricedPoint]]
    priority: int = 0
    backlog: float = 0.0


@dataclasses.dataclass
class Grant:
    """The solver's verdict for one demand."""
    demand: str
    point: Optional[PricedPoint]   # None => starved (nothing fits)
    feasible: bool                 # meets its target within its grant

    @property
    def units(self) -> int:
        return self.point.units if self.point is not None else 0

    @property
    def cost(self) -> float:
        return self.point.cost if self.point is not None else 0.0


def priority_order(demands: Sequence[Demand]) -> List[Demand]:
    """Stable priority order: ties broken by input (registration) order."""
    return sorted(demands, key=lambda d: -d.priority)


def fill_order(demands: Sequence[Demand]) -> List[Demand]:
    """Surplus-pass order: deepest backlog first, then priority (stable)."""
    return sorted(demands, key=lambda d: (-d.backlog, -d.priority))


def min_share_point(d: Demand, units_cap: int,
                    cost_cap: float) -> Optional[PricedPoint]:
    """Feasible candidate with the smallest (units, base_cost), max
    accuracy — the minimal share the min-share pass reserves."""
    pts = d.feasible(units_cap, cost_cap)
    if not pts:
        return None
    return min(pts, key=lambda p: (p.units, p.base_cost, -p.accuracy))


def best_effort_point(d: Demand, units_cap: int,
                      cost_cap: float) -> Optional[PricedPoint]:
    """Fastest candidate that fits the leftovers (target missed)."""
    pts = d.candidates(units_cap, cost_cap)
    if not pts:
        return None
    return min(pts, key=lambda p: p.latency_ms)


def waterfill(demands: Sequence[Demand], units: int,
              cost: float = math.inf, *,
              backlog_min: float = BACKLOG_MIN,
              max_passes: int = MAX_FILL_PASSES) -> Dict[str, Grant]:
    """Divide ``(units, cost)`` among the demands — the one objective.

    Pure: repeated calls with equal inputs return equal grants, and the
    arithmetic (subtraction order, comparison keys, epsilons) replicates
    the pre-extraction arbiter exactly.
    """
    order = priority_order(demands)
    units_left = units
    cost_left = cost
    grants: Dict[str, Grant] = {}

    # pass 1: minimal feasible share, highest priority first.  cost_left
    # is tracked in PRICED units throughout.
    for d in order:
        point = min_share_point(d, units_left, cost_left)
        feasible = point is not None
        if point is None:
            point = best_effort_point(d, units_left, cost_left)
        units_left -= point.units if point else 0
        cost_left -= point.cost if point else 0.0
        grants[d.name] = Grant(demand=d.name, point=point, feasible=feasible)

    # pass 2+: water-fill the surplus to a fixpoint.  Backlogged demands
    # come FIRST (deepest backlog wins, then priority) and trade up to
    # their fastest feasible candidate; backlog-free demands spend
    # surplus on strictly more accuracy, in priority order.
    filling = fill_order(order)
    for _ in range(max_passes):
        changed = False
        for d in filling:
            cur = grants[d.name]
            cap_units = cur.units + units_left
            cap_cost = cur.cost + cost_left
            pts = d.feasible(cap_units, cap_cost)
            if not pts:
                continue
            if d.backlog >= backlog_min:
                # drain the queue: fastest feasible point, accuracy as
                # the tie-break
                best = min(pts, key=lambda p: (p.latency_ms, -p.accuracy))
                upgraded = (not cur.feasible
                            or cur.point is None
                            or best.latency_ms
                            < cur.point.latency_ms - 1e-12)
            else:
                best = max(pts, key=lambda p: (p.accuracy, -p.energy_mj))
                upgraded = (not cur.feasible
                            or cur.point is None
                            or best.accuracy > cur.point.accuracy + 1e-12)
            if not upgraded:
                continue
            units_left = cap_units - best.units
            cost_left = cap_cost - best.cost
            grants[d.name] = Grant(demand=d.name, point=best, feasible=True)
            changed = True
        if not changed:
            break
    return grants
