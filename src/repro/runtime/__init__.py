"""Runtime resource management layer (the paper's middle layer).

hwmodel  — TPU v5e roofline/DVFS/energy model
lut      — (subnet x hw-state) profile tables (modelled + measured)
governor — joint algorithm+hardware governor and Linux-governor baselines
monitor  — latency/energy accounting and the paper's workload traces
engine   — dynamic serving engine with a sub-network executable cache
waterfill— level-agnostic water-filling solver (chip slices OR cluster
           replicas): min-share + backlog-first surplus over priced points
arbiter  — multi-workload water-filling arbiter over shared chips/power
           (delegates its objective to waterfill)
telemetry— measured-performance CalibrationStore closing the loop:
           engine-recorded (subnet, bucket) latency EWMAs and measured
           tenant watts feed the LUT columns and the arbiter's energy
           objective
"""
from repro.runtime.hwmodel import HwState, RooflineTerms, roofline, FREQ_LADDER
from repro.runtime.lut import (LUT, model_lut, measured_lut,
                               accuracy_surrogate, default_hw_states,
                               bucket_ladder, bucket_for, bucket_latency_ms)
from repro.runtime.governor import (Constraints, JointGovernor,
                                    PerformanceGovernor, SchedutilGovernor,
                                    StaticPrunedGovernor)
from repro.runtime.monitor import Monitor, paper_trace, run_governor, quantile
from repro.runtime.engine import DynamicServer
from repro.runtime.telemetry import CalibrationStore
# NOTE: the solver function itself stays namespaced
# (``waterfill.waterfill``) — re-exporting the bare name here would
# shadow the submodule attribute and break ``from repro.runtime import
# waterfill`` module imports
from repro.runtime.waterfill import Demand, Grant, PricedPoint
from repro.runtime.arbiter import (AdmissionError, Allocation,
                                   GlobalConstraints, Headroom,
                                   ResourceArbiter, Workload)
