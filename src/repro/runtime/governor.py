"""Runtime governors: the paper's joint algorithm+hardware manager and the
baselines it is compared against.

* :class:`JointGovernor` — the paper's approach: pick the
  (sub-network, hardware state) pair that meets the current latency target
  under the current hardware constraints with maximum accuracy, breaking
  ties by minimum energy.  Hysteresis avoids oscillation.
* :class:`PerformanceGovernor` — Linux ``performance``: max frequency,
  fixed full network (hardware knob pinned, no algorithm knob).
* :class:`SchedutilGovernor` — Linux ``schedutil``-like: frequency tracks
  utilisation (latency/target), fixed full network.
* :class:`StaticPrunedGovernor` — platform-aware static pruning
  (NetAdapt-style [1]): a single subnet chosen offline for the worst-case
  hardware configuration, then never changed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.pareto import OpPoint
from repro.runtime import hwmodel as hm
from repro.runtime.lut import LUT


@dataclasses.dataclass
class Constraints:
    target_latency_ms: float
    chips_available: int
    power_budget_w: Optional[float] = None
    min_accuracy: Optional[float] = None
    temperature_throttle: float = 1.0   # <1 caps the frequency ladder
    # multi-workload fields (read by the arbiter, ignored by single-model
    # governors): arbitration priority and the fraction of the global
    # budget this workload was granted.
    priority: int = 0
    share: float = 1.0


class GovernorBase:
    name = "base"

    def select(self, c: Constraints) -> OpPoint:
        raise NotImplementedError


class JointGovernor(GovernorBase):
    """The paper's runtime resource manager."""

    name = "joint"

    def __init__(self, lut: LUT, *, hysteresis_acc: float = 0.15,
                 hysteresis_energy: float = 0.05):
        self.lut = lut
        self.current: Optional[OpPoint] = None
        self.h_acc = hysteresis_acc
        self.h_energy = hysteresis_energy

    def _feasible(self, c: Constraints):
        return self.lut.feasible(
            max_latency_ms=c.target_latency_ms,
            chips_available=c.chips_available,
            power_budget_w=c.power_budget_w,
            min_accuracy=c.min_accuracy,
            max_freq=c.temperature_throttle)

    def select(self, c: Constraints) -> OpPoint:
        feasible = self._feasible(c)
        if not feasible:
            # infeasible target: degrade gracefully to the fastest point
            # that still respects the thermal throttle and power grant
            choice = self.lut.fastest(c.chips_available,
                                      max_freq=c.temperature_throttle,
                                      power_budget_w=c.power_budget_w)
            self.current = choice
            return choice
        # max accuracy, tie-break min energy
        best = max(feasible, key=lambda p: (p.accuracy, -p.energy_mj))
        cur = self.current
        if cur is not None and cur in feasible:
            # hysteresis: only switch for a real improvement
            if (best.accuracy - cur.accuracy) < self.h_acc and \
               best.energy_mj > cur.energy_mj * (1 - self.h_energy):
                best = cur
        self.current = best
        return best


class PerformanceGovernor(GovernorBase):
    """Max frequency, full network — hardware-only policy."""

    name = "performance"

    def __init__(self, lut: LUT, full_spec):
        self.point_by_chips = {}
        for p in lut.points:
            if p.subnet == full_spec and p.hw_state.freq == 1.0:
                self.point_by_chips[p.hw_state.chips] = p

    def select(self, c: Constraints) -> OpPoint:
        chips = max((k for k in self.point_by_chips
                     if k <= c.chips_available),
                    default=min(self.point_by_chips))
        return self.point_by_chips[chips]


class SchedutilGovernor(GovernorBase):
    """Utilisation-tracking DVFS, full network (no algorithm knob)."""

    name = "schedutil"

    def __init__(self, lut: LUT, full_spec):
        self.points = [p for p in lut.points if p.subnet == full_spec]
        self.freq = 1.0

    def select(self, c: Constraints) -> OpPoint:
        cands = [p for p in self.points
                 if p.hw_state.chips <= c.chips_available]
        if not cands:
            cands = self.points
        # pick the lowest frequency that still meets the target; if none
        # meets it, run at max frequency (classic schedutil ramp)
        meeting = [p for p in cands if p.latency_ms <= c.target_latency_ms]
        if meeting:
            choice = min(meeting, key=lambda p: p.hw_state.freq)
        else:
            choice = max(cands, key=lambda p: p.hw_state.freq)
        self.freq = choice.hw_state.freq
        return choice


class StaticPrunedGovernor(GovernorBase):
    """NetAdapt-style static pruning: one subnet sized offline for the
    worst-case hardware state, max frequency forever."""

    name = "static-pruned"

    def __init__(self, lut: LUT, *, worst_case: Constraints):
        feas = lut.feasible(max_latency_ms=worst_case.target_latency_ms,
                            chips_available=worst_case.chips_available)
        feas = [p for p in feas if p.hw_state.freq == 1.0]
        if feas:
            self.point = max(feas, key=lambda p: p.accuracy)
        else:
            self.point = lut.fastest(worst_case.chips_available)
        # the deployed static model: same subnet regardless of conditions
        self.points_same_subnet = [p for p in lut.points
                                   if p.subnet == self.point.subnet
                                   and p.hw_state.freq == 1.0]

    def select(self, c: Constraints) -> OpPoint:
        cands = [p for p in self.points_same_subnet
                 if p.hw_state.chips <= c.chips_available] or [self.point]
        return max(cands, key=lambda p: p.hw_state.chips)
