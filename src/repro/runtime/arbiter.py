"""Concurrent-workload runtime arbiter.

The paper's management layer monitors *multiple concurrent workloads* and
splits the hardware between them; the single-model :class:`JointGovernor`
cannot do that — each instance assumes it owns the whole machine, so two
governors co-running on one slice oversubscribe it.  The arbiter closes the
gap (the multi-DNN arbitration problem of Xun et al., arXiv:2105.03608):

* N registered workloads, each with its own LUT, latency target, priority
  and :class:`JointGovernor`;
* a global chip count + power budget, divided by **iterative
  water-filling**: first give every workload (in priority order) the
  *smallest* resource share under which a feasible :class:`OpPoint` exists,
  then pour the surplus back wherever it buys the most, until a full pass
  changes nothing.  The surplus pass is **queue-depth aware** (ROADMAP
  item): :meth:`set_active` carries each tenant's queue length and an
  arrival-rate EWMA (tenants with servers report their live queue depth
  automatically), and backlogged tenants are filled FIRST, trading up to
  their *fastest* feasible point so the surplus drains the backlog; only
  backlog-free tenants spend surplus on accuracy, in priority order as
  before;
* a shared constraint clock that re-arbitrates periodically and drives the
  per-workload governors/servers — multiple :class:`DynamicServer`
  instances run behind one arbiter, each keeping its own (thread-safe)
  executable cache.

Degradation is by priority: when the budget shrinks below the sum of
minimal shares, the lowest-priority workloads lose their targets first and
fall back to the fastest point that fits the leftovers.

The traffic layer (``repro.traffic``) adds two ROADMAP items on top:

* **admission control** — :meth:`ResourceArbiter.admission_check` asks
  whether a prospective class's minimal feasible share can EVER fit next
  to the minimal shares of its equal-or-higher-priority tenants;
  ``register(..., admission_under=g)`` raises :class:`AdmissionError`
  when it cannot (lower-priority tenants don't block admission — they
  are preemptable);
* **priority preemption** — :meth:`ResourceArbiter.preempt` re-arbitrates
  mid-cycle on behalf of a high-priority arrival, evicting lower-priority
  slices immediately instead of waiting for the next constraint clock
  tick.  Idle workloads release their slice via :meth:`set_active`.

With a :class:`repro.runtime.telemetry.CalibrationStore` attached
(``ResourceArbiter(calibration=...)``) the planner is CLOSED-LOOP (the
paper's runtime layer "monitors the dynamically changing algorithms'
performance targets as well as hardware resources"): feasibility runs on
calibrated point latencies (measured per-bucket EWMAs blended over the
analytic prior) and the power budget is charged the tenant's MEASURED
watts — modelled slice power scaled by its observed duty cycle — so the
energy objective the paper optimises is driven by observed energy, not
the open-loop ``slice_power_w`` model.

Lock discipline (enforced by ``pytest --lock-check``, see
:mod:`repro.analysis.locks`): the canonical project lock order is
``Cluster._admin_lock > Cluster._lock > ResourceArbiter._lock >
DynamicServer locks > Tracer/Metrics locks`` — outer locks left of inner.
``ResourceArbiter._lock`` (an RLock) guards ``_workloads`` and
``last_alloc``; it may be taken while a cluster lock is held (router load
probes, drain/failover) and may itself be held while taking engine locks
(``_drive_servers`` pausing/resuming servers), but never the reverse.
External readers of ``last_alloc`` go through :meth:`last_allocations`.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.analysis.guards import guarded_by
from repro.core.pareto import OpPoint
from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry
from repro.runtime import hwmodel as hm
from repro.runtime import waterfill as wf
from repro.runtime.engine import DynamicServer
from repro.runtime.governor import Constraints, JointGovernor
from repro.runtime.lut import LUT

# the water-filling core lives in repro.runtime.waterfill since PR 6 (the
# cluster placement engine runs the SAME solver over nodes); the aliases
# keep the arbiter's historical knobs pointing at the one definition
_MAX_FILL_PASSES = wf.MAX_FILL_PASSES
# new latency observations before a tenant's calibrated LUT is rebuilt
_LUT_REFRESH_SAMPLES = 16
# smoothing for the arrival-rate EWMA reported through set_active()
_EWMA_BETA = 0.6
# below this many pending requests a tenant counts as backlog-free (the
# EWMA decays geometrically and never exactly reaches zero — without a
# threshold one reported burst would keep a tenant "backlogged" forever)
_BACKLOG_MIN = wf.BACKLOG_MIN


class AdmissionError(RuntimeError):
    """A registration whose minimal feasible share can never fit."""


# the per-tenant accounting series (label ``tenant=``) that replaced the
# old ad-hoc ``_stats`` dicts; :meth:`ResourceArbiter.summary` reads them
# back into its historical row shape, and unregister/export clears them so
# a re-registered tenant never inherits a predecessor's meet-rate
_STAT_SERIES = ("arbiter_cycles_total", "arbiter_met_total",
                "arbiter_energy_mj_total", "arbiter_share_sum",
                "arbiter_preemptions_total")
_STAT_GAUGES = ("arbiter_chips", "arbiter_backlog")


@dataclasses.dataclass
class GlobalConstraints:
    """The shared machine state the arbiter divides each cycle."""
    total_chips: int
    power_budget_w: Optional[float] = None
    temperature_throttle: float = 1.0


@dataclasses.dataclass
class Workload:
    """One tenant: a governed model with its own profile and target."""
    name: str
    lut: LUT
    target_latency_ms: float
    priority: int = 0
    min_accuracy: Optional[float] = None
    governor: Optional[JointGovernor] = None
    server: Optional[DynamicServer] = None
    active: bool = True   # idle tenants release their slice (set_active)
    # backlog signals (queue-depth-aware water-filling): reported through
    # set_active() or refreshed from server.queue_depth() each arbitration
    queue_depth: int = 0
    arrival_ewma: float = 0.0   # requests/s, smoothed
    # exactly-once rate smoothing: arrivals pulled off the server since
    # the last EWMA update, and when that update happened (monotonic s).
    # A mid-cycle preempt() accumulates counts here instead of smoothing
    # a partial window a second time.
    rate_pending: int = 0
    rate_last_t: Optional[float] = None
    # last seen server.measured_energy_mj (per-tick measured-watts delta)
    energy_last_mj: float = 0.0
    # brownout mode (chaos reliability): the ORIGINAL target while the
    # tenant is pinned to its degraded one; None = not browned out
    brownout_base_ms: Optional[float] = None
    # SLO-watchtower burn signal (0 = healthy): while a fast burn-rate
    # alert is active on this tenant's class, the surplus pass treats its
    # backlog as (1 + alert_pressure)x — capacity shifts toward the
    # burning class BEFORE failure pressure would have reacted
    alert_pressure: float = 0.0

    def __post_init__(self):
        if self.governor is None:
            self.governor = JointGovernor(self.lut)


@dataclasses.dataclass
class Headroom:
    """Unreserved capacity after minimal shares (cluster admission export)."""
    chips: int
    power_w: float   # math.inf when the node has no power budget


@dataclasses.dataclass
class Allocation:
    """One workload's share of the machine for one arbitration cycle."""
    workload: str
    point: Optional[OpPoint]   # None => starved (nothing fits the leftovers)
    chips: int
    power_w: float
    feasible: bool             # meets its latency target within its share
    share: float = 0.0         # chips / total_chips
    # what the slice costs against the global power budget: modelled
    # watts scaled by the tenant's MEASURED duty cycle when a calibration
    # store is attached (== power_w otherwise).  Summing priced watts is
    # how the energy-aware water-filling packs more tenants under one
    # budget without oversubscribing observed draw.
    priced_power_w: float = 0.0


@guarded_by("_lock", "_workloads", "last_alloc")
class ResourceArbiter:
    """Water-filling allocator + shared constraint clock over N workloads."""

    def __init__(self, *, interval_s: float = 0.05, calibration=None,
                 time_fn: Callable[[], float] = time.monotonic,
                 tracer=None, metrics: Optional[MetricsRegistry] = None):
        self.interval_s = interval_s
        # measured-performance feedback (repro.runtime.telemetry
        # .CalibrationStore): when set, water-filling plans off CALIBRATED
        # point latencies and prices candidate slices with each tenant's
        # measured watts instead of the raw modelled slice_power_w
        self.calibration = calibration
        self._time_fn = time_fn   # injectable for deterministic tests
        self._workloads: Dict[str, Workload] = {}   # guarded-by: _lock
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._clock: Optional[threading.Thread] = None
        # per-tenant calibrated-LUT cache: (raw lut, store version, eff)
        self._lut_cache: Dict[str, Tuple[LUT, int, LUT]] = {}
        # recent cycles only; summary() uses the running accumulators so a
        # 20 Hz clock doesn't grow memory without bound
        self.alloc_log: Deque[Dict[str, Allocation]] = collections.deque(
            maxlen=4096)
        self.last_alloc: Dict[str, Allocation] = {}   # guarded-by: _lock
        # per-tenant accounting lives in the metrics registry (see
        # _STAT_SERIES); the arbiter owns its registry by default — two
        # nodes can both host a tenant "api", so arbiter registries are
        # NOT shared cluster-wide (the cluster keeps its own for
        # router/placement counters)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # live tracing: ARBITRATE decision spans per tick.  The cluster
        # sets trace_label to the node name; the virtual-time simulators
        # leave arbiter tracers unset and emit their own spans at sim time
        self.tracer = tracer
        self.trace_label: Optional[str] = None

    # --- registration -------------------------------------------------------

    def register(self, name: str, lut: LUT, target_latency_ms: float, *,
                 priority: int = 0, min_accuracy: Optional[float] = None,
                 governor: Optional[JointGovernor] = None,
                 server: Optional[DynamicServer] = None,
                 admission_under: Optional[GlobalConstraints] = None
                 ) -> Workload:
        with self._lock:
            if name in self._workloads:
                raise ValueError(f"workload {name!r} already registered")
            if admission_under is not None and self.admission_check(
                    lut, target_latency_ms, admission_under,
                    priority=priority, min_accuracy=min_accuracy) is None:
                raise AdmissionError(
                    f"workload {name!r}: no feasible point under "
                    f"{target_latency_ms}ms fits {admission_under.total_chips}"
                    f" chips after equal-or-higher-priority minimal shares")
            w = Workload(name=name, lut=lut,
                         target_latency_ms=target_latency_ms,
                         priority=priority, min_accuracy=min_accuracy,
                         governor=governor, server=server)
            self._workloads[name] = w
            if (server is not None and not server.is_running
                    and self._clock is not None and self._clock.is_alive()):
                # late arrival while the clock is already running
                server.start()
            return w

    def _touch_stats(self, name: str):
        """Create the tenant's full accounting row at once — summary()'s
        row-existence semantics (absent vs all-zero) match the old dicts."""
        for s in _STAT_SERIES:
            self.metrics.counter(s, tenant=name)

    def _clear_stats(self, name: str):
        for s in _STAT_SERIES + _STAT_GAUGES:
            self.metrics.remove(s, tenant=name)

    def unregister(self, name: str):
        with self._lock:
            w = self._workloads.pop(name, None)
            self.last_alloc.pop(name, None)
            # a later tenant registering under the same name must not
            # inherit this one's accumulated cycles/meet-rate/energy
            self._clear_stats(name)
            self._lut_cache.pop(name, None)
            if w is not None and w.server is not None:
                w.server.stop()   # the clock drove it; don't leak the worker

    def export_tenant(self, name: str) -> Workload:
        """Remove a tenant WITHOUT stopping its server (migration hook).

        The cluster layer moves a draining node's registrations to
        surviving nodes: the returned :class:`Workload` carries the
        lut/target/priority needed to re-register elsewhere, and the
        server (if any) stays up so in-flight work still resolves.
        Stats are cleared like :meth:`unregister` — the new host starts
        the tenant's accounting fresh.
        """
        with self._lock:
            w = self._workloads.pop(name)   # KeyError: unknown workload
            self.last_alloc.pop(name, None)
            self._clear_stats(name)
            self._lut_cache.pop(name, None)
            return w

    def set_active(self, name: str, active: bool = True, *,
                   queue_depth: Optional[int] = None,
                   arrival_rate_rps: Optional[float] = None):
        """Idle workloads release their slice (an empty request queue needs
        no chips); the traffic driver toggles this as queues fill/drain.

        ``queue_depth`` and ``arrival_rate_rps`` carry the tenant's backlog
        into the arbiter (ROADMAP queue-depth-aware water-filling): the
        surplus pass fills the most backlogged tenant first, buying it
        speed instead of accuracy.  The arrival rate is EWMA-smoothed here
        so callers can report instantaneous per-epoch rates.

        For a tenant WITH a server the reported rate is ignored: the
        server's own arrival counter is authoritative and is smoothed
        once per interval by :meth:`arbitrate` — accepting a second
        report of the same arrivals here would run them through the EWMA
        twice (the double-smoothing bug: the twice-smoothed value then
        feeds the server's adaptive batching window at an effective
        beta² instead of the configured beta).
        """
        with self._lock:
            w = self._workloads[name]
            w.active = active
            if queue_depth is not None:
                w.queue_depth = max(0, int(queue_depth))
            if arrival_rate_rps is not None and w.server is None:
                w.arrival_ewma = (_EWMA_BETA * w.arrival_ewma
                                  + (1.0 - _EWMA_BETA)
                                  * max(0.0, float(arrival_rate_rps)))

    def set_brownout(self, name: str, degraded_target_ms: Optional[float]):
        """Pin a tenant to a relaxed latency target (chaos brownout mode).

        Under sustained fault pressure the reliability layer prefers
        serving every request a bit slower over shedding some outright:
        passing a value saves the tenant's original target in
        ``brownout_base_ms`` and arbitrates against the degraded one
        (a looser target admits cheaper LUT points, freeing chips on the
        shrunken post-fault cluster); passing ``None`` restores the
        original.  Idempotent in both directions — re-entering brownout
        keeps the first saved base, restoring twice is a no-op.
        """
        with self._lock:
            w = self._workloads[name]
            if degraded_target_ms is None:
                if w.brownout_base_ms is not None:
                    w.target_latency_ms = w.brownout_base_ms
                    w.brownout_base_ms = None
            else:
                if w.brownout_base_ms is None:
                    w.brownout_base_ms = w.target_latency_ms
                    self.metrics.counter("arbiter_brownouts_total",
                                         tenant=name).inc()
                w.target_latency_ms = float(degraded_target_ms)

    def set_alert_pressure(self, name: str, pressure: float):
        """Feed one tenant's watchtower burn signal into arbitration.

        ``pressure`` is the normalised fast-window burn (0 = no active
        alert); the demand phrasing scales the tenant's backlog by
        ``1 + pressure`` so water-filling's surplus pass favours the
        burning class.  Unknown tenants are ignored (the watchtower may
        monitor classes a node does not host)."""
        with self._lock:
            w = self._workloads.get(name)
            if w is None:
                return
            w.alert_pressure = max(0.0, float(pressure))
            self.metrics.gauge("arbiter_alert_pressure",
                               tenant=name).set(w.alert_pressure)

    def _backlog(self, w: Workload) -> float:
        """Pending work the surplus pass should drain: queued requests plus
        the arrivals expected before the next arbitration."""
        return w.queue_depth + w.arrival_ewma * self.interval_s

    def tenants(self) -> List[str]:
        """Registered workload names, in registration order."""
        with self._lock:
            return list(self._workloads)

    def backlog(self, name: str) -> float:
        """One tenant's pending-work signal (cluster routing reads it)."""
        with self._lock:
            return self._backlog(self._workloads[name])

    def last_allocations(self) -> Dict[str, "Allocation"]:
        """Snapshot of the most recent per-tenant allocations.

        The locked accessor external readers (health checks, drivers,
        simulators) must use instead of touching ``last_alloc`` directly —
        ``arbitrate`` rebinds it mid-cycle under ``_lock``.
        """
        with self._lock:
            return dict(self.last_alloc)

    def total_backlog(self) -> float:
        """Summed pending work across active tenants — the per-node load
        signal the cluster router's least-loaded/p2c policies compare."""
        with self._lock:
            return sum(self._backlog(w) for w in self._workloads.values()
                       if w.active)

    def _priority_order(self) -> List[Workload]:
        # stable sort: ties broken by registration order
        return sorted(self._workloads.values(), key=lambda w: -w.priority)

    # --- admission control --------------------------------------------------

    def admission_check(self, lut: LUT, target_latency_ms: float,
                        g: GlobalConstraints, *, priority: int = 0,
                        min_accuracy: Optional[float] = None
                        ) -> Optional[OpPoint]:
        """Can a prospective class ever get its minimal feasible share?

        Reserves the minimal feasible share of every equal-or-higher-
        priority tenant (lower-priority tenants are preemptable, so they
        don't block admission) and looks for a feasible point in the
        remainder.  Returns that point, or None — reject the registration
        (ROADMAP admission-control item).
        """
        with self._lock:
            chips_left, power_left = self._after_min_shares(
                g, min_priority=priority)
            probe = Workload(name="__probe__", lut=lut,
                             target_latency_ms=target_latency_ms,
                             priority=priority, min_accuracy=min_accuracy)
            return self._min_share_point(probe, chips_left, power_left,
                                         g.temperature_throttle)

    def _after_min_shares(self, g: GlobalConstraints,
                          min_priority: Optional[int] = None
                          ) -> "tuple[int, float]":
        """(chips, power) left after reserving tenants' minimal feasible
        shares — all tenants, or only those at ``min_priority`` and above
        (lower-priority tenants are preemptable)."""
        chips_left = g.total_chips
        power_left = (g.power_budget_w if g.power_budget_w is not None
                      else math.inf)
        for w in self._priority_order():
            if min_priority is not None and w.priority < min_priority:
                continue
            p = self._min_share_point(w, chips_left, power_left,
                                      g.temperature_throttle)
            if p is not None:
                chips_left -= p.hw_state.chips
                power_left -= (hm.slice_power_w(p.hw_state)
                               * self._power_scale(w.name))
        return chips_left, power_left

    def headroom(self, g: GlobalConstraints) -> "Headroom":
        """Chips/power left after EVERY tenant's minimal feasible share —
        the node's observability export (dashboards, `cluster_headroom`).

        This is deliberately more conservative than admission: it
        reserves all tenants, while the admission path
        (:meth:`admission_check`, called per node by
        ``repro.cluster.cluster_admission``) skips lower-priority ones
        because they are preemptable.  Don't compute admission from this
        number.
        """
        with self._lock:
            chips_left, power_left = self._after_min_shares(g)
            return Headroom(chips=chips_left, power_w=power_left)

    # --- calibration (measured-performance feedback) ------------------------

    def _power_scale(self, name: str) -> float:
        """Measured/modelled watts ratio for one tenant (1.0 uncalibrated).

        Pricing a candidate slice at ``slice_power_w(hw) * scale`` makes
        the water-filling's power arithmetic run on OBSERVED draw: a
        tenant that historically keeps its slice 30 % busy charges the
        budget 30 % of the modelled board power.  Equivalently, its
        power cap is divided by the scale before the LUT filter.
        """
        if self.calibration is None:
            return 1.0
        return max(1e-6, self.calibration.power_scale(name))

    def _lut_for(self, w: Workload) -> LUT:
        """The tenant's planning LUT: raw, or calibrated point latencies.

        With a calibration store, each point's pad-to-max latency is
        re-estimated from the measured per-bucket EWMAs
        (:meth:`CalibrationStore.point_latency_ms` — analytic value as
        the prior, measurement blended in by sample count), so
        feasibility checks run on what the engine actually observed.

        Cached per tenant against the store's latency-observation
        counter, refreshed only after ``_LUT_REFRESH_SAMPLES`` new
        observations: under live traffic every completed batch bumps the
        counter, and rebuilding the table per 20 Hz tick would contend
        the store lock with the completer for no benefit — the blend
        moves negligibly per sample (EWMA + count confidence).
        """
        if self.calibration is None:
            return w.lut
        version = self.calibration.version()
        cached = self._lut_cache.get(w.name)
        if (cached is not None and cached[0] is w.lut
                and version - cached[1] < _LUT_REFRESH_SAMPLES):
            return cached[2]
        eff = LUT([dataclasses.replace(
            p, latency_ms=self.calibration.point_latency_ms(
                p.subnet, p.latency_ms)) for p in w.lut.points])
        if w.name != "__probe__":
            self._lut_cache[w.name] = (w.lut, version, eff)
        return eff

    # --- water-filling (delegates to repro.runtime.waterfill) ---------------

    @staticmethod
    def _throttled(pts, throttle: float):
        if throttle < 1.0:
            pts = [p for p in pts if p.hw_state.freq <= throttle]
        return pts

    def _priced(self, p: OpPoint, scale: float) -> wf.PricedPoint:
        """One LUT point, phrased for the level-agnostic solver."""
        base = hm.slice_power_w(p.hw_state)
        return wf.PricedPoint(units=p.hw_state.chips, cost=base * scale,
                              base_cost=base, latency_ms=p.latency_ms,
                              accuracy=p.accuracy, energy_mj=p.energy_mj,
                              payload=p)

    def _demand_for(self, w: Workload, throttle: float) -> wf.Demand:
        """Phrase one workload as a solver demand.

        The candidate enumerators close over the tenant's calibrated LUT
        and duty-cycle price: the solver budgets in PRICED watts, so the
        callbacks un-price the cost cap back to modelled watts for the
        LUT's power filter — exactly the arithmetic the pre-extraction
        arbiter ran inline.
        """
        scale = self._power_scale(w.name)

        def feasible(chips_cap: int, power_cap: float):
            pts = self._lut_for(w).feasible(
                max_latency_ms=w.target_latency_ms,
                chips_available=chips_cap,
                power_budget_w=(None if math.isinf(power_cap)
                                else power_cap / scale),
                min_accuracy=w.min_accuracy, max_freq=throttle)
            return [self._priced(p, scale) for p in pts]

        def candidates(chips_cap: int, power_cap: float):
            cands = [p for p in self._lut_for(w).points
                     if p.hw_state.chips <= chips_cap
                     and hm.slice_power_w(p.hw_state) * scale <= power_cap]
            cands = self._throttled(cands, throttle) or cands
            return [self._priced(p, scale) for p in cands]

        return wf.Demand(name=w.name, feasible=feasible,
                         candidates=candidates, priority=w.priority,
                         backlog=self._backlog(w)
                         * (1.0 + w.alert_pressure))

    def _min_share_point(self, w: Workload, chips_cap: int,
                         power_cap: float, throttle: float
                         ) -> Optional[OpPoint]:
        """Feasible point with the smallest (chips, power), max accuracy.

        ``power_cap`` is in PRICED watts (measured-duty-cycle scaled);
        the demand callback converts it back to modelled watts for the
        LUT filter.
        """
        got = wf.min_share_point(self._demand_for(w, throttle),
                                 chips_cap, power_cap)
        return got.payload if got is not None else None

    def _best_effort_point(self, w: Workload, chips_cap: int,
                           power_cap: float, throttle: float
                           ) -> Optional[OpPoint]:
        """Fastest point that fits the leftover budget (target missed)."""
        got = wf.best_effort_point(self._demand_for(w, throttle),
                                   chips_cap, power_cap)
        return got.payload if got is not None else None

    def _refresh_live_tenant(self, w: Workload, now: float):
        """Pull a live tenant's measured signals (backlog, arrival rate,
        energy) — each observation smoothed EXACTLY once.

        Arrivals accumulate in ``rate_pending`` and enter the EWMA only
        when at least half an interval has elapsed since the last update,
        with the ACTUAL elapsed time as the rate denominator.  A
        mid-cycle :meth:`preempt` therefore neither re-smooths a partial
        window nor inflates the rate by dividing a few arrivals by a full
        ``interval_s``; the counts it drains are folded into the next
        tick's window instead.
        """
        w.queue_depth = w.server.queue_depth()
        w.rate_pending += w.server.take_arrival_count()
        elapsed = (self.interval_s if w.rate_last_t is None
                   else now - w.rate_last_t)
        if elapsed < 0.5 * self.interval_s:
            return
        w.arrival_ewma = (_EWMA_BETA * w.arrival_ewma
                          + (1.0 - _EWMA_BETA)
                          * (w.rate_pending / max(elapsed, 1e-9)))
        w.rate_pending = 0
        w.rate_last_t = now
        if self.calibration is not None:
            # measured tenant watts over the window vs the modelled watts
            # of the slice it held: the duty-cycle ratio that prices its
            # candidate points in the next water-filling pass
            energy_mj = w.server.measured_energy_mj
            d_mj = energy_mj - w.energy_last_mj
            w.energy_last_mj = energy_mj
            last = self.last_alloc.get(w.name)
            if last is not None and last.point is not None and d_mj >= 0:
                self.calibration.note_power(
                    w.name, (d_mj / max(elapsed, 1e-9)) / 1e3,
                    hm.slice_power_w(last.point.hw_state))

    def arbitrate(self, g: GlobalConstraints) -> Dict[str, Allocation]:
        """Divide (chips, power) among all registered workloads.

        The min-share + backlog-first-surplus objective itself lives in
        :func:`repro.runtime.waterfill.waterfill` (shared with the
        cluster placement engine); this method phrases the active
        tenants as demands, runs the solver, and converts grants back
        into :class:`Allocation`s — bit-identical to the pre-extraction
        inline algorithm (see ``tests/test_waterfill.py``).
        """
        with self._lock:
            now = self._time_fn()
            for w in self._workloads.values():
                if w.server is not None:
                    # live tenants report backlog/rate/energy automatically
                    self._refresh_live_tenant(w, now)
            order = [w for w in self._priority_order() if w.active]
            power = (g.power_budget_w if g.power_budget_w is not None
                     else math.inf)
            grants = wf.waterfill(
                [self._demand_for(w, g.temperature_throttle) for w in order],
                g.total_chips, power)
            allocs: Dict[str, Allocation] = {}
            for w in order:
                grant = grants[w.name]
                point: Optional[OpPoint] = (grant.point.payload
                                            if grant.point is not None
                                            else None)
                allocs[w.name] = Allocation(
                    workload=w.name, point=point,
                    chips=point.hw_state.chips if point else 0,
                    power_w=(hm.slice_power_w(point.hw_state)
                             if point else 0.0),
                    feasible=grant.feasible,
                    priced_power_w=grant.cost)

            # inactive tenants hold nothing this cycle (slice released)
            for w in self._workloads.values():
                if w.name not in allocs:
                    allocs[w.name] = Allocation(workload=w.name, point=None,
                                                chips=0, power_w=0.0,
                                                feasible=False)
            for a in allocs.values():
                a.share = a.chips / g.total_chips if g.total_chips else 0.0
            self.last_alloc = allocs
            return allocs

    # --- per-workload constraints + governor/server drive -------------------

    def constraints_for(self, w: Workload, alloc: Allocation,
                        g: GlobalConstraints) -> Constraints:
        """The arbiter's grant, phrased as the workload's own Constraints."""
        return Constraints(
            target_latency_ms=w.target_latency_ms,
            chips_available=max(alloc.chips, 1),
            power_budget_w=alloc.power_w if alloc.power_w > 0 else None,
            min_accuracy=w.min_accuracy,
            temperature_throttle=g.temperature_throttle,
            priority=w.priority,
            share=alloc.share)

    def _drive_servers(self, allocs: Dict[str, Allocation],
                       g: GlobalConstraints):
        for w in self._workloads.values():
            alloc = allocs[w.name]
            if alloc.point is None:
                # starved or idle: its slice went to other tenants — park
                # the server so it doesn't compute on chips it lost
                if w.server is not None:
                    w.server.pause()
                continue
            c = self.constraints_for(w, alloc, g)
            if self.calibration is not None and hasattr(w.governor, "lut"):
                # the governor must re-pick from the same calibrated
                # table the water-filling planned with, or it would undo
                # the measurement loop with analytic latencies
                w.governor.lut = self._lut_for(w)
            point = w.governor.select(c)
            if w.server is not None:
                # the arbiter's EWMA sizes the server's adaptive batching
                # window (a no-op unless adaptive_window=True)
                w.server.note_arrival_rate(w.arrival_ewma)
                if point.subnet != w.server.active_spec:
                    w.server.switch(point.subnet, point)
                else:
                    w.server.active_point = point
                w.server.resume()

    def tick(self, g: GlobalConstraints) -> Dict[str, Allocation]:
        """One arbitration cycle: allocate, govern, switch/pause servers."""
        with self._lock:
            t0 = self.tracer.clock() if self.tracer is not None else 0.0
            allocs = self.arbitrate(g)
            self._drive_servers(allocs, g)
            self.alloc_log.append(allocs)
            m = self.metrics
            for name, a in allocs.items():
                w = self._workloads[name]
                if not w.active:
                    continue   # idle: no demand, don't dilute meet_rate
                self._touch_stats(name)
                m.counter("arbiter_cycles_total", tenant=name).inc()
                if a.feasible:
                    m.counter("arbiter_met_total", tenant=name).inc()
                m.counter("arbiter_share_sum", tenant=name).inc(a.share)
                if a.point is not None:
                    m.counter("arbiter_energy_mj_total", tenant=name).inc(
                        a.point.energy_mj)
                m.gauge("arbiter_chips", tenant=name).set(a.chips)
                m.gauge("arbiter_backlog", tenant=name).set(self._backlog(w))
            if self.tracer is not None:
                self.tracer.decision(
                    obs.ARBITRATE, t0, self.tracer.clock(),
                    node=self.trace_label,
                    tenants=sum(w.active
                                for w in self._workloads.values()),
                    granted=sum(a.chips for a in allocs.values()))
            return allocs

    def preempt(self, name: str, g: GlobalConstraints) -> Allocation:
        """Mid-cycle priority preemption (ROADMAP item).

        A high-priority arrival must not wait out the constraint clock:
        re-arbitrate NOW on behalf of ``name``.  Water-filling in priority
        order means any chips/power the arrival needs are reclaimed from
        strictly lower-priority tenants, whose servers are parked or
        downgraded in the same call — the eviction lands mid-cycle, not at
        the next tick.
        """
        with self._lock:
            w = self._workloads[name]   # KeyError: unknown workload
            w.active = True
            t0 = self.tracer.clock() if self.tracer is not None else 0.0
            allocs = self.arbitrate(g)
            self._drive_servers(allocs, g)
            self._touch_stats(name)
            self.metrics.counter("arbiter_preemptions_total",
                                 tenant=name).inc()
            if self.tracer is not None:
                self.tracer.decision(obs.PREEMPT, t0, self.tracer.clock(),
                                     node=self.trace_label, for_cls=name)
            return allocs[name]

    # --- shared constraint clock --------------------------------------------

    def start(self, global_constraints_fn: Callable[[], GlobalConstraints]):
        """Run the constraint clock: re-arbitrate every ``interval_s``."""
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self.tick(global_constraints_fn())
                self._stop.wait(self.interval_s)

        self._clock = threading.Thread(target=loop, daemon=True)
        self._clock.start()
        with self._lock:
            servers = [w.server for w in self._workloads.values()]
        for server in servers:
            if server is not None and not server.is_running:
                # servers run governor-less: the arbiter's clock governs
                server.start()

    def stop(self):
        self._stop.set()
        if self._clock:
            self._clock.join(timeout=5)
            self._clock = None
        with self._lock:
            for w in self._workloads.values():
                if w.server is not None:
                    w.server.stop()

    # --- accounting ---------------------------------------------------------

    def summary(self) -> dict:
        """Meet-rate and energy per workload over ALL cycles (running
        accumulators — alloc_log only keeps the recent window).

        ``energy_mj`` is modelled (LUT points held per cycle);
        ``measured_energy_mj`` integrates the server's real batch
        wall-clock against the active slice's power model — the ROADMAP's
        measured per-tenant energy accounting (minimal version).

        The rows keep their historical shape but are READ BACK from the
        metrics registry (``self.metrics``) — the same numbers a
        Prometheus scrape of the registry exports.
        """
        out = {}
        m = self.metrics
        tenants_seen = {lbl.get("tenant")
                        for lbl in m.labels_of("arbiter_cycles_total")}
        with self._lock:
            # snapshot: register/unregister mutate the dict concurrently
            workloads = list(self._workloads.items())
        for name, w in workloads:
            exists = name in tenants_seen
            n = m.value("arbiter_cycles_total", tenant=name)
            if not exists or not n:
                row = {"cycles": 0}
            else:
                row = {"cycles": int(n),
                       "meet_rate": round(
                           m.value("arbiter_met_total", tenant=name) / n, 4),
                       "energy_mj": round(
                           m.value("arbiter_energy_mj_total", tenant=name),
                           2),
                       "mean_share": round(
                           m.value("arbiter_share_sum", tenant=name) / n, 4)}
            if exists:
                row["preemptions"] = int(
                    m.value("arbiter_preemptions_total", tenant=name))
            if w.server is not None:
                row["measured_energy_mj"] = round(
                    w.server.measured_energy_mj, 2)
                row["busy_s"] = round(w.server.busy_s, 4)
            if w.queue_depth or w.arrival_ewma:
                row["queue_depth"] = w.queue_depth
                row["arrival_ewma_rps"] = round(w.arrival_ewma, 2)
            if w.brownout_base_ms is not None:
                row["brownout"] = True
            if w.alert_pressure > 0.0:
                row["alert_pressure"] = round(w.alert_pressure, 3)
            if self.calibration is not None:
                row["power_scale"] = round(self._power_scale(name), 4)
            out[name] = row
        return out
