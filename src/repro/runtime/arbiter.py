"""Concurrent-workload runtime arbiter.

The paper's management layer monitors *multiple concurrent workloads* and
splits the hardware between them; the single-model :class:`JointGovernor`
cannot do that — each instance assumes it owns the whole machine, so two
governors co-running on one slice oversubscribe it.  The arbiter closes the
gap (the multi-DNN arbitration problem of Xun et al., arXiv:2105.03608):

* N registered workloads, each with its own LUT, latency target, priority
  and :class:`JointGovernor`;
* a global chip count + power budget, divided by **iterative
  water-filling**: first give every workload (in priority order) the
  *smallest* resource share under which a feasible :class:`OpPoint` exists,
  then pour the surplus back in priority order wherever it buys accuracy,
  until a full pass changes nothing;
* a shared constraint clock that re-arbitrates periodically and drives the
  per-workload governors/servers — multiple :class:`DynamicServer`
  instances run behind one arbiter, each keeping its own (thread-safe)
  executable cache.

Degradation is by priority: when the budget shrinks below the sum of
minimal shares, the lowest-priority workloads lose their targets first and
fall back to the fastest point that fits the leftovers.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
from typing import Callable, Deque, Dict, List, Optional

from repro.core.pareto import OpPoint
from repro.runtime import hwmodel as hm
from repro.runtime.engine import DynamicServer
from repro.runtime.governor import Constraints, JointGovernor
from repro.runtime.lut import LUT

_MAX_FILL_PASSES = 8


@dataclasses.dataclass
class GlobalConstraints:
    """The shared machine state the arbiter divides each cycle."""
    total_chips: int
    power_budget_w: Optional[float] = None
    temperature_throttle: float = 1.0


@dataclasses.dataclass
class Workload:
    """One tenant: a governed model with its own profile and target."""
    name: str
    lut: LUT
    target_latency_ms: float
    priority: int = 0
    min_accuracy: Optional[float] = None
    governor: Optional[JointGovernor] = None
    server: Optional[DynamicServer] = None

    def __post_init__(self):
        if self.governor is None:
            self.governor = JointGovernor(self.lut)


@dataclasses.dataclass
class Allocation:
    """One workload's share of the machine for one arbitration cycle."""
    workload: str
    point: Optional[OpPoint]   # None => starved (nothing fits the leftovers)
    chips: int
    power_w: float
    feasible: bool             # meets its latency target within its share
    share: float = 0.0         # chips / total_chips


class ResourceArbiter:
    """Water-filling allocator + shared constraint clock over N workloads."""

    def __init__(self, *, interval_s: float = 0.05):
        self.interval_s = interval_s
        self._workloads: Dict[str, Workload] = {}
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._clock: Optional[threading.Thread] = None
        # recent cycles only; summary() uses the running accumulators so a
        # 20 Hz clock doesn't grow memory without bound
        self.alloc_log: Deque[Dict[str, Allocation]] = collections.deque(
            maxlen=4096)
        self.last_alloc: Dict[str, Allocation] = {}
        self._stats: Dict[str, Dict[str, float]] = {}

    # --- registration -------------------------------------------------------

    def register(self, name: str, lut: LUT, target_latency_ms: float, *,
                 priority: int = 0, min_accuracy: Optional[float] = None,
                 governor: Optional[JointGovernor] = None,
                 server: Optional[DynamicServer] = None) -> Workload:
        with self._lock:
            if name in self._workloads:
                raise ValueError(f"workload {name!r} already registered")
            w = Workload(name=name, lut=lut,
                         target_latency_ms=target_latency_ms,
                         priority=priority, min_accuracy=min_accuracy,
                         governor=governor, server=server)
            self._workloads[name] = w
            if (server is not None and not server.is_running
                    and self._clock is not None and self._clock.is_alive()):
                # late arrival while the clock is already running
                server.start()
            return w

    def unregister(self, name: str):
        with self._lock:
            w = self._workloads.pop(name, None)
            self.last_alloc.pop(name, None)
            if w is not None and w.server is not None:
                w.server.stop()   # the clock drove it; don't leak the worker

    def _priority_order(self) -> List[Workload]:
        # stable sort: ties broken by registration order
        return sorted(self._workloads.values(), key=lambda w: -w.priority)

    # --- water-filling ------------------------------------------------------

    @staticmethod
    def _throttled(pts, throttle: float):
        if throttle < 1.0:
            pts = [p for p in pts if p.hw_state.freq <= throttle]
        return pts

    def _min_share_point(self, w: Workload, chips_cap: int,
                         power_cap: float, throttle: float
                         ) -> Optional[OpPoint]:
        """Feasible point with the smallest (chips, power), max accuracy."""
        pts = w.lut.feasible(max_latency_ms=w.target_latency_ms,
                             chips_available=chips_cap,
                             power_budget_w=(None if math.isinf(power_cap)
                                             else power_cap),
                             min_accuracy=w.min_accuracy, max_freq=throttle)
        if not pts:
            return None
        return min(pts, key=lambda p: (p.hw_state.chips,
                                       hm.slice_power_w(p.hw_state),
                                       -p.accuracy))

    def _best_effort_point(self, w: Workload, chips_cap: int,
                           power_cap: float, throttle: float
                           ) -> Optional[OpPoint]:
        """Fastest point that fits the leftover budget (target missed)."""
        cands = [p for p in w.lut.points
                 if p.hw_state.chips <= chips_cap
                 and hm.slice_power_w(p.hw_state) <= power_cap]
        cands = self._throttled(cands, throttle) or cands
        if not cands:
            return None
        return min(cands, key=lambda p: p.latency_ms)

    def arbitrate(self, g: GlobalConstraints) -> Dict[str, Allocation]:
        """Divide (chips, power) among all registered workloads."""
        with self._lock:
            order = self._priority_order()
            chips_left = g.total_chips
            power_left = (g.power_budget_w if g.power_budget_w is not None
                          else math.inf)
            allocs: Dict[str, Allocation] = {}

            # pass 1: minimal feasible share, highest priority first
            for w in order:
                point = self._min_share_point(w, chips_left, power_left,
                                              g.temperature_throttle)
                feasible = point is not None
                if point is None:
                    point = self._best_effort_point(
                        w, chips_left, power_left, g.temperature_throttle)
                chips = point.hw_state.chips if point else 0
                power = hm.slice_power_w(point.hw_state) if point else 0.0
                chips_left -= chips
                power_left -= power
                allocs[w.name] = Allocation(workload=w.name, point=point,
                                            chips=chips, power_w=power,
                                            feasible=feasible)

            # pass 2+: water-fill the surplus — in priority order, let a
            # workload trade its share up whenever the surplus buys either
            # feasibility or strictly more accuracy; repeat to a fixpoint.
            for _ in range(_MAX_FILL_PASSES):
                changed = False
                for w in order:
                    cur = allocs[w.name]
                    cap_chips = cur.chips + chips_left
                    cap_power = cur.power_w + power_left
                    pts = w.lut.feasible(
                        max_latency_ms=w.target_latency_ms,
                        chips_available=cap_chips,
                        power_budget_w=(None if math.isinf(cap_power)
                                        else cap_power),
                        min_accuracy=w.min_accuracy,
                        max_freq=g.temperature_throttle)
                    if not pts:
                        continue
                    best = max(pts, key=lambda p: (p.accuracy, -p.energy_mj))
                    upgraded = (not cur.feasible
                                or cur.point is None
                                or best.accuracy > cur.point.accuracy + 1e-12)
                    if not upgraded:
                        continue
                    chips_left = cap_chips - best.hw_state.chips
                    power_left = cap_power - hm.slice_power_w(best.hw_state)
                    allocs[w.name] = Allocation(
                        workload=w.name, point=best,
                        chips=best.hw_state.chips,
                        power_w=hm.slice_power_w(best.hw_state),
                        feasible=True)
                    changed = True
                if not changed:
                    break

            for a in allocs.values():
                a.share = a.chips / g.total_chips if g.total_chips else 0.0
            self.last_alloc = allocs
            return allocs

    # --- per-workload constraints + governor/server drive -------------------

    def constraints_for(self, w: Workload, alloc: Allocation,
                        g: GlobalConstraints) -> Constraints:
        """The arbiter's grant, phrased as the workload's own Constraints."""
        return Constraints(
            target_latency_ms=w.target_latency_ms,
            chips_available=max(alloc.chips, 1),
            power_budget_w=alloc.power_w if alloc.power_w > 0 else None,
            min_accuracy=w.min_accuracy,
            temperature_throttle=g.temperature_throttle,
            priority=w.priority,
            share=alloc.share)

    def tick(self, g: GlobalConstraints) -> Dict[str, Allocation]:
        """One arbitration cycle: allocate, govern, switch/pause servers."""
        with self._lock:
            allocs = self.arbitrate(g)
            for w in self._workloads.values():
                alloc = allocs[w.name]
                if alloc.point is None:
                    # starved: its slice went to other tenants — park the
                    # server so it doesn't keep computing on chips it lost
                    if w.server is not None:
                        w.server.pause()
                    continue
                c = self.constraints_for(w, alloc, g)
                point = w.governor.select(c)
                if w.server is not None:
                    if point.subnet != w.server.active_spec:
                        w.server.switch(point.subnet, point)
                    else:
                        w.server.active_point = point
                    w.server.resume()
            self.alloc_log.append(allocs)
            for name, a in allocs.items():
                s = self._stats.setdefault(
                    name, {"cycles": 0, "met": 0, "energy_mj": 0.0,
                           "share_sum": 0.0})
                s["cycles"] += 1
                s["met"] += a.feasible
                s["share_sum"] += a.share
                if a.point is not None:
                    s["energy_mj"] += a.point.energy_mj
            return allocs

    # --- shared constraint clock --------------------------------------------

    def start(self, global_constraints_fn: Callable[[], GlobalConstraints]):
        """Run the constraint clock: re-arbitrate every ``interval_s``."""
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self.tick(global_constraints_fn())
                self._stop.wait(self.interval_s)

        self._clock = threading.Thread(target=loop, daemon=True)
        self._clock.start()
        for w in self._workloads.values():
            if w.server is not None and not w.server.is_running:
                # servers run governor-less: the arbiter's clock governs
                w.server.start()

    def stop(self):
        self._stop.set()
        if self._clock:
            self._clock.join(timeout=5)
            self._clock = None
        with self._lock:
            for w in self._workloads.values():
                if w.server is not None:
                    w.server.stop()

    # --- accounting ---------------------------------------------------------

    def summary(self) -> dict:
        """Meet-rate and energy per workload over ALL cycles (running
        accumulators — alloc_log only keeps the recent window)."""
        out = {}
        for name in self._workloads:
            s = self._stats.get(name)
            if not s or not s["cycles"]:
                out[name] = {"cycles": 0}
                continue
            n = s["cycles"]
            out[name] = {"cycles": n,
                         "meet_rate": round(s["met"] / n, 4),
                         "energy_mj": round(s["energy_mj"], 2),
                         "mean_share": round(s["share_sum"] / n, 4)}
        return out
