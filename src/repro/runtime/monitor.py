"""Runtime monitoring + workload/hardware trace simulation.

The paper's management layer "monitors the dynamically changing algorithm
performance targets as well as hardware resources and constraints".  The
monitor tracks latency violations and integrated energy; the trace
simulator reproduces the paper's experimental conditions: phase-changing
latency targets [2], thermal throttling, and co-running applications
stealing compute.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence

# the ONE nearest-rank quantile every percentile in the repo reports
# through (traffic reports, monitor summaries, histogram percentiles);
# re-exported here for backward compatibility — canonical home is
# repro.obs.metrics
from repro.obs.metrics import quantile  # noqa: F401
from repro.runtime.governor import Constraints


@dataclasses.dataclass
class StepLog:
    t: float
    target_ms: float
    latency_ms: float
    energy_mj: float
    accuracy: float
    subnet: str
    hw: str
    violated: bool


@dataclasses.dataclass
class Monitor:
    logs: List[StepLog] = dataclasses.field(default_factory=list)

    def record(self, t, c: Constraints, point, latency_ms=None):
        lat = latency_ms if latency_ms is not None else point.latency_ms
        self.logs.append(StepLog(
            t=t, target_ms=c.target_latency_ms, latency_ms=lat,
            energy_mj=point.energy_mj, accuracy=point.accuracy,
            subnet=point.subnet.name() if hasattr(point.subnet, "name")
            else str(point.subnet),
            hw=point.hw_state.name(), violated=lat > c.target_latency_ms))

    @property
    def total_energy_mj(self) -> float:
        return sum(l.energy_mj for l in self.logs)

    @property
    def violation_rate(self) -> float:
        return (sum(l.violated for l in self.logs) / len(self.logs)
                if self.logs else 0.0)

    @property
    def mean_latency_ms(self) -> float:
        return (sum(l.latency_ms for l in self.logs) / len(self.logs)
                if self.logs else 0.0)

    @property
    def mean_accuracy(self) -> float:
        return (sum(l.accuracy for l in self.logs) / len(self.logs)
                if self.logs else 0.0)

    def latency_percentiles(self, qs: Sequence[float] = (50, 95, 99)) -> dict:
        lats = [l.latency_ms for l in self.logs]
        return {f"p{q:g}_ms": round(quantile(lats, q), 3) for q in qs}

    def summary(self) -> dict:
        return {"steps": len(self.logs),
                "energy_mj": round(self.total_energy_mj, 2),
                "violation_rate": round(self.violation_rate, 4),
                "mean_latency_ms": round(self.mean_latency_ms, 3),
                "mean_accuracy": round(self.mean_accuracy, 3)}


def paper_trace(n_steps: int = 300, *, chips: int = 256,
                base_target_ms: float = 30.0, seed: int = 0
                ) -> Iterator[Constraints]:
    """The paper's runtime conditions as a deterministic trace:

    - three application phases with different latency targets [2],
    - a thermal-throttling window (frequency cap 0.7),
    - a co-running workload window (half the chips taken).
    """
    import numpy as np
    rng = np.random.default_rng(seed)
    for i in range(n_steps):
        phase = (i // 50) % 3
        target = base_target_ms * (1.0, 0.5, 2.0)[phase]
        target *= float(1.0 + 0.1 * rng.standard_normal())
        throttle = 0.7 if 120 <= i < 180 else 1.0
        avail = chips // 2 if 200 <= i < 260 else chips
        yield Constraints(target_latency_ms=max(target, 1.0),
                          chips_available=avail,
                          temperature_throttle=throttle)


def run_governor(governor, trace, monitor: Optional[Monitor] = None,
                 measure_fn=None) -> Monitor:
    """Drive a governor through a trace; optionally measure real latency."""
    mon = monitor or Monitor()
    for i, c in enumerate(trace):
        point = governor.select(c)
        lat = measure_fn(point) if measure_fn else None
        mon.record(float(i), c, point, latency_ms=lat)
    return mon
