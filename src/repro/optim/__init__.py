from repro.optim.api import make_optimizer, clip_by_global_norm
