"""Optimizers as pure pytree transforms (no external deps).

``make_optimizer(name, **hp)`` returns ``(init_fn, update_fn)``:
    state = init_fn(params)
    params, state = update_fn(params, grads, state, step)

All states inherit the parameter sharding (elementwise or factored over the
trailing dims), so ZeRO-style partitioning falls out of the param
PartitionSpecs for free.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-6))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _wd_ok(path_s: str) -> bool:
    """No weight decay on norms/biases/BN."""
    return not any(t in path_s for t in ("bias", "scale", "ln", "norm", "bn",
                                         "pos", "cls"))


def _zip_update(params, grads, state_tree, fn):
    """Apply fn(path, p, g, s) -> (p', s') leafwise, where state_tree may be
    deeper than params at each leaf (flatten_up_to handles it)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    g_flat = treedef.flatten_up_to(grads)
    s_flat = treedef.flatten_up_to(state_tree)
    new_p, new_s = [], []
    for (path, p), g, s in zip(leaves, g_flat, s_flat):
        np_, ns_ = fn(_path_str(path), p, g, s)
        new_p.append(np_)
        new_s.append(ns_)
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            jax.tree_util.tree_unflatten(treedef, new_s))


# --- AdamW -------------------------------------------------------------------

def adamw(lr: float = 1e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1):
    def init(params):
        return {"s": jax.tree_util.tree_map(
            lambda p: {"mu": jnp.zeros_like(p, dtype=jnp.float32),
                       "nu": jnp.zeros_like(p, dtype=jnp.float32)}, params)}

    def update(params, grads, state, step):
        t = step.astype(jnp.float32) + 1.0
        c1, c2 = 1.0 - b1 ** t, 1.0 - b2 ** t

        def fn(path_s, p, g, s):
            g = g.astype(jnp.float32)
            mu = b1 * s["mu"] + (1 - b1) * g
            nu = b2 * s["nu"] + (1 - b2) * jnp.square(g)
            u = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
            if weight_decay and _wd_ok(path_s):
                u = u + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * u).astype(p.dtype),
                    {"mu": mu, "nu": nu})

        new_p, new_s = _zip_update(params, grads, state["s"], fn)
        return new_p, {"s": new_s}

    return init, update


# --- Adafactor (factored second moment; for 1T-param configs) ---------------

def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0):
    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128

    def init(params):
        def st(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"s": jax.tree_util.tree_map(st, params)}

    def update(params, grads, state, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def fn(path_s, p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                vr_hat = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                          eps)
                u = g * jax.lax.rsqrt(vr_hat)[..., None] \
                      * jax.lax.rsqrt(jnp.maximum(vc, eps))[..., None, :]
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), ns

        new_p, new_s = _zip_update(params, grads, state["s"], fn)
        return new_p, {"s": new_s}

    return init, update


# --- SGD momentum ------------------------------------------------------------

def sgdm(lr: float = 0.1, momentum: float = 0.9, weight_decay: float = 1e-4):
    def init(params):
        return {"s": jax.tree_util.tree_map(
            lambda p: {"m": jnp.zeros_like(p, dtype=jnp.float32)}, params)}

    def update(params, grads, state, step):
        def fn(path_s, p, g, s):
            g = g.astype(jnp.float32)
            if weight_decay and _wd_ok(path_s):
                g = g + weight_decay * p.astype(jnp.float32)
            m = momentum * s["m"] + g
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), {"m": m}

        new_p, new_s = _zip_update(params, grads, state["s"], fn)
        return new_p, {"s": new_s}

    return init, update


def make_optimizer(name: str, **hp) -> Tuple[Callable, Callable]:
    return {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}[name](**hp)
