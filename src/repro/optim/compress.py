"""Gradient compression: int8 quantisation with error feedback.

Large-scale trick: the data-parallel gradient all-reduce moves
params-sized fp32/bf16 tensors every step; quantising to int8 (per-tensor
scale) cuts those bytes 4x at the cost of quantisation noise, which error
feedback (residual carried to the next step) provably corrects for SGD-
style updates.  Used by the compressed-allreduce train-step variant
(examples/train_supernet.py --compress) and unit-tested for convergence.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jax.Array, err: jax.Array):
    """Error-feedback compression of one gradient leaf.

    Returns (decompressed_gradient, new_error).  The caller all-reduces the
    int8 payload; here (single-program view) we model the lossy channel.
    """
    g = g.astype(jnp.float32) + err
    q, scale = quantize_int8(g)
    deq = dequantize_int8(q, scale)
    return deq, g - deq


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str):
    """shard_map body: quantise locally, all-reduce int8 payloads (summed in
    int32 to avoid overflow), dequantise with the max scale.

    This is the explicit-collective form used when the train step manages
    its own data-parallel reduction (bytes on the wire: 1/4 of fp32).
    """
    g = g.astype(jnp.float32) + err
    q, scale = quantize_int8(g)
    # max scale across replicas keeps dequantisation conservative
    scale_max = jax.lax.pmax(scale, axis_name)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    mean = qsum.astype(jnp.float32) * scale_max / n.astype(jnp.float32)
    local = dequantize_int8(q, scale)
    return mean, g - local


def tree_compress(grads, errors):
    """Apply error-feedback compression leafwise; returns (grads, errors)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    outs = [compress_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e


def init_errors(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
