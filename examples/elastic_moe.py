"""Beyond-paper: dynamic DNN knobs for Mixture-of-Experts LMs.

Channel/layer scaling (the paper) extends naturally to MoE: active expert
count and top-k become runtime knobs.  This example runs the deepseek-moe
smoke config at several (experts, top_k, ffn) operating points and shows
per-token active compute vs measured latency — the LUT a governor would
use to serve an MoE LM under a latency target.

    PYTHONPATH=src python examples/elastic_moe.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.flops import lm_model_flops
from repro.models.transformer import lm_apply, lm_init

arch = get_arch("deepseek-moe-16b")
cfg = arch.make_smoke()
params = lm_init(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)

points = [
    ("full (8e top2 f32)", {}),
    ("half experts", {"a_experts": 4}),
    ("top-1 routing", {"top_k": 1}),
    ("half expert width", {"a_ff": cfg.moe.d_ff // 2}),
    ("min subnet", {"a_experts": 4, "top_k": 1, "a_ff": cfg.moe.d_ff // 2,
                    "a_layers": cfg.n_layers // 2}),
]

print(f"{cfg.name}: {cfg.n_layers}L, {cfg.moe.n_experts} experts "
      f"top-{cfg.moe.top_k} (+{cfg.moe.n_shared} shared)\n")
print(f"{'operating point':24s} {'latency':>10s} {'rel flops':>10s}")
full_lat = None
for name, E in points:
    fn = jax.jit(lambda p, t: lm_apply(p, t, cfg, E=E)[0])
    jax.block_until_ready(fn(params, toks))
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(fn(params, toks))
    ms = (time.perf_counter() - t0) / 10 * 1e3
    full_lat = full_lat or ms
    # analytic active compute of this operating point
    import dataclasses
    top_k = E.get("top_k", cfg.moe.top_k)
    n_exp = E.get("a_experts", cfg.moe.n_experts)
    d_ff = int(E.get("a_ff", cfg.moe.d_ff))
    c2 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, top_k=top_k,
                                     n_experts=n_exp, d_ff=d_ff))
    rel = (lm_model_flops(c2, "prefill", 4, 32)
           / lm_model_flops(cfg, "prefill", 4, 32))
    print(f"{name:24s} {ms:8.2f}ms {rel:9.2f}x")
print("\n(the masked executable is shared: every row above ran without "
      "recompilation)")
