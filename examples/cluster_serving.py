"""Multi-node cluster serving walkthrough.

Eight acts:

1. **Scale-out (virtual time)** — one overloaded SLO class replayed
   against 1-node and 2-node clusters through the deterministic
   simulator: goodput ~doubles on the same seeded trace.
2. **Routing under skew (virtual time)** — a 256-chip node next to a
   64-chip node; round-robin floods the slow node and the p95 explodes,
   power-of-two-choices follows the backlog-per-chip signal instead.
3. **Lifecycle (live)** — two tiny real ViT nodes behind the
   :class:`~repro.cluster.Cluster` front-end: requests route p2c, one
   node drains (backlog served, tenants migrated), then the survivor is
   fail-stopped (every outstanding future resolves with an error payload
   instead of hanging).
4. **Wedged-node auto-failover (health checking)** — the failure mode
   acts 1-3 can't see: a node that silently stops completing while still
   accepting routed work (hung worker, lost device).  First in virtual
   time (``wedge_at`` + ``health_epochs``: the stall detector fails the
   node within K epochs and the survivor absorbs the class), then live —
   a cluster started with ``health_interval_s`` watches every node's
   completion counters, and a wedged replica's stuck futures all resolve
   with failed payloads instead of hanging their callers.
5. **Placement engine (virtual time)** — the PR-6 rebalancer end to
   end: an overloaded class first-fit-parked on ONE hot node scales
   out through priced migrations (warmup charged, hysteresis-gated); a
   backlogged high-priority class cross-node-preempts a co-located
   low-priority replica that keeps serving from its other home; a
   burst wakes a STANDBY node; and once the burst passes, expensive
   energy parks the idle spare again.
6. **Tracing a tail request (observability)** — a node loses its
   accelerators mid-run while the whole class is first-fit-parked on
   it; the rebalancer prices a paired move onto the healthy spare and
   requeues the stranded backlog behind that replica's warmup.  With a
   :class:`repro.obs.Tracer` attached, the ``migrate`` decision span
   shows the priced warmup window, the tail-biased trace buffer fills
   with exactly those migration victims, and one victim's span tree
   decomposes its latency into warming + queue + device — the warming
   span ends at the instant the placement engine charged for
   (``t_rebalance + cost_s``), now visible per request.
7. **Chaos day with request reliability (virtual time)** — a seeded
   :class:`repro.chaos.Scenario` rack-fails half the cluster mid-burst
   and throttles a survivor's DVFS ladder.  Replayed bare, the dead
   rack's queues resolve ``failed`` and the interactive class bleeds
   goodput; replayed with a :class:`repro.chaos.Reliability` layer,
   failed attempts re-route through the router under deadline-aware
   backoff, interactive requests hedge onto a second replica, and
   sustained pressure brownouts the class to its degraded target —
   the interactive p95 stays inside the SLO across the whole day.
8. **SLO watchtower (virtual time)** — a deep thermal DVFS ladder
   throttles both serving nodes: completions come back LATE without a
   single failure, so act 7's failure-pressure EWMA never trips.  A
   :class:`repro.obs.Watchtower` fed by the same span pipeline fires a
   multi-window fast-burn page within epochs, the alert's attribution
   names ``chaos:thermal`` as the root cause (the span decomposition
   shows where the latency went, the injection log shows why), and —
   replayed with ``actuate=True`` — alert pressure boosts the class's
   water-fill demand, relaxes its quality target without suspending
   admission control, and wakes the standby pool NOW instead of at the
   scheduled autoscale instant: the interactive p95 lands back inside
   the SLO.

    PYTHONPATH=src python examples/cluster_serving.py
"""
import time

import jax
import numpy as np

from repro.cluster import (DEAD, FIRST_FIT, LEAST_LOADED, P2C, ROUND_ROBIN,
                           STANDBY, UP, Cluster, ClusterNode,
                           simulate_cluster)
from repro.core.types import ElasticSpace, SubnetSpec
from repro.models.vit import ViTConfig, vit_apply, vit_init
from repro.runtime import DynamicServer, GlobalConstraints, model_lut
from repro.runtime import hwmodel as hm
from repro.traffic import DEGRADE, SHED, SLOClass, poisson

SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))
TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008, t_collective=0.004)


def make_nodes(capacities):
    return [ClusterNode(name=f"n{i}",
                        g_fn=lambda t, c=cap: GlobalConstraints(total_chips=c))
            for i, cap in enumerate(capacities)]


def act_1_scale_out():
    lut = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)
    cls = [SLOClass("api", deadline_ms=200.0, priority=2, drop_policy=SHED)]
    stream = poisson(1000.0, 6.0, seed=1)
    print("== act 1: scale-out on one seeded trace ==")
    for caps in ([64], [64, 64]):
        rep = simulate_cluster(cls, {"api": lut}, {"api": list(stream)},
                               make_nodes(caps), router=P2C)
        s = rep.classes["api"]
        print(f"  {len(caps)} node(s): goodput={s.good}/{s.submitted} "
              f"p95={s.p(95):.1f}ms routed={rep.routed['api']}")


def act_2_skewed_routing():
    lut = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)
    cls = [SLOClass("web", deadline_ms=200.0, priority=2,
                    drop_policy=DEGRADE)]
    stream = poisson(1000.0, 6.0, seed=2)
    print("== act 2: p2c vs round-robin under 4:1 skewed capacity ==")
    for router in (ROUND_ROBIN, P2C):
        rep = simulate_cluster(cls, {"web": lut}, {"web": list(stream)},
                               make_nodes([256, 64]), router=router)
        s = rep.classes["web"]
        print(f"  {router:12s}: p95={s.p(95):8.1f}ms goodput={s.good} "
              f"routed={rep.routed['web']}")


def tiny_server(_node):
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2, d_model=32,
                    n_heads=4, d_ff=64, n_classes=4,
                    compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    return DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                         params, dims)


def act_3_live_lifecycle():
    print("== act 3: live drain + fail-stop ==")
    lut = model_lut([SubnetSpec()], full_terms=TERMS, full_chips=2,
                    hw_states=[hm.HwState(chips=1, freq=1.0)])
    nodes = [ClusterNode(name=f"n{i}",
                         g_fn=lambda t: GlobalConstraints(total_chips=2))
             for i in range(2)]
    cluster = Cluster(nodes, router=P2C)
    placed = cluster.register("api", lut, target_latency_ms=500.0,
                              priority=1, make_server=tiny_server)
    print(f"  admitted 'api' on {placed}")
    cluster.start()
    x = np.zeros((16, 16, 3), "float32")
    outs = [cluster.submit("api", x).get(timeout=30) for _ in range(8)]
    print(f"  served {sum(not o.get('cancelled') for o in outs)}/8, "
          f"routed: {cluster.summary()['routed']['api']}")

    drained = cluster.drain("n0", timeout_s=15.0)
    print(f"  drained n0 (backlog fully served: {drained}); "
          f"placements now {cluster.placements_snapshot()['api']}")
    out = cluster.submit("api", x).get(timeout=30)
    print(f"  post-drain request served on the survivor: "
          f"{not out.get('cancelled')}")

    futs = [cluster.submit("api", x) for _ in range(4)]
    cluster.fail("n1", reason="rack lost power")
    resolved = [f.get(timeout=10) for f in futs]   # nothing hangs
    print(f"  fail-stopped n1: {len(resolved)}/4 futures resolved "
          f"({sum(bool(o.get('cancelled')) for o in resolved)} with error "
          f"payloads)")
    cluster.stop()


def act_4_wedged_node_auto_failover():
    print("== act 4: wedged-node auto-failover (stall health check) ==")
    # virtual time first: n1 wedges at t=2s — still routable, completing
    # nothing — and the stall detector fails it over after 3 flat epochs
    lut = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)
    cls = [SLOClass("api", deadline_ms=200.0, priority=2, drop_policy=SHED)]
    stream = poisson(1000.0, 6.0, seed=3)
    rep = simulate_cluster(cls, {"api": lut}, {"api": list(stream)},
                           make_nodes([64, 64]), router=ROUND_ROBIN,
                           wedge_at={"n1": 2.0}, health_epochs=3)
    s = rep.classes["api"]
    print(f"  sim: n1 wedged at t=2.0s, health failed it at "
          f"t={rep.health_failed[0][0]:.1f}s; "
          f"completed={s.completed} failed={s.failed} dropped={s.dropped} "
          f"(all {s.submitted} accounted)")

    # live: a hung worker — completions flat while futures pile up.  The
    # health thread fails the node; nothing hangs.
    nodes = [ClusterNode(name=f"n{i}",
                         g_fn=lambda t: GlobalConstraints(total_chips=2))
             for i in range(2)]
    cluster = Cluster(nodes, router=P2C, health_interval_s=0.05,
                      health_epochs=3)
    lut1 = model_lut([SubnetSpec()], full_terms=TERMS, full_chips=2,
                     hw_states=[hm.HwState(chips=1, freq=1.0)])
    cluster.register("api", lut1, target_latency_ms=500.0, priority=1,
                     make_server=tiny_server)
    x = np.zeros((16, 16, 3), "float32")
    for node in nodes:       # warmed replicas: a cold compile looks like
        node.servers["api"].warm([SubnetSpec()], example_input=x)  # a stall
    cluster.start()
    srv = nodes[0].servers["api"]
    srv.resume = lambda: None      # simulate a hung worker: stays parked
    srv.pause()
    futs = [srv.submit(x) for _ in range(4)]
    deadline = time.time() + 15.0
    while nodes[0].state != DEAD and time.time() < deadline:
        time.sleep(0.02)
    outs = [f.get(timeout=10) for f in futs]
    print(f"  live: health checker failed "
          f"{cluster.summary()['health_failed']} "
          f"({outs[0]['error']!r})")
    print(f"  live: {sum(o.get('failed', False) for o in outs)}/4 stuck "
          f"futures resolved with failed payloads, survivor serves: "
          f"{not cluster.submit('api', x).get(timeout=30).get('cancelled')}")
    cluster.stop()


def act_5_placement_engine():
    print("== act 5: global placement engine ==")
    lut = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)

    # 5a: hot node -> priced migrations.  First-fit parks the whole
    # class on n0; the rebalancer pays warmup to scale it out.
    cls = [SLOClass("api", deadline_ms=200.0, priority=2,
                    drop_policy=DEGRADE)]
    kw = dict(luts={"api": lut},
              streams={"api": poisson(2500.0, 6.0, seed=5)},
              router=LEAST_LOADED, placement_mode=FIRST_FIT)
    def nodes3():
        return make_nodes([256, 256, 256])
    static = simulate_cluster(cls, nodes=nodes3(), **kw)
    rebal = simulate_cluster(cls, nodes=nodes3(),
                             rebalance_at=[0.5, 1.5, 2.5], **kw)
    print(f"  5a hot node: static goodput={static.total_goodput}, "
          f"rebalanced={rebal.total_goodput} after "
          f"{len(rebal.migrations)} priced migrations "
          f"(warmup {rebal.migration_energy_mj / 1e3:.0f}J charged)")

    # 5b: cross-node preemption.  A backlogged priority-3 class evicts
    # the priority-0 replica sharing its node; the victim keeps serving
    # from its other home.
    rep = simulate_cluster(
        [SLOClass("hot", deadline_ms=200.0, priority=3,
                  drop_policy=DEGRADE),
         SLOClass("bulk", deadline_ms=200.0, priority=0,
                  drop_policy=DEGRADE)],
        {"hot": lut, "bulk": lut},
        {"hot": poisson(2500.0, 3.0, seed=17),
         "bulk": poisson(50.0, 3.0, seed=18)},
        make_nodes([256, 256]), router=LEAST_LOADED, rebalance_at=[0.5])
    ev = rep.preempted[0]
    print(f"  5b preemption: {ev[1]!r} evicted from {ev[2]} for "
          f"{ev[3]!r} at t={ev[0]:.1f}s; bulk still completed "
          f"{rep.classes['bulk'].completed}")

    # 5c: autoscale up.  A burst against UP + STANDBY: sustained
    # backlog wakes the spare, which serves after its priced warmup.
    up_nodes = make_nodes([256, 256])
    up_nodes[1].state = STANDBY
    rep = simulate_cluster(cls, {"api": lut},
                           {"api": poisson(3000.0, 4.0, seed=13)},
                           up_nodes, router=LEAST_LOADED,
                           scale_at=[1.0, 2.0, 3.0])
    print(f"  5c spin-up: {rep.scale_events} "
          f"(n1 then served {rep.routed['api'].get('n1', 0)} requests)")

    # 5d: autoscale down.  A trickle one node absorbs + expensive
    # energy parks the idle spare back to STANDBY.
    down_nodes = make_nodes([256, 64])
    rep = simulate_cluster(
        [SLOClass("api", deadline_ms=200.0, priority=2,
                  drop_policy=SHED)],
        {"api": lut}, {"api": [i * 0.25 for i in range(40)]},
        down_nodes, router=LEAST_LOADED, scale_at=[8.0],
        energy_price_fn=lambda t: 2.0)
    print(f"  5d spin-down: {rep.scale_events} -> n1 state "
          f"{down_nodes[1].state!r} (idle + price 2.0)")


def act_6_trace_a_tail_request():
    print("== act 6: trace a tail request through a priced migration ==")
    from repro.obs import (MIGRATE, WARMING, Tracer, decompose_latency,
                           format_decomposition)
    lut = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)
    cls = [SLOClass("api", deadline_ms=200.0, priority=2,
                    drop_policy=DEGRADE)]
    # n0 loses its accelerators at t=0.6s while first-fit holds the whole
    # class there; backlog piles up until the 1.3s rebalance prices a
    # paired move onto n1 and requeues the stranded queue behind its
    # warmup — those requests are the tail this act goes looking for.
    def dipped(t):
        return GlobalConstraints(total_chips=256 if t < 0.6 else 1)
    nodes = [ClusterNode(name="n0", g_fn=dipped),
             ClusterNode(name="n1",
                         g_fn=lambda t: GlobalConstraints(total_chips=256))]
    tracer = Tracer(clock=lambda: 0.0)   # sims stamp virtual times
    rep = simulate_cluster(cls, {"api": lut},
                           {"api": poisson(1500.0, 4.0, seed=5)},
                           nodes, router=LEAST_LOADED,
                           placement_mode=FIRST_FIT,
                           rebalance_at=[1.3], replicas=1, tracer=tracer)
    mig = next(s for s in tracer.decisions if s.name == MIGRATE)
    print(f"  migration: 'api' {mig.attrs['src']} -> {mig.node} at "
          f"t={mig.t0:.2f}s, warmup {mig.attrs['cost_s']:.2f}s priced "
          f"into the placement")
    # the migration is make-before-break: n0 stays routable until n1's
    # priced warmup lands, then its stranded queue re-homes behind the
    # warm replica — those requests' wait up to the warm instant shows
    # up as a `warming` span in their trace
    warmed = [t for t in tracer.requests()
              if any(s.name == WARMING for s in t.spans)]
    print(f"  retained traces: {len(warmed)}/{len(tracer.requests())} "
          f"stalled behind the warming replica")
    victim = max(warmed, key=lambda t: t.total_ms)
    comp = victim.component_ms()
    parts = " + ".join(f"{n} {ms:.1f}ms" for n, ms in sorted(
        comp.items(), key=lambda kv: -kv[1]) if ms > 0)
    print(f"  tail request {victim.trace_id} ({victim.total_ms:.1f}ms on "
          f"{victim.node}): {parts}")
    print(f"  (sums to the measured latency: "
          f"{sum(comp.values()):.1f}ms == {victim.total_ms:.1f}ms)")
    warm_span = next(s for s in victim.spans if s.name == WARMING)
    print(f"  its warming span ends at t={warm_span.t1:.3f}s — exactly "
          f"the instant the rebalancer priced "
          f"(t={mig.t0:.1f}s + cost {mig.attrs['cost_s']:.3f}s)")
    print("  per-class decomposition over the retained traces:")
    for line in format_decomposition(decompose_latency(rep)).splitlines():
        print(f"    {line}")


def act_7_chaos_day_reliability():
    print("== act 7: rack failure mid-burst, reliability on vs off ==")
    from repro.chaos import (PARTITION, RACK_FAIL, THERMAL, BrownoutPolicy,
                             Injection, Reliability, RetryBudget,
                             RetryPolicy, Scenario)
    lut = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)
    cls = [SLOClass("interactive", deadline_ms=600.0, priority=3,
                    drop_policy=SHED, degrade_factor=1.5),
           SLOClass("batch", deadline_ms=2500.0, priority=1,
                    drop_policy=DEGRADE)]
    # mid-burst, a whole rack fail-stops, a survivor's thermals bite,
    # and the fabric blips both survivors away from the router twice
    day = Scenario(name="rack-day", injections=(
        Injection(t=1.5, kind=RACK_FAIL, nodes=("n0", "n1")),
        Injection(t=1.6, kind=THERMAL, node="n2", duration_s=1.5),
        Injection(t=2.2, kind=PARTITION, node="n2", duration_s=0.9),
        Injection(t=2.2, kind=PARTITION, node="n3", duration_s=0.9),
        Injection(t=3.8, kind=PARTITION, node="n2", duration_s=0.9),
        Injection(t=3.8, kind=PARTITION, node="n3", duration_s=0.9)))
    rel = Reliability(
        policies={"interactive": RetryPolicy(max_attempts=5, backoff_s=0.1,
                                             hedge=True)},
        default=RetryPolicy(max_attempts=5, backoff_s=0.15),
        budget=RetryBudget(fraction=2.0, burst=512),
        brownout=BrownoutPolicy())
    kw = dict(luts={"interactive": lut, "batch": lut},
              streams={"interactive": poisson(100.0, 6.0, seed=7),
                       "batch": poisson(400.0, 6.0, seed=8)},
              router=P2C, chaos=day)
    off = simulate_cluster(cls, nodes=make_nodes([64] * 4), **kw)
    on = simulate_cluster(cls, nodes=make_nodes([64] * 4),
                          reliability=rel, **kw)
    print(f"  injections: {[(t, k, n) for t, k, n in on.injections]}")
    so, sn = off.classes["interactive"], on.classes["interactive"]
    print(f"  off: interactive good={so.good} failed={so.failed} "
          f"dropped={so.dropped} p95={so.p(95):.0f}ms; "
          f"batch failed={off.classes['batch'].failed}")
    print(f"  on:  interactive good={sn.good} failed={sn.failed} "
          f"dropped={sn.dropped} p95={sn.p(95):.0f}ms "
          f"({sn.retried} retried, {sn.hedge_wasted} hedges wasted); "
          f"batch failed={on.classes['batch'].failed} "
          f"({on.classes['batch'].retried} retried)")
    trans = [(f"{t:.1f}s", c, d) for t, c, d in on.brownouts]
    print(f"  brownout transitions: {trans}")
    print(f"  interactive p95 inside the 600ms SLO all day: "
          f"{sn.p(95) <= 600.0} (goodput {sn.good} vs {so.good} bare)")


def act_8_slo_watchtower():
    print("== act 8: thermal burn -> paged alert -> early actuation ==")
    from repro.chaos import THERMAL, Injection, Scenario
    from repro.obs import Tracer, Watchtower, format_alerts
    lut = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)
    cls = [SLOClass("interactive", deadline_ms=600.0, priority=3,
                    drop_policy=SHED, degrade_factor=1.5),
           SLOClass("batch", deadline_ms=2500.0, priority=1,
                    drop_policy=DEGRADE)]
    horizon = 8.0
    # both serving nodes walk a DEEP DVFS ladder (the stock one bottoms
    # at 0.5x, which this fleet absorbs): requests finish LATE, nothing
    # fails — invisible to act 7's failure-pressure EWMA
    day = Scenario(name="throttle-day", injections=(
        Injection(t=2.0, kind=THERMAL, node="n0", duration_s=horizon - 3,
                  ladder=(0.2, 0.12, 0.08)),
        Injection(t=2.0, kind=THERMAL, node="n1", duration_s=horizon - 3,
                  ladder=(0.2, 0.12, 0.08))))

    def run(actuate):
        nodes = make_nodes([16] * 4)
        for n in nodes[2:]:
            n.state = STANDBY       # half the fleet is a standby pool
        tracer = Tracer(clock=lambda: 0.0)
        wt = Watchtower({"interactive": 0.999, "batch": 0.99},
                        time_scale=horizon / 86400.0, tracer=tracer,
                        actuate=actuate, rebalance_on_alert=actuate)
        rep = simulate_cluster(
            cls, {"interactive": lut, "batch": lut},
            {"interactive": poisson(200.0, horizon, seed=7),
             "batch": poisson(100.0, horizon, seed=8)},
            nodes, router=P2C, chaos=day, tracer=tracer, watchtower=wt,
            scale_at=(0.8 * horizon,), min_nodes=2)
        return rep, wt

    reactive, wt_off = run(actuate=False)
    alerted, wt_on = run(actuate=True)
    print("  the alert log (monitoring-only day):")
    for line in format_alerts(reactive.alerts).splitlines()[:3]:
        print(f"    {line}")
    top = reactive.alerts[0].attribution
    print(f"  attribution: {top.component} regressed "
          f"+{top.delta_ms:.0f}ms -> {top.cause}")
    t_up = {name: min((t for t, d, _ in rep.scale_events if d == "up"),
                      default=float("nan"))
            for name, rep in (("reactive", reactive), ("alerted", alerted))}
    print(f"  standby wake-up: scheduled t={t_up['reactive']:.1f}s vs "
          f"alert-driven t={t_up['alerted']:.1f}s "
          f"(scale_at was {0.8 * horizon:.1f}s)")
    so, sn = reactive.classes["interactive"], alerted.classes["interactive"]
    print(f"  reactive: p95={so.p(95):.0f}ms goodput={so.good} "
          f"time-in-SLO={wt_off.time_in_slo('interactive'):.3f}")
    print(f"  alerted:  p95={sn.p(95):.0f}ms goodput={sn.good} "
          f"time-in-SLO={wt_on.time_in_slo('interactive'):.3f}")
    print(f"  interactive p95 back inside the 600ms SLO: "
          f"{sn.p(95) <= 600.0}")


if __name__ == "__main__":
    act_1_scale_out()
    act_2_skewed_routing()
    act_3_live_lifecycle()
    act_4_wedged_node_auto_failover()
    act_5_placement_engine()
    act_6_trace_a_tail_request()
    act_7_chaos_day_reliability()
    act_8_slo_watchtower()
