"""Multi-node cluster serving walkthrough.

Three acts:

1. **Scale-out (virtual time)** — one overloaded SLO class replayed
   against 1-node and 2-node clusters through the deterministic
   simulator: goodput ~doubles on the same seeded trace.
2. **Routing under skew (virtual time)** — a 256-chip node next to a
   64-chip node; round-robin floods the slow node and the p95 explodes,
   power-of-two-choices follows the backlog-per-chip signal instead.
3. **Lifecycle (live)** — two tiny real ViT nodes behind the
   :class:`~repro.cluster.Cluster` front-end: requests route p2c, one
   node drains (backlog served, tenants migrated), then the survivor is
   fail-stopped (every outstanding future resolves with an error payload
   instead of hanging).

    PYTHONPATH=src python examples/cluster_serving.py
"""
import jax
import numpy as np

from repro.cluster import (P2C, ROUND_ROBIN, Cluster, ClusterNode,
                           simulate_cluster)
from repro.core.types import ElasticSpace, SubnetSpec
from repro.models.vit import ViTConfig, vit_apply, vit_init
from repro.runtime import DynamicServer, GlobalConstraints, model_lut
from repro.runtime import hwmodel as hm
from repro.traffic import DEGRADE, SHED, SLOClass, poisson

SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))
TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008, t_collective=0.004)


def make_nodes(capacities):
    return [ClusterNode(name=f"n{i}",
                        g_fn=lambda t, c=cap: GlobalConstraints(total_chips=c))
            for i, cap in enumerate(capacities)]


def act_1_scale_out():
    lut = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)
    cls = [SLOClass("api", deadline_ms=200.0, priority=2, drop_policy=SHED)]
    stream = poisson(1000.0, 6.0, seed=1)
    print("== act 1: scale-out on one seeded trace ==")
    for caps in ([64], [64, 64]):
        rep = simulate_cluster(cls, {"api": lut}, {"api": list(stream)},
                               make_nodes(caps), router=P2C)
        s = rep.classes["api"]
        print(f"  {len(caps)} node(s): goodput={s.good}/{s.submitted} "
              f"p95={s.p(95):.1f}ms routed={rep.routed['api']}")


def act_2_skewed_routing():
    lut = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)
    cls = [SLOClass("web", deadline_ms=200.0, priority=2,
                    drop_policy=DEGRADE)]
    stream = poisson(1000.0, 6.0, seed=2)
    print("== act 2: p2c vs round-robin under 4:1 skewed capacity ==")
    for router in (ROUND_ROBIN, P2C):
        rep = simulate_cluster(cls, {"web": lut}, {"web": list(stream)},
                               make_nodes([256, 64]), router=router)
        s = rep.classes["web"]
        print(f"  {router:12s}: p95={s.p(95):8.1f}ms goodput={s.good} "
              f"routed={rep.routed['web']}")


def tiny_server(_node):
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2, d_model=32,
                    n_heads=4, d_ff=64, n_classes=4,
                    compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    return DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                         params, dims)


def act_3_live_lifecycle():
    print("== act 3: live drain + fail-stop ==")
    lut = model_lut([SubnetSpec()], full_terms=TERMS, full_chips=2,
                    hw_states=[hm.HwState(chips=1, freq=1.0)])
    nodes = [ClusterNode(name=f"n{i}",
                         g_fn=lambda t: GlobalConstraints(total_chips=2))
             for i in range(2)]
    cluster = Cluster(nodes, router=P2C)
    placed = cluster.register("api", lut, target_latency_ms=500.0,
                              priority=1, make_server=tiny_server)
    print(f"  admitted 'api' on {placed}")
    cluster.start()
    x = np.zeros((16, 16, 3), "float32")
    outs = [cluster.submit("api", x).get(timeout=30) for _ in range(8)]
    print(f"  served {sum(not o.get('cancelled') for o in outs)}/8, "
          f"routed: {cluster.summary()['routed']['api']}")

    drained = cluster.drain("n0", timeout_s=15.0)
    print(f"  drained n0 (backlog fully served: {drained}); "
          f"placements now {cluster.placements['api']}")
    out = cluster.submit("api", x).get(timeout=30)
    print(f"  post-drain request served on the survivor: "
          f"{not out.get('cancelled')}")

    futs = [cluster.submit("api", x) for _ in range(4)]
    cluster.fail("n1", reason="rack lost power")
    resolved = [f.get(timeout=10) for f in futs]   # nothing hangs
    print(f"  fail-stopped n1: {len(resolved)}/4 futures resolved "
          f"({sum(bool(o.get('cancelled')) for o in resolved)} with error "
          f"payloads)")
    cluster.stop()


if __name__ == "__main__":
    act_1_scale_out()
    act_2_skewed_routing()
    act_3_live_lifecycle()
