"""Train the paper's Dynamic-OFA supernet with the sandwich rule + in-place
distillation, then report every sub-network's accuracy and the resulting
latency-accuracy Pareto front (measured on this host).

This is the end-to-end training driver for the paper's technique:
    PYTHONPATH=src python examples/train_supernet.py --steps 300

Options: --compress enables int8 error-feedback gradient compression.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.elastic import spec_to_static
from repro.core.pareto import OpPoint, accuracy_latency_front
from repro.core.supernet import make_sandwich_step
from repro.data import synthetic_image_batches
from repro.models.vit import vit_apply, vit_init
from repro.optim import make_optimizer
from repro.optim.compress import init_errors, tree_compress
from repro.runtime import DynamicServer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=64)
ap.add_argument("--compress", action="store_true")
args = ap.parse_args()

arch = get_arch("dynamic-ofa-supernet")
cfg = arch.make_smoke()
n_classes = cfg.n_classes
params = vit_init(jax.random.PRNGKey(0), cfg)
init_fn, update_fn = make_optimizer("adamw", lr=3e-3, weight_decay=0.01)
opt = init_fn(params)
dims = {"d_model": cfg.d_model, "d_ff": cfg.d_ff, "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers}

if args.compress:
    errors = init_errors(params)
    base_update = update_fn

    def update_fn(params, grads, opt, step):   # noqa: F811
        global errors
        grads, errors = tree_compress(grads, errors)
        return base_update(params, grads, opt, step)

apply_fn = lambda p, b, E: vit_apply(p, b["images"], cfg, E=E)[0]
step_fn, sample_fn = make_sandwich_step(apply_fn, update_fn, dims, n_random=2)
step_jit = jax.jit(step_fn) if not args.compress else step_fn

rng = np.random.default_rng(0)
data = synthetic_image_batches(global_batch=args.batch, img_res=cfg.img_res,
                               n_classes=n_classes)
t0 = time.time()
for step in range(args.steps):
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    E_stack = sample_fn(cfg.elastic, rng)
    params, opt, metrics = step_jit(params, opt, batch, E_stack,
                                    jnp.asarray(step))
    if step % 50 == 0:
        print(f"step {step:4d}  sandwich loss {float(metrics['loss']):.4f}")
print(f"trained {args.steps} steps in {time.time() - t0:.1f}s "
      f"({'compressed grads' if args.compress else 'plain grads'})\n")

# --- evaluate all sub-networks (sliced mode) + measured Pareto ---------------
test = {k: jnp.asarray(v) for k, v in next(data).items()}
server = DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                       params, dims, max_batch=args.batch)
points = []
for spec in cfg.elastic.enumerate():
    E = spec_to_static(spec, dims)
    logits = apply_fn(params, test, E)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == test["labels"]))
    lat = server.measure(spec, np.asarray(test["images"]))
    points.append(OpPoint(spec, None, lat, 0.0, acc))
    print(f"  {spec.name():28s} acc={acc:.3f}  lat={lat:6.2f}ms")

front = accuracy_latency_front(points)
print(f"\nPareto front ({len(front)} of {len(points)} points):")
for p in front:
    print(f"  {p.subnet.name():28s} acc={p.accuracy:.3f} "
          f"lat={p.latency_ms:6.2f}ms")
full = max(points, key=lambda p: p.latency_ms)
fast = min(points, key=lambda p: p.latency_ms)
print(f"\nlatency span {full.latency_ms / fast.latency_ms:.2f}x "
      f"(paper: up to 3.5x CPU) — accuracy span "
      f"{fast.accuracy:.3f} -> {full.accuracy:.3f}")
