"""Serve a dynamic supernet with the runtime resource manager in the loop —
the paper's deployed system (Fig. 1), end to end:

  request queue -> bucketed continuous batching (pad only to the nearest
  power-of-two bucket; per-bucket pinned pad buffers; ladder pre-warmed so
  steady state does zero cold compiles) -> governor picks (subnet, DVFS
  point) under changing latency targets / thermal throttling / co-running
  apps -> sliced-executable cache switch -> pipelined dispatch (batch N+1
  stacks while batch N is on device) -> response.

Serving data-path knobs (see ``repro.launch.serve`` / ``DynamicServer``):

  --max-batch N   batching ceiling; bucket ladder = powers of two up to N
  --no-buckets    pad-to-max baseline (what bench_traffic compares against)
  --no-pipeline   synchronous dispatch, no host/device overlap

    PYTHONPATH=src python examples/serve_dynamic.py
"""
from repro.launch import serve

serve.main(["--arch", "dynamic-ofa-supernet", "--smoke",
            "--requests", "48", "--trace-steps", "150"])
