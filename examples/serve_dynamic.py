"""Serve a dynamic supernet with the runtime resource manager in the loop —
the paper's deployed system (Fig. 1), end to end:

  request queue -> dynamic batching -> governor picks (subnet, DVFS point)
  under changing latency targets / thermal throttling / co-running apps ->
  sliced-executable cache switch -> response.

    PYTHONPATH=src python examples/serve_dynamic.py
"""
from repro.launch import serve

serve.main(["--arch", "dynamic-ofa-supernet", "--smoke",
            "--requests", "48", "--trace-steps", "150"])
