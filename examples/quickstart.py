"""Quickstart: the paper's idea in 60 lines.

Builds a dynamic ViT supernet, extracts three sub-networks, shows that
(1) sliced and masked execution agree, (2) smaller sub-networks are
genuinely faster, (3) the elastic Pallas kernel matches its oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.elastic import spec_to_dynamic, spec_to_static
from repro.core.types import SubnetSpec
from repro.models.vit import vit_apply, vit_init

arch = get_arch("dynamic-ofa-supernet")
cfg = arch.make_smoke()
params = vit_init(jax.random.PRNGKey(0), cfg)
dims = {"d_model": cfg.d_model, "d_ff": cfg.d_ff, "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers}
x = np.random.default_rng(0).normal(
    size=(8, cfg.img_res, cfg.img_res, 3)).astype(np.float32)

print(f"supernet: {cfg.name}  ({cfg.n_layers}L d={cfg.d_model})")
print(f"elastic space: {len(cfg.elastic.enumerate())} sub-networks\n")

for spec in [SubnetSpec(),
             SubnetSpec(width_mult=0.5, ffn_mult=0.5),
             SubnetSpec(width_mult=0.5, ffn_mult=0.25, depth_mult=2 / 3)]:
    E_static = spec_to_static(spec, dims)
    E_masked = spec_to_dynamic(spec, dims)

    sliced = jax.jit(lambda p, x: vit_apply(p, x, cfg, E=E_static)[0])
    masked = jax.jit(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0])

    y_s = jax.block_until_ready(sliced(params, x))
    y_m = jax.block_until_ready(masked(params, x, E_masked))
    agree = np.allclose(np.asarray(y_s), np.asarray(y_m), atol=5e-3)

    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(sliced(params, x))
    ms = (time.perf_counter() - t0) / 10 * 1e3
    print(f"{spec.name():24s} latency={ms:6.2f}ms  sliced==masked: {agree}")

# the elastic Pallas kernel (TPU target, interpret-mode here)
from repro.kernels.ops import elastic_matmul_op
from repro.kernels.ref import elastic_matmul_ref

xm = jax.random.normal(jax.random.PRNGKey(1), (128, 512))
wm = jax.random.normal(jax.random.PRNGKey(2), (512, 512))
y = elastic_matmul_op(xm, wm, 256, 384)
yr = elastic_matmul_ref(xm, wm, 256, 384)
print(f"\nelastic_matmul kernel vs oracle: "
      f"max_err={float(jnp.max(jnp.abs(y - yr))):.2e}")
