"""Two dynamic models served concurrently behind one runtime arbiter.

An interactive ViT (tight latency target, high priority) and a batch ViT
(loose target, low priority) share a modelled 4-chip slice.  Each model
runs in its own :class:`DynamicServer` (own executable cache, own
``JointGovernor``); one :class:`ResourceArbiter` clock re-divides the
machine every cycle and switches each server's active sub-network.  Midway
the slice shrinks to 2 chips: the batch model degrades first (priority
order), the interactive model keeps its target.

    PYTHONPATH=src python examples/concurrent_serving.py
"""
import time

import jax
import numpy as np

from repro.core.types import ElasticSpace
from repro.models.vit import ViTConfig, vit_apply, vit_init
from repro.runtime import (DynamicServer, GlobalConstraints, ResourceArbiter,
                           model_lut)
from repro.runtime import hwmodel as hm

SPACE = ElasticSpace(width_mults=(0.5, 1.0), ffn_mults=(0.5, 1.0))
HW_STATES = [hm.HwState(chips=c, freq=f) for c in (4, 2, 1)
             for f in (0.7, 1.0)]


def make_server(name: str, n_layers: int, d_model: int):
    cfg = ViTConfig(name=name, img_res=32, patch=8, n_layers=n_layers,
                    d_model=d_model, n_heads=4, d_ff=4 * d_model,
                    n_classes=10, compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": d_model, "d_ff": 4 * d_model, "n_heads": 4,
            "n_layers": n_layers}
    server = DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                           params, dims, max_batch=4, timeout_ms=2.0)
    return server


def main():
    arb = ResourceArbiter(interval_s=0.05)
    # interactive: small model, tight target, high priority
    interactive = make_server("interactive", n_layers=2, d_model=32)
    terms_i = hm.RooflineTerms(4e-3, 1.5e-3, 5e-4)
    arb.register("interactive",
                 model_lut(SPACE.enumerate(), full_terms=terms_i,
                           full_chips=4, hw_states=HW_STATES),
                 target_latency_ms=6.0, priority=2, server=interactive)
    # batch: bigger model, loose target, low priority
    batch = make_server("batch", n_layers=4, d_model=64)
    terms_b = hm.RooflineTerms(1.6e-2, 6e-3, 2e-3)
    arb.register("batch",
                 model_lut(SPACE.enumerate(), full_terms=terms_b,
                           full_chips=4, hw_states=HW_STATES),
                 target_latency_ms=40.0, priority=0, server=batch)

    machine = {"chips": 4}
    arb.start(lambda: GlobalConstraints(total_chips=machine["chips"],
                                        power_budget_w=machine["chips"]
                                        * hm.TDP_W))

    x = np.zeros((32, 32, 3), "float32")
    futs = []
    # batch requests sent while it is starved queue up behind the pause and
    # drain in the recovery phase — so every future below resolves
    for phase, chips in (("full machine", 4), ("co-runner takes half", 2),
                         ("co-runner leaves", 4)):
        machine["chips"] = chips
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < 2.0:
            futs.append(("interactive", interactive.submit(x)))
            futs.append(("batch", batch.submit(x)))
            time.sleep(0.02)
        alloc = {k: (a.chips, a.feasible,
                     a.point.subnet.name() if a.point else None)
                 for k, a in arb.last_allocations().items()}
        print(f"[{phase}] alloc (chips, meets-target, subnet): {alloc}")
    outs = [(who, f.get(timeout=60)) for who, f in futs]
    arb.stop()

    for name in ("interactive", "batch"):
        lats = [o["latency_ms"] for who, o in outs if who == name]
        print(f"{name}: {len(lats)} served, "
              f"p50={np.median(lats):.1f}ms p95={np.percentile(lats, 95):.1f}ms")
    print("arbiter summary:", arb.summary())
    switches = {"interactive": len(interactive.switch_log),
                "batch": len(batch.switch_log)}
    print("subnet switches:", switches)


if __name__ == "__main__":
    main()
