"""Bucketed continuous batching + pipelined dispatch (PR 3).

Covers the acceptance items: bucket selection/padding bit-exactness vs
the unbucketed pad-to-max baseline, zero cold compiles in steady state
after bucket-ladder warmup, pipelined future resolution under
stop()/pause races, the bounded switch log, and the batching-aware
service model in the traffic simulator.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.types import ElasticSpace, SubnetSpec
from repro.runtime import (GlobalConstraints, bucket_for, bucket_ladder,
                           bucket_latency_ms, model_lut)
from repro.runtime import hwmodel as hm

TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008, t_collective=0.004)
SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))


def tiny_server(**kw):
    import jax
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2,
                    d_model=32, n_heads=4, d_ff=64, n_classes=4,
                    compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    return DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                         params, dims, **kw)


# --- bucket model -------------------------------------------------------------

def test_bucket_ladder_and_selection():
    assert bucket_ladder(8) == (1, 2, 4, 8)
    assert bucket_ladder(1) == (1,)
    assert bucket_ladder(6) == (1, 2, 4, 6)   # non-power-of-two ceiling
    assert bucket_for(1, 8) == 1
    assert bucket_for(3, 8) == 4
    assert bucket_for(8, 8) == 8
    assert bucket_for(5, 6) == 6
    with pytest.raises(ValueError):
        bucket_ladder(0)


def test_bucket_latency_monotone_and_anchored():
    lats = [bucket_latency_ms(24.0, b, 8) for b in bucket_ladder(8)]
    assert lats == sorted(lats)               # monotone in bucket
    assert lats[-1] == pytest.approx(24.0)    # full bucket = profiled cost
    assert lats[0] < 24.0                     # small bucket genuinely cheaper
    assert lats[0] >= 24.0 * 0.3              # but pays the fixed overhead


def test_lut_bucket_latency_columns():
    lut = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)
    cols = lut.bucket_latencies(lut.points[0], 8)
    assert set(cols) == {1, 2, 4, 8}
    assert cols[8] == pytest.approx(lut.points[0].latency_ms)
    assert cols[1] < cols[8]


# --- bucketed serving: bit-exactness + zero cold compiles ---------------------

def test_bucketed_padding_bit_exact_vs_unbucketed():
    """A bucketed batch of k (padded to the nearest bucket) must answer
    exactly what the unbucketed pad-to-max path answers (acceptance)."""
    server = tiny_server(max_batch=8, timeout_ms=50.0)
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(3, 16, 16, 3)).astype("float32")
    server.start()
    try:
        futs = [server.submit(xs[i]) for i in range(3)]
        outs = [f.get(timeout=60) for f in futs]
    finally:
        server.stop()
    assert all(not o.get("cancelled") for o in outs)
    # unbucketed baseline: same requests padded all the way to max_batch
    padded = np.concatenate([xs, np.zeros((5, 16, 16, 3), "float32")])
    ref = np.asarray(server.infer(padded))
    for i, o in enumerate(outs):
        assert np.array_equal(o["y"], ref[i])   # bit-exact, not just close


def test_zero_cold_compiles_after_ladder_warmup():
    x1 = np.zeros((16, 16, 3), "float32")
    half = SubnetSpec(width_mult=0.5, ffn_mult=0.5, depth_mult=0.5)
    server = tiny_server(max_batch=4, timeout_ms=2.0,
                         warm_specs=[SubnetSpec(), half], example_input=x1)
    assert server.cold_compiles == 0
    server.start()
    try:
        futs = []
        for spec in (SubnetSpec(), half, SubnetSpec()):
            server.switch(spec)
            for k in (1, 2, 3, 4):            # hit every bucket
                futs += [server.submit(x1) for _ in range(k)]
                time.sleep(0.01)
        outs = [f.get(timeout=60) for f in futs]
    finally:
        server.stop()
    assert all(not o.get("cancelled") for o in outs)
    assert server.cold_compiles == 0          # steady state: ladder warm
    assert all(not e["cold"] for e in server.switch_log)


def test_unwarmed_buckets_counted_cold():
    x1 = np.zeros((16, 16, 3), "float32")
    server = tiny_server(max_batch=4, timeout_ms=2.0)
    server.start()
    try:
        assert server.submit(x1).get(timeout=60)["y"].shape == (4,)
    finally:
        server.stop()
    assert server.cold_compiles >= 1          # nothing was warmed


def test_no_buckets_restores_pad_to_max():
    server = tiny_server(max_batch=4, batch_buckets=False)
    assert server.buckets == (4,)
    assert server._bucket_for(1) == 4


# --- pipelined dispatch -------------------------------------------------------

def test_pipelined_resolution_under_stop_race():
    """Every submitted future resolves (answered or cancelled) when stop()
    lands mid-stream with batches in flight (acceptance)."""
    x1 = np.zeros((16, 16, 3), "float32")
    server = tiny_server(max_batch=2, timeout_ms=1.0, pipeline=True)
    server.start()
    futs = [server.submit(x1) for _ in range(40)]
    time.sleep(0.05)                          # some batches in flight
    server.stop()
    outs = [f.get(timeout=10) for f in futs]
    answered = [o for o in outs if not o.get("cancelled")]
    cancelled = [o for o in outs if o.get("cancelled")]
    assert len(answered) + len(cancelled) == 40
    assert all(o["y"].shape == (4,) for o in answered)
    assert server.served == len(answered)
    assert server.cancelled == len(cancelled)


def test_pipelined_resolution_under_pause_churn():
    """Arbiter-style preempt churn (pause/resume from another thread) must
    not lose or double-resolve futures."""
    x1 = np.zeros((16, 16, 3), "float32")
    server = tiny_server(max_batch=2, timeout_ms=1.0, pipeline=True)
    server.start()
    stop_churn = threading.Event()

    def churn():
        while not stop_churn.is_set():
            server.pause()
            time.sleep(0.002)
            server.resume()
            time.sleep(0.002)

    th = threading.Thread(target=churn)
    th.start()
    try:
        futs = [server.submit(x1) for _ in range(30)]
        outs = [f.get(timeout=60) for f in futs]
    finally:
        stop_churn.set()
        th.join()
        server.stop()
    assert all(o["y"].shape == (4,) for o in outs)   # none lost or cancelled
    assert server.served == 30


def test_accounting_non_overlapping_under_pipeline():
    """busy_s integrates non-overlapping dispatch->ready intervals: it can
    never exceed the wall-clock span of the run."""
    x1 = np.zeros((16, 16, 3), "float32")
    server = tiny_server(max_batch=1, timeout_ms=0.5, pipeline=True)
    t0 = time.perf_counter()
    server.start()
    futs = [server.submit(x1) for _ in range(20)]
    for f in futs:
        f.get(timeout=60)
    server.stop()
    span = time.perf_counter() - t0
    assert 0.0 < server.busy_s <= span
    assert server.measured_energy_mj > 0.0


# --- bounded switch log -------------------------------------------------------

def test_switch_log_bounded_with_drop_counter():
    server = tiny_server(switch_log_cap=8)
    specs = [SubnetSpec(), SubnetSpec(width_mult=0.5)]
    for i in range(20):
        server.switch(specs[i % 2])
    assert len(server.switch_log) == 8
    assert server.switch_log_dropped == 12
    assert server.switch_log[-1]["ms"] >= 0.0


# --- idle behaviour -----------------------------------------------------------

def test_queue_depth_ignores_wake_tokens():
    """pause()/stop() wake tokens must not read as phantom backlog."""
    x1 = np.zeros((16, 16, 3), "float32")
    server = tiny_server()
    server.pause()                            # enqueues a wake token
    assert server.queue_depth() == 0
    futs = [server.submit(x1) for _ in range(3)]
    assert server.queue_depth() == 3
    server.stop()
    assert all(f.get(timeout=5)["cancelled"] for f in futs)
    assert server.queue_depth() == 0


def test_idle_server_serves_immediately_after_wait():
    """The worker blocks on the queue (no poll loop): a request after a
    long idle period is still picked up promptly."""
    x1 = np.zeros((16, 16, 3), "float32")
    server = tiny_server(max_batch=4, timeout_ms=1.0)
    server.start()
    try:
        time.sleep(0.3)                       # idle: worker parked on get()
        out = server.submit(x1).get(timeout=60)
        assert out["y"].shape == (4,)
    finally:
        server.stop()


# --- batching-aware service model in the simulator ----------------------------

def _cmp_sim(service_model):
    from repro.traffic import SHED, SLO_POLICY, SLOClass, poisson, simulate
    classes = [SLOClass("rt", deadline_ms=8.0, priority=1,
                        drop_policy=SHED, service_frac=0.8)]
    lut = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)
    streams = {"rt": poisson(400.0, 6.0, seed=4)}
    g = lambda t: GlobalConstraints(total_chips=256)
    return simulate(classes, {"rt": lut}, streams, g,
                    policy=SLO_POLICY, service_model=service_model)


def test_simulate_bucketed_beats_padded_at_low_occupancy():
    from repro.traffic import BUCKETED_SERVICE, PADDED_SERVICE
    bkt = _cmp_sim(BUCKETED_SERVICE)
    pad = _cmp_sim(PADDED_SERVICE)
    assert bkt.classes["rt"].mean_batch <= 4.0        # low occupancy
    assert bkt.total_goodput >= 1.25 * max(pad.total_goodput, 1)
    assert bkt.classes["rt"].p(95) <= pad.classes["rt"].p(95)
    # deterministic: same seeds, same model => same report
    assert bkt.summary() == _cmp_sim(BUCKETED_SERVICE).summary()
