"""PR 7: end-to-end tracing + metrics (`repro.obs`).

* **schema parity** — the live engine and BOTH virtual-time simulators
  emit the same span vocabulary with the same required attrs
  (``validate_schema`` on each emitter, span-name sequences compared);
* **tail bias** — the bounded trace buffer keeps exactly the slowest
  ``tail_frac`` of an adversarial stream plus a seeded uniform sample;
* **decomposition** — per-trace component sums equal the measured
  latency within tolerance (asserted inside ``decompose_latency``);
* **Perfetto export** — the Chrome trace-event JSON round-trips through
  ``json.loads`` with well-formed complete/metadata events;
* **shared quantile** — the one nearest-rank implementation behind
  traffic percentiles and histogram percentiles, with edge cases;
* **bounded logs** — the sim's migration/preempt/health/scale logs cap
  with dropped counters (the frontend uses the same idiom).
"""
import json
import math

import pytest

from repro.obs import (COMPONENTS, SCHEMA, Histogram, MetricsRegistry,
                       RequestTrace, Span, Tracer, decompose_latency,
                       mean_components, quantile, to_chrome_trace,
                       validate_schema, weighted_quantile,
                       write_chrome_trace)
from repro.obs import trace as obs
from repro.obs.analyze import DecompositionError, check_trace
from repro.core.types import ElasticSpace
from repro.runtime import GlobalConstraints, model_lut
from repro.runtime import hwmodel as hm
from repro.traffic import DEGRADE, SHED, SLOClass, poisson, simulate

TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008, t_collective=0.004)
SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))


def make_lut(scale=1.0, full_chips=256):
    terms = hm.RooflineTerms(TERMS.t_compute * scale, TERMS.t_memory * scale,
                             TERMS.t_collective * scale)
    return model_lut(SPACE.enumerate(), full_terms=terms,
                     full_chips=full_chips)


def virtual_tracer(**kw):
    return Tracer(clock=lambda: 0.0, **kw)


def sim_traced(horizon_s=3.0, **kw):
    classes = [SLOClass("rt", deadline_ms=80.0, priority=2,
                        drop_policy=SHED),
               SLOClass("batch", deadline_ms=400.0, priority=0,
                        drop_policy=DEGRADE)]
    streams = {"rt": poisson(40.0, horizon_s, seed=1),
               "batch": poisson(20.0, horizon_s, seed=2)}
    lut = make_lut()
    tr = virtual_tracer(**kw)
    rep = simulate(classes, {"rt": lut, "batch": lut}, streams,
                   lambda t: GlobalConstraints(total_chips=256), tracer=tr)
    return rep, tr


# --- quantile: the one shared implementation ---------------------------------

def test_quantile_edge_cases():
    assert math.isnan(quantile([], 50))
    assert quantile([7.0], 0) == 7.0
    assert quantile([7.0], 50) == 7.0
    assert quantile([7.0], 100) == 7.0
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert quantile(xs, 0) == 1.0          # q=0 -> min (nearest rank >= 1)
    assert quantile(xs, 100) == 5.0        # q=100 -> max
    assert quantile(xs, 50) == 3.0
    assert quantile(xs, 95) == 5.0         # always an observed value
    # the traffic layer re-exports THIS function (consolidation check)
    from repro.runtime.monitor import quantile as mq
    from repro.traffic.driver import quantile as tq
    assert mq is quantile and tq is quantile


def test_weighted_quantile_and_histogram_percentile():
    assert math.isnan(weighted_quantile([], [], 50))
    h = Histogram(buckets=(1.0, 5.0, 10.0))
    for v in (0.5, 0.7, 4.0, 4.5, 9.0):
        h.observe(v)
    assert h.count == 5 and h.sum == pytest.approx(18.7)
    # p50 lands in the (1, 5] bucket -> its upper edge
    assert h.percentile(50) == 5.0
    # p0/p100 are tightened to the observed min/max, not bucket edges
    assert h.percentile(0) == 0.5
    assert h.percentile(100) == 9.0
    assert math.isnan(Histogram(buckets=(1.0,)).percentile(50))


# --- tracer: bounded buffer, tail bias, schema -------------------------------

def test_tracer_tail_bias_property():
    """Adversarial stream (slowest arrive first): the tail reservoir must
    still hold EXACTLY the slowest tail_frac when the buffer overflows."""
    cap, n = 100, 1000
    tr = virtual_tracer(cap=cap, tail_frac=0.10, seed=3)
    # descending latency: naive "keep newest" would evict every slow one
    for i in range(n):
        lat = float(n - i)
        tr.request("c", 0.0, lat / 1e3,
                   spans=[(obs.QUEUE, 0.0, 0.0, None),
                          (obs.DEVICE, 0.0, lat / 1e3,
                           {"bucket": 1, "subnet": "s", "n": 1})])
    kept = tr.requests()
    assert len(kept) <= cap
    assert tr.dropped == n - len(kept)
    tail = sorted((t.total_ms for t in tr.tail_requests()), reverse=True)
    k = len(tail)
    assert k == int(cap * 0.10)
    # the k slowest of the whole stream, exactly
    assert tail == [float(n - i) for i in range(k)]
    # the uniform reservoir is seeded -> deterministic across runs
    tr2 = virtual_tracer(cap=cap, tail_frac=0.10, seed=3)
    for i in range(n):
        lat = float(n - i)
        tr2.request("c", 0.0, lat / 1e3)
    assert sorted(t.total_ms for t in tr.requests()) == \
        sorted(t.total_ms for t in tr2.requests())


def test_tracer_decision_log_bounded():
    tr = virtual_tracer(decision_cap=4)
    for i in range(7):
        tr.decision(obs.SCALE, float(i), float(i), direction="up")
    assert len(tr.decisions) == 4
    assert tr.decisions_dropped == 3
    assert tr.decisions[0].t0 == 3.0      # oldest evicted


def test_validate_schema_catches_violations():
    good = Span(name=obs.DEVICE, t0=0.0, t1=1.0,
                attrs={"bucket": 4, "subnet": "s", "n": 3})
    bad_name = Span(name="warp", t0=0.0, t1=1.0)
    bad_attrs = Span(name=obs.MIGRATE, t0=0.0, t1=1.0, attrs={"src": "n0"})
    assert validate_schema([good]) == []
    assert any("warp" in p for p in validate_schema([bad_name]))
    assert any("cost_s" in str(p) for p in validate_schema([bad_attrs]))


# --- sim vs live: one span schema --------------------------------------------

SIM_NAMES = [obs.QUEUE, obs.COLLECT, obs.STACK, obs.DISPATCH, obs.DEVICE,
             obs.COMPLETE]


def test_sim_emits_live_schema_in_virtual_time():
    rep, tr = sim_traced()
    assert validate_schema(tr.spans()) == []
    assert rep.total_goodput > 0 and len(tr.requests()) > 0
    for t in tr.requests():
        assert [s.name for s in t.spans] == SIM_NAMES
    assert any(s.name == obs.ARBITRATE for s in tr.decisions)


def test_live_engine_emits_same_schema():
    """The live engine's per-request span tree carries the same names in
    the same order (and the same DEVICE attrs) as the simulator's."""
    import jax
    import numpy as np
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2, d_model=32,
                    n_heads=4, d_ff=64, n_classes=4,
                    compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    tr = Tracer()                       # wall clock
    metrics = MetricsRegistry()
    server = DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                           params, dims, max_batch=4, timeout_ms=2.0,
                           tracer=tr, metrics=metrics)
    server.trace_node = "local"
    x = np.zeros((16, 16, 3), "float32")
    server.start()
    futs = [server.submit(x) for _ in range(10)]
    outs = [f.get(timeout=30) for f in futs]
    server.stop()
    assert validate_schema(tr.spans()) == []
    traces = tr.requests()
    assert len(traces) == 10
    for t in traces:
        assert [s.name for s in t.spans] == SIM_NAMES   # parity with sim
        assert t.node == "local"
        dev = t.spans[SIM_NAMES.index(obs.DEVICE)]
        assert set(dev.attrs) >= set(SCHEMA[obs.DEVICE])
        # decomposition holds on WALL-clock spans too
        check_trace(t)
    lat = [o["latency_ms"] for o in outs]
    assert metrics.value("engine_served_total", tenant="default",
                         node="local") == 10
    assert metrics.histogram("engine_request_ms", tenant="default",
                             node="local").count == 10
    # traced totals are the engine's own measured latencies
    assert sorted(round(t.total_ms, 3) for t in traces) == \
        sorted(round(v, 3) for v in lat)


def test_cluster_sim_decision_spans_and_bounded_logs():
    from repro.cluster import (ClusterNode, FIRST_FIT, LEAST_LOADED,
                               simulate_cluster)
    def nodes():
        return [ClusterNode(name=f"n{i}",
                            g_fn=lambda t: GlobalConstraints(
                                total_chips=256))
                for i in range(3)]
    cls = SLOClass("api", deadline_ms=200.0, priority=2,
                   drop_policy=DEGRADE)
    tr = virtual_tracer()
    rep = simulate_cluster(
        [cls], {"api": make_lut()}, {"api": poisson(2500.0, 4.0, seed=5)},
        nodes(), router=LEAST_LOADED, placement_mode=FIRST_FIT,
        rebalance_at=[0.5, 1.5, 2.5, 3.5], tracer=tr, log_cap=1)
    assert validate_schema(tr.spans()) == []
    names = {s.name for s in tr.decisions}
    assert obs.ARBITRATE in names and obs.REBALANCE in names
    migs = [s for s in tr.decisions if s.name == obs.MIGRATE]
    assert migs and all(s.attrs["cost_s"] > 0 and s.t1 > s.t0
                        for s in migs)   # the priced warmup window
    # request trees carry the route span and node labels
    t0 = tr.requests()[0]
    assert t0.spans[0].name == obs.ROUTE and t0.node is not None
    # log_cap=1 with >=2 migrations: capped list + dropped counter
    assert len(rep.migrations) == 1
    assert rep.log_dropped["migrations"] >= 1
    assert rep.summary()["log_dropped"] == rep.log_dropped
    assert rep.tracer is tr


# --- decomposition -----------------------------------------------------------

def test_decomposition_sums_to_total_within_tolerance():
    rep, tr = sim_traced()
    d = decompose_latency(tr)           # asserts per-trace sums internally
    assert set(d) == {"rt", "batch"}
    for cname, row in d.items():
        for q in ("p50", "p95"):
            parts = sum(v for k, v in row[q].items()
                        if k.endswith("_ms") and k != "total_ms")
            tot = row[q]["total_ms"]
            assert parts == pytest.approx(tot, rel=0.05, abs=0.05)
            # the quantile pick is a REAL retained trace
            assert any(t.trace_id == row[q]["trace_id"]
                       for t in tr.requests())
        assert row["n"] > 0
    mc = mean_components(tr, cls="rt")
    assert set(mc) <= set(COMPONENTS)


def test_decomposition_rejects_gapped_trace():
    t = RequestTrace(trace_id=1, cls="c", t0=0.0, t1=1.0)
    t.spans = [Span(obs.QUEUE, 0.0, 0.2, trace_id=1),
               Span(obs.DEVICE, 0.8, 1.0, trace_id=1,
                    attrs={"bucket": 1, "subnet": "s", "n": 1})]
    with pytest.raises(DecompositionError):
        check_trace(t)   # 600ms unaccounted


# --- Perfetto / Chrome trace export ------------------------------------------

def test_perfetto_export_roundtrips_json(tmp_path):
    _, tr = sim_traced()
    path = str(tmp_path / "trace.json")
    n = write_chrome_trace(tr, path)
    with open(path) as f:
        doc = json.loads(f.read())      # valid JSON is the acceptance bar
    evs = doc["traceEvents"]
    assert len(evs) == n and doc["displayTimeUnit"] == "ms"
    complete = [e for e in evs if e["ph"] == "X"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert complete and meta
    for e in complete:
        assert e["ts"] >= 0 and e["dur"] >= 0          # rebased us
        assert {"pid", "tid", "name", "args"} <= set(e)
    assert to_chrome_trace(tr)["traceEvents"][0] is not None


# --- metrics registry --------------------------------------------------------

def test_metrics_registry_snapshot_and_exports():
    m = MetricsRegistry()
    m.counter("served_total", tenant="a").inc(3)
    m.counter("served_total", tenant="b").inc()
    m.gauge("chips", node="n0").set(7)
    m.histogram("lat_ms", buckets=(1.0, 10.0), tenant="a").observe(5.0)
    snap = m.snapshot()
    served = [r for r in snap if r["name"] == "served_total"]
    assert sorted(r["value"] for r in served) == [1.0, 3.0]
    assert all(r["kind"] == "counter" for r in served)
    assert json.loads(m.to_json())["series"]            # valid JSON
    prom = m.to_prometheus()
    assert 'served_total{tenant="a"} 3' in prom
    assert 'lat_ms_bucket{le="+Inf",tenant="a"} 1' in prom
    assert "lat_ms_sum" in prom and "lat_ms_count" in prom
    assert m.value("served_total", tenant="a") == 3.0
    assert m.value("missing", default=0.0) == 0.0
    m.remove(tenant="a")
    assert m.value("served_total", tenant="a") == 0.0
    assert m.value("served_total", tenant="b") == 1.0
    with pytest.raises(ValueError):
        m.counter("served_total", tenant="b").inc(-1)


def test_arbiter_summary_backed_by_registry():
    from repro.runtime import ResourceArbiter
    arb = ResourceArbiter()
    arb.register("api", make_lut(), target_latency_ms=50.0, priority=1)
    g = GlobalConstraints(total_chips=256)
    arb.tick(g)
    s = arb.summary()
    assert s["api"]["cycles"] == 1
    assert arb.metrics.value("arbiter_cycles_total", tenant="api") == 1.0
    arb.unregister("api")
    assert arb.metrics.value("arbiter_cycles_total", tenant="api") == 0.0
    assert "api" not in arb.summary()
