"""Fallback for ``hypothesis`` in offline containers.

The property tests only use a small slice of the API:

    from hypothesis import given, settings, strategies as st
    st.floats(lo, hi) / st.integers(lo, hi) / st.sampled_from(seq)
    settings.register_profile(...) / settings.load_profile(...)

When the real package is importable we do nothing.  Otherwise
:func:`install` registers a shim module named ``hypothesis`` that replays
fixed, deterministic example sets (bounds, midpoints, and a few seeded
draws) through ``@given`` — property tests degrade to example tests instead
of killing collection.
"""
from __future__ import annotations

import functools
import inspect
import itertools
import random
import sys
import types

_MAX_COMBOS = 16


class _Strategy:
    def __init__(self, examples, draw):
        self.examples = list(examples)   # always-tried corner cases
        self.draw = draw                 # rng -> one more example


def floats(min_value, max_value):
    mid = 0.5 * (min_value + max_value)
    return _Strategy([min_value, max_value, mid],
                     lambda rng: rng.uniform(min_value, max_value))


def integers(min_value, max_value):
    mid = (min_value + max_value) // 2
    return _Strategy([min_value, max_value, mid],
                     lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(elements, lambda rng: rng.choice(elements))


def booleans():
    return _Strategy([False, True], lambda rng: rng.random() < 0.5)


def given(**strategies):
    """Run the test on the cartesian product of corner examples (capped at
    ``_MAX_COMBOS``, topped up with seeded random draws)."""
    names = sorted(strategies)

    def decorator(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            combos = list(itertools.islice(
                itertools.product(*(strategies[n].examples for n in names)),
                _MAX_COMBOS))
            rng = random.Random(0)
            while len(combos) < _MAX_COMBOS:
                combos.append(tuple(strategies[n].draw(rng) for n in names))
            for combo in combos:
                fn(*args, **dict(zip(names, combo)), **kwargs)
        # hide the strategy params from pytest (it would treat them as
        # fixtures); remaining params stay visible, like real @given
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[p for p in sig.parameters.values()
                        if p.name not in strategies])
        del wrapper.__wrapped__
        return wrapper
    return decorator


class settings:  # noqa: N801 — mirrors hypothesis' class name
    def __init__(self, *args, **kwargs):
        pass

    def __call__(self, fn):
        return fn

    @staticmethod
    def register_profile(name, *args, **kwargs):
        pass

    @staticmethod
    def load_profile(name):
        pass


def install():
    """Put the shim in ``sys.modules`` iff real hypothesis is unavailable."""
    try:
        import hypothesis  # noqa: F401 — real package wins
        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.floats = floats
    st.integers = integers
    st.sampled_from = sampled_from
    st.booleans = booleans
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
