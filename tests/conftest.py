import os
import subprocess
import sys

import pytest

# Tests see the real device count (1 CPU). Only the dry-run forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

TESTS = os.path.dirname(os.path.abspath(__file__))
if TESTS not in sys.path:
    sys.path.insert(0, TESTS)

# Offline containers lack hypothesis; shim it so collection never dies.
import _hypothesis_compat  # noqa: E402

_hypothesis_compat.install()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: soak tests (traffic etc.) — opt-in via --runslow")
    if config.getoption("--lock-check"):
        # Instrument every repro.* Lock/RLock allocated from here on and
        # hook the engine's device-dispatch point, so the whole suite
        # doubles as the lock-order corpus (repro.analysis.locks).
        from repro.analysis import locks
        from repro.runtime import engine
        monitor = locks.install()
        engine._DISPATCH_NOTE = monitor.note_dispatch
        config._lock_monitor = monitor


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run tests marked slow (traffic soak tests)")
    parser.addoption("--lock-check", action="store_true", default=False,
                     help="run under the repro.analysis lock-order "
                          "detector; fail the session on any cycle or "
                          "lock held across device dispatch")


@pytest.fixture(scope="session", autouse=True)
def _lock_check_verdict(request):
    """With --lock-check: assert an acyclic lock-order graph at session
    end (teardown failure -> nonzero pytest exit, report printed)."""
    yield
    monitor = getattr(request.config, "_lock_monitor", None)
    if monitor is None:
        return
    from repro.analysis import locks
    from repro.runtime import engine
    locks.uninstall()
    engine._DISPATCH_NOTE = None
    report = monitor.report()
    sys.stderr.write(f"\n[lock-check] {report}\n")
    assert not monitor.cycles(), f"lock-order cycles detected:\n{report}"
    assert not monitor.dispatch_violations, \
        f"locks held across device dispatch:\n{report}"


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow soak test: needs --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run python code in a fresh process with N fake CPU devices.

    Multi-device sharding/collective tests need a device count set before
    jax initialises, so they run out of process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}")
    return res.stdout


@pytest.fixture
def subproc():
    return run_subprocess
