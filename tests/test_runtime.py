"""Runtime resource manager: Pareto/LUT/governor invariants (the paper's
claims as properties) + serving engine behaviour."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pareto import OpPoint, accuracy_latency_front, pareto_front
from repro.core.types import ElasticSpace, SubnetSpec
from repro.runtime import (Constraints, JointGovernor, PerformanceGovernor,
                           SchedutilGovernor, StaticPrunedGovernor,
                           model_lut, paper_trace, run_governor)
from repro.runtime import hwmodel as hm

settings.register_profile("rt", max_examples=25, deadline=None)
settings.load_profile("rt")

TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008, t_collective=0.004)
SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))
LUT = model_lut(SPACE.enumerate(), full_terms=TERMS, full_chips=256)


def test_pareto_front_non_dominated():
    front = pareto_front(LUT.points)
    assert front
    for p in front:
        assert not any(q.dominates(p) for q in LUT.points)


def test_accuracy_latency_front_monotone():
    front = accuracy_latency_front(LUT.points)
    lats = [p.latency_ms for p in front]
    accs = [p.accuracy for p in front]
    assert lats == sorted(lats)
    assert accs == sorted(accs)


@given(target=st.floats(1.0, 200.0), chips=st.sampled_from([64, 128, 256]),
       throttle=st.sampled_from([1.0, 0.7]))
def test_governor_meets_feasible_targets(target, chips, throttle):
    gov = JointGovernor(LUT)
    c = Constraints(target_latency_ms=target, chips_available=chips,
                    temperature_throttle=throttle)
    point = gov.select(c)
    feasible = gov._feasible(c)
    if feasible:
        assert point.latency_ms <= target
        assert point.hw_state.chips <= chips
        # max-accuracy selection
        assert point.accuracy == max(p.accuracy for p in feasible)
    else:
        # graceful degradation: fastest point that respects the throttle
        assert point.latency_ms == min(
            p.latency_ms for p in LUT.points
            if p.hw_state.chips <= chips and p.hw_state.freq <= throttle)
        assert point.hw_state.freq <= throttle


def test_governor_hysteresis_no_oscillation():
    gov = JointGovernor(LUT)
    c = Constraints(target_latency_ms=40.0, chips_available=256)
    p1 = gov.select(c)
    # a tiny target wiggle should not flip the operating point
    picks = {gov.select(Constraints(target_latency_ms=40.0 + d,
                                    chips_available=256)).subnet
             for d in (-0.5, 0.0, 0.5)}
    assert len(picks) == 1
    assert p1.subnet in picks


def test_paper_claims_qualitative():
    """The paper's two headline comparisons, on the modelled trace:
    (1) joint saves energy vs performance/schedutil at <= violations;
    (2) joint beats static pruning on accuracy at similar latency."""
    full = SubnetSpec()
    trace = lambda: paper_trace(300, chips=256, base_target_ms=30.0)
    joint = run_governor(JointGovernor(LUT), trace()).summary()
    perf = run_governor(PerformanceGovernor(LUT, full), trace()).summary()
    sched = run_governor(SchedutilGovernor(LUT, full), trace()).summary()
    static = run_governor(StaticPrunedGovernor(
        LUT, worst_case=Constraints(target_latency_ms=15.0,
                                    chips_available=128)), trace()).summary()
    assert joint["energy_mj"] < perf["energy_mj"]
    assert joint["energy_mj"] < sched["energy_mj"]
    assert joint["violation_rate"] <= perf["violation_rate"]
    assert joint["mean_accuracy"] > static["mean_accuracy"] + 1.0


def test_dvfs_energy_monotone_in_frequency():
    e = [hm.power_w(hm.HwState(chips=1, freq=f)) for f in hm.FREQ_LADDER]
    assert e == sorted(e)


def test_engine_switching_and_measurement():
    import jax
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=64, patch=8, n_layers=8, d_model=128,
                    n_heads=4, d_ff=512, n_classes=10,
                    compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 128, "d_ff": 512, "n_heads": 4, "n_layers": 8}
    server = DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                           params, dims, max_batch=8)
    x = np.random.default_rng(0).normal(size=(8, 64, 64, 3)).astype("float32")
    y = server.infer(x)
    assert y.shape == (8, 10)
    half = SubnetSpec(width_mult=0.5, ffn_mult=0.25, depth_mult=0.5)
    lat_full = server.measure(SubnetSpec(), x, iters=9)
    lat_half = server.measure(half, x, iters=9)
    # ~8x fewer FLOPs; demand >=1.3x to stay robust under CI noise
    assert lat_half * 1.3 < lat_full    # compute really shrinks (sliced)
    # warm switch is cheap (cache hit)
    server.switch(half)
    assert server.switch_log[-1]["ms"] < 50.0


def test_engine_batched_serving():
    import jax
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=32, patch=8, n_layers=2, d_model=32,
                    n_heads=4, d_ff=64, n_classes=10,
                    compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    server = DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                           params, dims, max_batch=4, timeout_ms=2.0)
    x = np.zeros((32, 32, 3), "float32")
    server.start()
    futs = [server.submit(x) for _ in range(10)]
    outs = [f.get(timeout=30) for f in futs]
    server.stop()
    assert len(outs) == 10
    assert all(o["y"].shape == (10,) for o in outs)
