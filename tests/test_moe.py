"""MoE dispatch equivalences and invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_mesh
from repro.models.moe import MoEConfig, moe_apply, moe_init

KEY = jax.random.PRNGKey(0)
CFG = MoEConfig(n_experts=8, top_k=2, d_ff=64, n_shared=1,
                capacity_factor=4.0, group_size=16)


@pytest.fixture(scope="module")
def setup():
    p = moe_init(KEY, 32, CFG)
    x = jax.random.normal(KEY, (2, 16, 32))
    return p, x


def test_einsum_matches_dense_oracle(setup):
    p, x = setup
    y_d, aux_d = moe_apply(p, x, dataclasses.replace(CFG, dispatch="dense"))
    y_e, aux_e = moe_apply(p, x, dataclasses.replace(CFG, dispatch="einsum"))
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_e),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_d), float(aux_e), rtol=1e-5)


def test_a2a_matches_dense_oracle(setup):
    p, x = setup
    mesh = make_mesh((1, 1), ("data", "model"))
    y_d, _ = moe_apply(p, x, dataclasses.replace(CFG, dispatch="dense"))
    y_a, _ = moe_apply(p, x, dataclasses.replace(CFG, dispatch="a2a"),
                       mesh=mesh, data_axes=("data",))
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_a),
                               rtol=2e-4, atol=2e-4)


def test_elastic_experts_slice_eq_mask(setup):
    p, x = setup
    cfg = dataclasses.replace(CFG, dispatch="einsum")
    y_s, _ = moe_apply(p, x, cfg, a_experts=4, top_k=1, a_ff=32)
    y_m, _ = moe_apply(p, x, cfg, a_experts=jnp.asarray(4), top_k=1,
                       a_ff=jnp.asarray(32))
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_m),
                               rtol=2e-4, atol=2e-4)


def test_inactive_experts_get_no_tokens(setup):
    p, x = setup
    cfg = dataclasses.replace(CFG, dispatch="dense")
    # with a_experts=4, routing probabilities to experts >=4 must be 0
    from repro.models.moe import _router
    probs, _, top_idx = _router(p, x, cfg, jnp.asarray(4), 2)
    assert float(jnp.max(probs[..., 4:])) == 0.0
    assert int(jnp.max(top_idx)) < 4


def test_capacity_drops_are_deterministic(setup):
    p, x = setup
    tight = dataclasses.replace(CFG, capacity_factor=0.5, dispatch="einsum")
    y1, _ = moe_apply(p, x, tight)
    y2, _ = moe_apply(p, x, tight)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_aux_loss_balanced_router_is_minimal():
    """Uniform routing => aux loss ~= 1 (its minimum, by AM-GM)."""
    d, E = 16, 8
    cfg = MoEConfig(n_experts=E, top_k=1, d_ff=8, capacity_factor=4.0,
                    group_size=64)
    p = moe_init(KEY, d, cfg)
    # force uniform logits
    p["router"]["kernel"] = jnp.zeros_like(p["router"]["kernel"])
    x = jax.random.normal(KEY, (1, 64, d))
    _, aux = moe_apply(p, x, dataclasses.replace(cfg, dispatch="dense"))
    assert 0.9 < float(aux) < 1.3


def test_a2a_multidevice_matches_single(subproc):
    """EP across a real (2,4) device mesh equals the dense oracle."""
    subproc("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.moe import MoEConfig, moe_apply, moe_init
cfg = MoEConfig(n_experts=8, top_k=2, d_ff=64, n_shared=1,
                capacity_factor=4.0, group_size=16)
key = jax.random.PRNGKey(0)
p = moe_init(key, 32, cfg)
x = jax.random.normal(key, (4, 16, 32))
y_ref, _ = moe_apply(p, x, dataclasses.replace(cfg, dispatch="dense"))
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
with mesh:
    fn = jax.jit(lambda p, x: moe_apply(
        p, x, dataclasses.replace(cfg, dispatch="a2a"), mesh=mesh,
        data_axes=("data",))[0])
    y = fn(p, x)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y),
                           rtol=3e-4, atol=3e-4)
print("OK")
""", n_devices=8)
