"""Checkpoint: roundtrip, atomicity, rotation, elastic reshard."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointManager, restore_checkpoint,
                              save_checkpoint)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "layers": [{"b": jnp.ones((4,))},
                                  {"b": jnp.zeros((4,))}]},
            "opt": {"mu": jnp.full((8, 16), 0.5)}}


def test_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, 7, st)
    step, restored = restore_checkpoint(tmp_path)
    assert step == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        st, restored)


def test_keep_k_rotation_and_latest(tmp_path):
    m = CheckpointManager(tmp_path, save_every=1, keep=2, async_save=False)
    for step in range(5):
        m.maybe_save(step, _state(step))
    m.wait()
    assert m.latest_step() == 4
    steps = sorted(int(p.name.split("_")[1])
                   for p in m.dir.glob("step_*"))
    assert steps == [3, 4]


def test_atomicity_tmp_dirs_ignored(tmp_path):
    save_checkpoint(tmp_path, 1, _state())
    (tmp_path / ".tmp_step_00000002").mkdir()   # simulated dead partial save
    step, _ = restore_checkpoint(tmp_path)
    assert step == 1


def test_save_every_gate(tmp_path):
    m = CheckpointManager(tmp_path, save_every=10, async_save=False)
    assert not m.maybe_save(3, _state())
    assert m.maybe_save(10, _state())


def test_elastic_reshard_on_restore(subproc):
    """Save under a (4,2) mesh sharding, restore onto (2,2) — values equal.
    This is the lose-a-pod recovery path."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint
d = tempfile.mkdtemp()
x = jnp.arange(64.0).reshape(8, 8)
from repro.launch.mesh import make_mesh
mesh1 = make_mesh((4, 2), ("data", "model"))
xs = jax.device_put(x, NamedSharding(mesh1, P("data", "model")))
save_checkpoint(d, 0, {"w": xs})
devs = np.array(jax.devices()[:4]).reshape(2, 2)
from jax.sharding import Mesh
mesh2 = Mesh(devs, ("data", "model"))
sh = {"w": NamedSharding(mesh2, P("model", "data"))}
step, st = restore_checkpoint(d, shardings=sh)
np.testing.assert_array_equal(np.asarray(st["w"]), np.asarray(x))
assert st["w"].sharding.mesh.shape["data"] == 2
print("OK")
""", n_devices=8)


def test_run_with_restarts_resumes(tmp_path):
    from repro.distributed.fault import SimulatedFailure, run_with_restarts
    m = CheckpointManager(tmp_path, save_every=2, async_save=False)
    calls = {"n": 0}

    def train(start_step, state):
        calls["n"] += 1
        x = state["x"] if state else 0
        for step in range(start_step, 10):
            x = x + 1
            m.maybe_save(step, {"x": x})
            if calls["n"] == 1 and step == 5:
                raise SimulatedFailure("boom")
        return {"x": x}

    final, restarts = run_with_restarts(train, manager=m, logger=lambda *_: 0)
    assert restarts == 1
    assert final["x"] == 10   # deterministic resume: same total increments


def test_straggler_monitor_flags_outliers():
    from repro.distributed.fault import StragglerMonitor
    mon = StragglerMonitor(window=20, threshold=2.0)
    for i in range(15):
        assert not mon.record(i, 0.1)
    assert mon.record(15, 0.5)
    assert mon.flags[0]["step"] == 15


def test_watchdog_detects_stall():
    import time
    from repro.distributed.fault import Watchdog
    events = []
    w = Watchdog(timeout_s=0.2, on_stall=lambda: events.append(1)).start()
    time.sleep(0.5)
    assert w.stalled and events
    w.stop()
