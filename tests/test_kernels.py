"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py),
executed in Pallas interpret mode on CPU (the kernel body runs in Python).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import elastic_matmul_op, flash_attention_op
from repro.kernels.ref import elastic_matmul_ref, flash_attention_ref

settings.register_profile("kernels", max_examples=8, deadline=None)
settings.load_profile("kernels")

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ka,na", [(256, 384), (128, 384), (256, 200),
                                   (100, 100), (1, 1), (129, 255)])
def test_elastic_matmul_sweep(dtype, ka, na):
    x = jax.random.normal(KEY, (64, 256), jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (256, 384),
                          jnp.float32).astype(dtype)
    y = elastic_matmul_op(x, w, ka, na, bm=32)
    yr = elastic_matmul_ref(x, w, ka, na)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=tol, atol=tol)


@given(m=st.integers(1, 40), k_act=st.integers(1, 256),
       n_act=st.integers(1, 384))
def test_elastic_matmul_property(m, k_act, n_act):
    x = jax.random.normal(KEY, (m, 256))
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (256, 384))
    y = elastic_matmul_op(x, w, k_act, n_act, bm=32)
    yr = elastic_matmul_ref(x, w, k_act, n_act)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)
    assert np.all(np.asarray(y[:, n_act:]) == 0)


def test_elastic_matmul_traced_widths_one_executable():
    """The widths are traced: one jit covers every (k_act, n_act)."""
    x = jax.random.normal(KEY, (32, 256))
    w = jax.random.normal(KEY, (256, 256))
    f = jax.jit(lambda ka, na: elastic_matmul_op(x, w, ka, na, bm=32))
    for ka, na in [(256, 256), (64, 128), (10, 250)]:
        np.testing.assert_allclose(
            np.asarray(f(ka, na)),
            np.asarray(elastic_matmul_ref(x, w, ka, na)),
            rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("S,T,H,KH,D", [
    (256, 256, 4, 4, 64), (256, 256, 4, 2, 64), (512, 512, 2, 1, 32),
])
def test_flash_attention_sweep(dtype, causal, S, T, H, KH, D):
    B = 2
    q = (jax.random.normal(KEY, (B, S, H, D), jnp.float32) * 0.3).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, KH, D),
                           jnp.float32) * 0.3).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, KH, D),
                          jnp.float32).astype(dtype)
    o = flash_attention_op(q, k, v, causal=causal, bq=128, bkv=128)
    kr = jnp.repeat(k, H // KH, 2)
    vr = jnp.repeat(v, H // KH, 2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = kr.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    vf = vr.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    orf = flash_attention_ref(qf, kf, vf, causal=causal)
    orf = orf.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-3
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), rtol=tol, atol=tol)


def test_flash_attention_long_context_block_sizes():
    """Non-square blocking + longer T (decode-ish asymmetry)."""
    q = jax.random.normal(KEY, (1, 128, 2, 64)) * 0.3
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1024, 2, 64)) * 0.3
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 1024, 2, 64))
    o = flash_attention_op(q, k, v, causal=False, bq=64, bkv=256)
    qf = q.transpose(0, 2, 1, 3).reshape(2, 128, 64)
    kf = k.transpose(0, 2, 1, 3).reshape(2, 1024, 64)
    vf = v.transpose(0, 2, 1, 3).reshape(2, 1024, 64)
    orf = flash_attention_ref(qf, kf, vf, causal=False)
    orf = orf.reshape(1, 2, 128, 64).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=3e-3, atol=3e-3)
