"""Traffic layer: seeded arrivals, SLO percentile/goodput math, admission
control, mid-cycle preemption, and the engine drain-on-stop bugfix."""
import queue
import threading
import time

import numpy as np
import pytest

from repro.core.types import ElasticSpace, SubnetSpec
from repro.runtime import (AdmissionError, GlobalConstraints, ResourceArbiter,
                           default_hw_states, model_lut, quantile)
from repro.runtime import hwmodel as hm
from repro.traffic import (DEGRADE, FIFO_POLICY, REJECT, SHED, SLO_POLICY,
                           ClassStats, SLOClass, diurnal, merge, onoff,
                           poisson, replay, save_schedule, simulate)

TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008, t_collective=0.004)
SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))


def make_lut(scale=1.0, full_chips=256):
    terms = hm.RooflineTerms(TERMS.t_compute * scale, TERMS.t_memory * scale,
                             TERMS.t_collective * scale)
    return model_lut(SPACE.enumerate(), full_terms=terms,
                     full_chips=full_chips)


# --- arrival generators -------------------------------------------------------

@pytest.mark.parametrize("gen,kwargs", [
    (poisson, dict(rate_rps=50.0, horizon_s=5.0)),
    (onoff, dict(rate_rps=80.0, horizon_s=5.0, on_s=0.5, off_s=0.5)),
    (diurnal, dict(peak_rps=60.0, horizon_s=5.0, period_s=2.0)),
])
def test_arrivals_seed_deterministic(gen, kwargs):
    """Same seed => identical inter-arrival sequence (acceptance item)."""
    a = gen(seed=7, **kwargs)
    b = gen(seed=7, **kwargs)
    c = gen(seed=8, **kwargs)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.all(np.diff(a) >= 0)          # sorted
    assert len(a) and a[0] >= 0 and a[-1] < kwargs["horizon_s"]


def test_poisson_hits_target_mean_rate():
    rate, horizon = 200.0, 50.0
    ts = poisson(rate, horizon, seed=3)
    measured = len(ts) / horizon
    assert abs(measured - rate) / rate < 0.05
    # inter-arrival mean ~ 1/rate
    gaps = np.diff(ts)
    assert abs(gaps.mean() - 1.0 / rate) / (1.0 / rate) < 0.05


def test_onoff_is_bursty():
    """ON windows carry the load; OFF windows are silent."""
    ts = onoff(100.0, 10.0, on_s=1.0, off_s=1.0, seed=0)
    phase = np.floor(ts) % 2.0
    assert np.all(phase == 0.0)             # every arrival in an ON second
    assert len(ts) > 300                    # ~100 rps over 5 ON seconds


def test_diurnal_ramps():
    """The thinned stream is denser at mid-period than at the floor."""
    ts = diurnal(200.0, 40.0, period_s=40.0, floor=0.05, seed=1)
    early = np.sum(ts < 5.0)                # near the floor
    mid = np.sum((ts >= 17.5) & (ts < 22.5))  # near the peak
    assert mid > 3 * early


def test_replay_roundtrip(tmp_path):
    ts = poisson(30.0, 3.0, seed=5)
    path = str(tmp_path / "sched.json")
    save_schedule(path, ts, meta={"rate": 30.0})
    back = replay(path)
    assert np.allclose(back, ts)
    assert np.allclose(replay(list(ts)), ts)


def test_merge_orders_events():
    ev = merge({"a": [0.3, 0.1], "b": [0.2]})
    assert ev == [(0.1, "a"), (0.2, "b"), (0.3, "a")]


# --- percentile / goodput math ------------------------------------------------

def test_quantile_nearest_rank_hand_values():
    xs = list(range(1, 101))               # 1..100
    assert quantile(xs, 50) == 50
    assert quantile(xs, 95) == 95
    assert quantile(xs, 99) == 99
    assert quantile(xs, 100) == 100
    assert quantile([7.0], 95) == 7.0
    assert np.isnan(quantile([], 50))


def test_class_stats_summary_hand_built():
    st = ClassStats()
    deadline = 50.0
    for lat in (10.0, 20.0, 30.0, 40.0, 60.0):   # one miss
        st.submitted += 1
        st.completed += 1
        st.latencies_ms.append(lat)
        if lat <= deadline:
            st.good += 1
    st.submitted += 2
    st.dropped += 1
    st.rejected += 1
    s = st.summary()
    assert s["goodput"] == 4
    assert s["submitted"] == 7
    assert s["p50_ms"] == 30.0
    assert s["p95_ms"] == 60.0
    assert s["goodput_rate"] == pytest.approx(4 / 7, abs=1e-4)


# --- SLO classes --------------------------------------------------------------

def test_slo_class_validation_and_mapping():
    c = SLOClass("x", deadline_ms=80.0, priority=3, drop_policy=SHED,
                 service_frac=0.5)
    assert c.service_target_ms == 40.0
    cons = c.constraints(chips_available=64, share=0.25)
    assert cons.target_latency_ms == 40.0
    assert cons.priority == 3 and cons.share == 0.25
    with pytest.raises(ValueError):
        SLOClass("bad", deadline_ms=-1.0)
    with pytest.raises(ValueError):
        SLOClass("bad", deadline_ms=10.0, drop_policy="nope")


# --- admission control --------------------------------------------------------

def test_admission_rejects_impossible_deadline():
    """No operating point can ever meet the target => rejected."""
    arb = ResourceArbiter()
    g = GlobalConstraints(total_chips=256)
    with pytest.raises(AdmissionError):
        arb.register("rt", make_lut(), target_latency_ms=0.001,
                     admission_under=g)
    assert "rt" not in arb.last_alloc       # nothing was registered


def test_admission_rejects_when_pool_too_small():
    """A feasible-in-principle class whose minimal share exceeds the
    machine is rejected; the same class fits a bigger pool."""
    arb = ResourceArbiter()
    lut = make_lut()
    with pytest.raises(AdmissionError):
        arb.register("a", lut, target_latency_ms=40.0,
                     admission_under=GlobalConstraints(total_chips=32))
    arb.register("a", lut, target_latency_ms=40.0,
                 admission_under=GlobalConstraints(total_chips=256))


def test_admission_respects_higher_priority_reservations():
    """Equal-or-higher-priority tenants reserve their minimal shares; a
    newcomer that can't fit the remainder is rejected, while a HIGHER
    priority newcomer may still preempt its way in."""
    arb = ResourceArbiter()
    g = GlobalConstraints(total_chips=64)
    arb.register("incumbent", make_lut(), target_latency_ms=40.0,
                 priority=2, admission_under=g)
    # same priority: incumbent's 48-chip minimal share blocks it
    with pytest.raises(AdmissionError):
        arb.register("peer", make_lut(), target_latency_ms=40.0,
                     priority=2, admission_under=g)
    # higher priority: the incumbent is preemptable => admitted
    arb.register("vip", make_lut(), target_latency_ms=40.0,
                 priority=5, admission_under=g)


# --- preemption ---------------------------------------------------------------

def test_preempt_evicts_lower_priority_within_one_tick():
    """A high-priority arrival gets its slice mid-cycle: the preempt call
    itself returns a feasible allocation and the low-priority tenant is
    demoted, without waiting for the next clock tick."""
    arb = ResourceArbiter()
    arb.register("lo", make_lut(), target_latency_ms=40.0, priority=0)
    arb.register("hi", make_lut(), target_latency_ms=40.0, priority=2)
    arb.set_active("hi", False)             # hi idle: releases its slice
    g = GlobalConstraints(total_chips=64)   # pool fits only one tenant
    allocs = arb.tick(g)
    assert allocs["lo"].feasible            # lo holds the machine
    assert allocs["hi"].chips == 0
    alloc = arb.preempt("hi", g)            # the high-priority arrival
    assert alloc.feasible
    assert not arb.last_alloc["lo"].feasible    # evicted mid-cycle
    assert arb.summary()["hi"]["preemptions"] == 1


def test_set_active_releases_and_regains_slice():
    arb = ResourceArbiter()
    arb.register("a", make_lut(), target_latency_ms=40.0)
    g = GlobalConstraints(total_chips=256)
    assert arb.arbitrate(g)["a"].feasible
    arb.set_active("a", False)
    assert arb.arbitrate(g)["a"].chips == 0
    arb.set_active("a", True)
    assert arb.arbitrate(g)["a"].feasible


# --- engine drain-on-stop bugfix ---------------------------------------------

def tiny_server():
    import jax
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2,
                    d_model=32, n_heads=4, d_ff=64, n_classes=4,
                    compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    return DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                         params, dims)


def test_stop_resolves_abandoned_futures():
    """Queued requests on a paused/never-started server must not leave
    callers blocked forever: stop() drains them with a cancelled payload."""
    server = tiny_server()
    x = np.zeros((16, 16, 3), "float32")
    futs = [server.submit(x) for _ in range(3)]
    server.stop()                           # never started
    for f in futs:
        out = f.get(timeout=5)
        assert out["cancelled"] and out["y"] is None
        assert out["error"] == "server stopped"
    assert server.cancelled == 3
    # submissions after stop resolve immediately instead of queueing
    out = server.submit(x).get(timeout=5)
    assert out["cancelled"]


def test_stop_drains_paused_server():
    server = tiny_server()
    server.start()
    server.pause()
    x = np.zeros((16, 16, 3), "float32")
    # the worker may sit one last _collect_batch window (50ms) before it
    # sees the pause flag; wait it out so submissions can't be picked up
    time.sleep(0.2)
    futs = [server.submit(x) for _ in range(4)]
    server.stop()
    outs = [f.get(timeout=5) for f in futs]
    assert all(o["cancelled"] for o in outs)


def test_stop_unblocks_waiting_caller_thread():
    """The original bug: a caller blocked on fut.get() hangs forever."""
    server = tiny_server()
    fut = server.submit(np.zeros((16, 16, 3), "float32"))
    got = queue.Queue()
    th = threading.Thread(target=lambda: got.put(fut.get(timeout=30)))
    th.start()
    server.stop()
    th.join(timeout=10)
    assert not th.is_alive()
    assert got.get_nowait()["cancelled"]


# --- measured energy accounting ----------------------------------------------

def test_measured_energy_in_arbiter_summary():
    arb = ResourceArbiter()
    server = tiny_server()
    arb.register("a", make_lut(), target_latency_ms=40.0, server=server)
    arb.tick(GlobalConstraints(total_chips=256))
    server.start()
    try:
        x = np.zeros((16, 16, 3), "float32")
        futs = [server.submit(x) for _ in range(4)]
        outs = [f.get(timeout=60) for f in futs]
        assert all(not o.get("cancelled") for o in outs)
    finally:
        server.stop()
    s = arb.summary()["a"]
    assert s["measured_energy_mj"] > 0.0
    assert s["busy_s"] > 0.0
    # measured = busy wall-clock x the active slice's modelled power
    hw = server.active_point.hw_state
    assert s["measured_energy_mj"] == pytest.approx(
        hm.slice_power_w(hw) * server.busy_s * 1e3, rel=0.01)


# --- finer LUT granularity ----------------------------------------------------

def test_default_hw_states_finer_than_legacy():
    states = default_hw_states(256)
    chips = sorted({s.chips for s in states}, reverse=True)
    assert chips == [256, 192, 128, 96, 64, 48, 32, 16]
    assert all(s.chips >= 1 for s in states)
    assert default_hw_states(1)             # degenerate pool still works
    # model_lut picks the ladder up by default
    lut = make_lut()
    assert sorted({p.hw_state.chips for p in lut.points},
                  reverse=True) == chips


# --- end-to-end simulated traffic --------------------------------------------

def _sim_setup(horizon_s=6.0):
    classes = [
        SLOClass("interactive", deadline_ms=60.0, priority=2,
                 drop_policy=SHED),
        SLOClass("batch", deadline_ms=400.0, priority=0,
                 drop_policy=DEGRADE),
        SLOClass("impossible", deadline_ms=2.0, priority=1,
                 drop_policy=REJECT),
    ]
    luts = {c.name: make_lut() for c in classes}
    streams = {
        "interactive": onoff(40.0, horizon_s, on_s=1.0, off_s=1.0, seed=1),
        "batch": poisson(5.0, horizon_s, seed=2),
        "impossible": poisson(8.0, horizon_s, seed=3),
    }
    g_fn = lambda t: GlobalConstraints(total_chips=256)
    return classes, luts, streams, g_fn


def test_simulate_slo_beats_fifo_on_same_trace():
    classes, luts, streams, g_fn = _sim_setup()
    slo = simulate(classes, luts, streams, g_fn, policy=SLO_POLICY)
    fifo = simulate(classes, luts, streams, g_fn, policy=FIFO_POLICY)
    assert slo.total_goodput > fifo.total_goodput
    assert slo.classes["interactive"].p(95) <= fifo.classes["interactive"].p(95)
    # admission fired: the impossible class is rejected under slo only
    assert slo.classes["impossible"].rejected > 0
    assert fifo.classes["impossible"].rejected == 0
    # preemption fired for the bursty class
    assert slo.arbiter["interactive"]["preemptions"] > 0
    # accounting closes: every request ends in exactly one bucket
    for rep in (slo, fifo):
        for cs in rep.classes.values():
            assert cs.submitted == cs.rejected + cs.dropped + cs.completed


def test_simulate_is_deterministic():
    classes, luts, streams, g_fn = _sim_setup(horizon_s=3.0)
    a = simulate(classes, luts, streams, g_fn, policy=SLO_POLICY).summary()
    b = simulate(classes, luts, streams, g_fn, policy=SLO_POLICY).summary()
    assert a == b


def test_simulate_shed_bounds_tail_latency():
    """A SHED class's completed requests never report unbounded waits:
    shedding keeps the served tail near the deadline."""
    classes, luts, streams, g_fn = _sim_setup()
    # the batching-aware service model amortises the old 40 rps burst away;
    # overload the bucketed capacity (~max_batch per point-latency) instead
    streams["interactive"] = onoff(800.0, 6.0, on_s=1.0, off_s=1.0, seed=1)
    rep = simulate(classes, luts, streams, g_fn, policy=SLO_POLICY)
    inter = rep.classes["interactive"]
    assert inter.dropped > 0                       # overload really shed
    assert inter.p(95) <= classes[0].deadline_ms * 1.5
    assert inter.mean_batch > 1.0                  # overload really batched


@pytest.mark.slow
def test_live_driver_soak():
    """Wall-clock soak: real requests through two DynamicServers behind
    the arbiter (opt-in: pytest --runslow)."""
    from repro.runtime import measured_lut
    from repro.traffic import drive_live

    s_int, s_bat = tiny_server(), tiny_server()
    x = np.zeros((16, 16, 3), "float32")
    lut = measured_lut([SubnetSpec(), SubnetSpec(width_mult=0.5)],
                       lambda spec, hw: (s_int.measure(spec, x[None]), 1.0))
    classes = [SLOClass("interactive", deadline_ms=500.0, priority=2),
               SLOClass("batch", deadline_ms=2000.0, priority=0,
                        drop_policy=DEGRADE)]
    arb = ResourceArbiter(interval_s=0.05)
    arb.register("interactive", lut, classes[0].service_target_ms,
                 priority=2, server=s_int)
    arb.register("batch", lut, classes[1].service_target_ms,
                 priority=0, server=s_bat)
    rep = drive_live(
        classes, {"interactive": s_int, "batch": s_bat}, arb,
        {"interactive": poisson(20.0, 2.0, seed=0),
         "batch": poisson(10.0, 2.0, seed=1)},
        lambda name: x, g_fn=lambda: GlobalConstraints(total_chips=2))
    for cs in rep.classes.values():
        assert cs.submitted == cs.completed + cs.dropped
    assert rep.total_goodput > 0
