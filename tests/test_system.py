"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_train_cli_with_failure_recovery(tmp_path):
    """Full launcher path: smoke train + injected failure + restart."""
    from repro.launch import train as T
    state = T.main([
        "--arch", "dynamic-ofa-supernet", "--smoke", "--steps", "12",
        "--save-every", "4", "--fail-at", "9",
        "--ckpt-dir", str(tmp_path), "--log-every", "100"])
    assert state is not None
    leaves = jax.tree_util.tree_leaves(state["params"])
    assert all(not np.any(np.isnan(np.asarray(l, np.float32)))
               for l in leaves)


def test_sandwich_supernet_training_improves_all_subnets():
    """The paper's training recipe: after a few hundred steps on the
    learnable synthetic task, every sub-network beats chance, and the full
    net is at least as good as the smallest (accuracy ordering)."""
    from repro.core.supernet import make_sandwich_step
    from repro.core.elastic import spec_to_static
    from repro.data import synthetic_image_batches
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.optim import make_optimizer
    from repro.core.types import ElasticSpace

    cfg = ViTConfig(name="t", img_res=16, patch=4, n_layers=3, d_model=32,
                    n_heads=4, d_ff=64, n_classes=4, compute_dtype="float32",
                    elastic=ElasticSpace(width_mults=(0.5, 1.0),
                                         ffn_mults=(0.5, 1.0),
                                         depth_mults=(2 / 3, 1.0)))
    params = vit_init(jax.random.PRNGKey(0), cfg)
    init_fn, update_fn = make_optimizer("adamw", lr=3e-3, weight_decay=0.0)
    opt = init_fn(params)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 3}

    apply_fn = lambda p, b, E: vit_apply(p, b["images"], cfg, E=E)[0]
    step_fn, sample_fn = make_sandwich_step(apply_fn, update_fn, dims,
                                            n_random=1)
    step_jit = jax.jit(step_fn)
    rng = np.random.default_rng(0)
    data = synthetic_image_batches(global_batch=32, img_res=16, n_classes=4)
    for step in range(150):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        E_stack = sample_fn(cfg.elastic, rng)
        params, opt, metrics = step_jit(params, opt, batch, E_stack,
                                        jnp.asarray(step))
    assert float(metrics["loss"]) < 2.0

    # evaluate subnets (sliced mode)
    test_batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    def acc(E):
        logits = apply_fn(params, test_batch, E)
        return float(jnp.mean(jnp.argmax(logits, -1)
                              == test_batch["labels"]))
    accs = {}
    for spec in cfg.elastic.enumerate():
        accs[spec.name()] = acc(spec_to_static(spec, dims))
    full = accs[cfg.elastic.max_spec().name()]
    smallest = accs[cfg.elastic.min_spec().name()]
    assert full > 0.5, accs            # beats 0.25 chance clearly
    assert smallest > 0.3, accs        # small subnet still works
    assert full >= smallest - 0.05, accs


def test_multipod_cell_lowering_smoke(subproc):
    """A reduced LM cell lowers+compiles on the REAL multi-pod mesh shape
    (2,16,16) — the dry-run path end-to-end, in-process proof."""
    out = subproc("""
import jax
from repro.configs import get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.distributed import use_mesh
mesh = make_production_mesh(multi_pod=True)
arch = get_arch("granite-20b")
with use_mesh(mesh):
    # smoke batch 64 shards evenly over the 32-way (pod,data) batch axes
    cell = build_cell(arch, "train_4k", smoke=True, mesh=mesh,
                      smoke_batch=64)
    compiled = cell.lower(mesh).compile()
ma = compiled.memory_analysis()
print("COMPILED", ma.temp_size_in_bytes >= 0)
""", n_devices=512, timeout=900)
    assert "COMPILED True" in out


def test_dryrun_records_exist_and_are_wellformed():
    """The sweep writes one record per cell; every ok record carries the
    three roofline terms and the memory analysis."""
    import glob
    import json
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    recs = [json.load(open(f)) for f in
            glob.glob(os.path.join(root,
                                   "benchmarks/results/dryrun/*__base.json"))]
    if not recs:
        pytest.skip("dry-run sweep has not produced records yet")
    ok = [r for r in recs if r["status"] == "ok"]
    assert ok, "no successful dry-run records"
    for r in ok:
        assert r["t_compute"] >= 0 and r["t_memory"] >= 0
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert "per_device_total" in r["memory"]
