"""Cluster layer: routing policies, cluster admission, node lifecycle
(drain/failover), determinism, the live front-end, and this PR's
satellites (record/replay, adaptive batching window, unregister-stats
bugfix)."""
import numpy as np
import pytest

from repro.cluster import (DEAD, DRAINED, P2C, LEAST_LOADED, ROUND_ROBIN,
                           Cluster, ClusterNode, ClusterRouter,
                           cluster_admission, cluster_headroom,
                           simulate_cluster)
from repro.core.types import ElasticSpace
from repro.runtime import (AdmissionError, GlobalConstraints, ResourceArbiter,
                           model_lut)
from repro.runtime import hwmodel as hm
from repro.traffic import (DEGRADE, SHED, SLOClass, load_schedule, poisson,
                           save_schedule, simulate)

TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008, t_collective=0.004)
SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))


def make_lut(scale=1.0, full_chips=256):
    terms = hm.RooflineTerms(TERMS.t_compute * scale, TERMS.t_memory * scale,
                             TERMS.t_collective * scale)
    return model_lut(SPACE.enumerate(), full_terms=terms,
                     full_chips=full_chips)


def make_nodes(capacities):
    return [ClusterNode(name=f"n{i}",
                        g_fn=lambda t, c=cap: GlobalConstraints(total_chips=c))
            for i, cap in enumerate(capacities)]


def one_class(deadline_ms=200.0, drop_policy=SHED, name="api"):
    return SLOClass(name, deadline_ms=deadline_ms, priority=2,
                    drop_policy=drop_policy)


# --- router ------------------------------------------------------------------

def test_round_robin_cycles():
    nodes = make_nodes([64, 64, 64])
    r = ClusterRouter(ROUND_ROBIN)
    picks = [r.pick("a", nodes).name for _ in range(6)]
    assert picks == ["n0", "n1", "n2", "n0", "n1", "n2"]


def test_least_loaded_follows_signal():
    nodes = make_nodes([64, 64])
    nodes[0].arbiter.register("a", make_lut(), target_latency_ms=40.0)
    nodes[0].arbiter.set_active("a", True, queue_depth=10)
    nodes[1].arbiter.register("a", make_lut(), target_latency_ms=40.0)
    r = ClusterRouter(LEAST_LOADED)
    assert r.pick("a", nodes).name == "n1"       # n0 is backlogged
    # load normalises by chips: same backlog on a 4x bigger node is lighter
    big = make_nodes([256])[0]
    big.name = "big"
    big.arbiter.register("a", make_lut(), target_latency_ms=40.0)
    big.arbiter.set_active("a", True, queue_depth=10)
    assert r.pick("a", [nodes[0], big]).name == "big"


def test_p2c_is_seed_deterministic_and_skips_unroutable():
    nodes = make_nodes([64, 64, 64])
    a = ClusterRouter(P2C, seed=7)
    b = ClusterRouter(P2C, seed=7)
    pa = [a.pick("x", nodes).name for _ in range(32)]
    pb = [b.pick("x", nodes).name for _ in range(32)]
    assert pa == pb
    assert len(set(pa)) > 1                      # it really spreads
    nodes[0].state = DEAD
    assert a.pick("x", nodes).name in ("n1", "n2")
    assert a.pick("x", []) is None


def test_router_rejects_unknown_policy():
    with pytest.raises(ValueError):
        ClusterRouter("random")


# --- cluster admission -------------------------------------------------------

def test_admission_needs_one_fitting_node():
    """A 10ms class's minimal share exceeds a 64-chip node: rejected on
    small nodes, admitted (and placed on the big node only) after
    scale-out — the acceptance scenario."""
    lut = make_lut()
    with pytest.raises(AdmissionError):
        cluster_admission(make_nodes([64, 64]), lut, 10.0, priority=2)
    placed = cluster_admission(make_nodes([64, 64, 256]), lut, 10.0,
                               priority=2)
    assert placed == ["n2"]


def test_admission_skips_unroutable_nodes():
    lut = make_lut()
    nodes = make_nodes([256, 64])
    nodes[0].state = DEAD
    with pytest.raises(AdmissionError):
        cluster_admission(nodes, lut, 10.0, priority=2)


def test_cluster_headroom_sums_routable():
    nodes = make_nodes([64, 64])
    hr = cluster_headroom(nodes)
    assert hr.chips == 128                       # idle: everything free
    nodes[1].state = DEAD
    assert cluster_headroom(nodes).chips == 64


def test_headroom_shrinks_with_tenants():
    node = make_nodes([256])[0]
    free = node.headroom().chips
    node.arbiter.register("a", make_lut(), target_latency_ms=40.0)
    assert node.headroom().chips < free


# --- simulate_cluster: scaling + routing -------------------------------------

def _sim(caps, router=P2C, **kw):
    cls = [one_class()]
    return simulate_cluster(cls, {"api": make_lut()},
                            {"api": poisson(1000.0, 4.0, seed=1)},
                            make_nodes(caps), router=router, **kw)


def test_two_nodes_scale_goodput():
    g1 = _sim([64]).classes["api"].good
    g2 = _sim([64, 64]).classes["api"].good
    assert g2 >= 1.7 * g1


def test_p2c_beats_round_robin_under_skew():
    cls = [one_class(drop_policy=DEGRADE, name="web")]
    luts = {"web": make_lut()}
    stream = poisson(1000.0, 4.0, seed=2)
    reps = {r: simulate_cluster(cls, luts, {"web": list(stream)},
                                make_nodes([256, 64]), router=r)
            for r in (P2C, ROUND_ROBIN)}
    assert (reps[P2C].classes["web"].p(95)
            <= reps[ROUND_ROBIN].classes["web"].p(95))
    # p2c sent the slow node LESS than its round-robin half
    assert (reps[P2C].routed["web"]["n1"]
            < reps[ROUND_ROBIN].routed["web"]["n1"])


def test_rejected_class_counts_rejected():
    cls = [SLOClass("rt", deadline_ms=2.0, priority=1, drop_policy=SHED)]
    rep = simulate_cluster(cls, {"rt": make_lut()},
                           {"rt": poisson(50.0, 2.0, seed=3)},
                           make_nodes([64]))
    s = rep.classes["rt"]
    assert s.rejected == s.submitted > 0
    assert s.completed == 0


# --- determinism (acceptance) ------------------------------------------------

def test_cluster_sim_deterministic():
    """Same seed + same trace => identical routing decisions and
    ClusterReport across runs."""
    a = _sim([64, 64, 64])
    b = _sim([64, 64, 64])
    assert a.decisions == b.decisions
    assert a.summary() == b.summary()


def test_cluster_sim_deterministic_with_failover():
    a = _sim([64, 64], fail_at={"n1": 2.0})
    b = _sim([64, 64], fail_at={"n1": 2.0})
    assert a.decisions == b.decisions
    assert a.summary() == b.summary()


# --- node lifecycle in the simulator -----------------------------------------

def test_failover_loses_no_requests():
    """Killing a node mid-trace: every submitted request still ends in
    exactly one bucket, the dead node's backlog resolves as failed, and
    traffic re-routes to the survivor."""
    rep = _sim([64, 64], fail_at={"n1": 2.0})
    s = rep.classes["api"]
    assert s.submitted == s.rejected + s.dropped + s.failed + s.completed
    assert s.failed > 0                          # overloaded: n1 had backlog
    assert rep.nodes["n1"]["state"] == DEAD
    # post-fail arrivals all go to n0: n1 got fewer than half
    assert rep.routed["api"]["n1"] < rep.routed["api"]["n0"]


def test_drain_migrates_without_failures():
    """Draining a node serves its backlog (nothing failed), stops new
    routes, and migrates the registration off the node."""
    rep = _sim([64, 64], drain_at={"n1": 2.0})
    s = rep.classes["api"]
    assert s.failed == 0
    assert s.submitted == s.rejected + s.dropped + s.completed
    assert rep.nodes["n1"]["state"] == DRAINED
    # the drained arbiter holds no tenants any more (export_tenant ran)
    assert "api" not in rep.nodes["n1"]["arbiter"]


def test_fail_unplaceable_class_counts_dropped_not_rejected():
    """Arrivals after a class lost its only placement to a failure are
    availability losses (dropped), not admission rejects: the 10ms class
    fits only the 256-chip node, and no survivor can re-admit it."""
    cls = [SLOClass("rt", deadline_ms=20.0, priority=2, drop_policy=SHED)]
    rep = simulate_cluster(cls, {"rt": make_lut()},
                           {"rt": poisson(100.0, 4.0, seed=5)},
                           make_nodes([256, 64]), fail_at={"n0": 2.0})
    s = rep.classes["rt"]
    assert s.rejected == 0                       # admission DID place it
    assert s.dropped > 0                         # post-failover arrivals
    assert s.submitted == s.dropped + s.failed + s.completed


def test_fail_only_placement_readmits_elsewhere():
    """A class whose ONLY placement dies re-arbitrates on a survivor:
    the 10ms class fits just the big node; when that dies mid-trace the
    class is orphaned (no survivor fits it) and later arrivals drop —
    while a survivor WITH headroom picks it up when capacities allow."""
    lut = make_lut()
    cls = [SLOClass("rt", deadline_ms=20.0, priority=2, drop_policy=SHED,
                    service_frac=0.5)]
    # rt (10ms target) fits only the 256-chip nodes
    rep = simulate_cluster(cls, {"rt": lut},
                           {"rt": poisson(100.0, 4.0, seed=4)},
                           make_nodes([256, 256]), fail_at={"n0": 2.0})
    s = rep.classes["rt"]
    assert s.submitted == s.rejected + s.dropped + s.failed + s.completed
    # service continued on n1 after n0 died
    post_fail = [d for d in rep.decisions if d[0] > 2.0]
    assert post_fail and all(d[2] == "n1" for d in post_fail)


# --- live front-end ----------------------------------------------------------

def tiny_server(*_node):
    import jax
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2,
                    d_model=32, n_heads=4, d_ff=64, n_classes=4,
                    compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    return DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                         params, dims)


def live_lut():
    from repro.core.types import SubnetSpec
    return model_lut([SubnetSpec()], full_terms=TERMS, full_chips=2,
                     hw_states=[hm.HwState(chips=1, freq=1.0)])


def live_cluster(n=2):
    nodes = [ClusterNode(name=f"n{i}",
                         g_fn=lambda t: GlobalConstraints(total_chips=2))
             for i in range(n)]
    cluster = Cluster(nodes, router=P2C)
    cluster.register("api", live_lut(), target_latency_ms=500.0,
                     priority=1, make_server=tiny_server)
    return cluster


def test_live_cluster_routes_and_serves():
    cluster = live_cluster()
    cluster.start()
    try:
        x = np.zeros((16, 16, 3), "float32")
        outs = [cluster.submit("api", x).get(timeout=30) for _ in range(8)]
        assert all(not o.get("cancelled") for o in outs)
    finally:
        cluster.stop()
    routed = cluster.summary()["routed"]["api"]
    assert sum(routed.values()) == 8


def test_live_drain_serves_backlog_then_migrates():
    cluster = live_cluster()
    cluster.start()
    try:
        x = np.zeros((16, 16, 3), "float32")
        futs = [cluster.submit("api", x) for _ in range(6)]
        assert cluster.drain("n0", timeout_s=20.0)
        # nothing in flight was cancelled by the drain
        outs = [f.get(timeout=30) for f in futs]
        assert all(not o.get("cancelled") for o in outs)
        assert cluster.placements["api"] == ["n1"]
        assert cluster.nodes["n0"].state == DRAINED
        # the survivor still serves
        out = cluster.submit("api", x).get(timeout=30)
        assert not out.get("cancelled")
    finally:
        cluster.stop()


def test_live_fail_resolves_every_future():
    """Fail-stop mid-burst: no future ever hangs — each resolves served
    or with the fail-reason error payload."""
    cluster = live_cluster()
    cluster.start()
    try:
        x = np.zeros((16, 16, 3), "float32")
        futs = [cluster.submit("api", x) for _ in range(16)]
        cluster.fail("n0", reason="pulled the plug")
        outs = [f.get(timeout=30) for f in futs]    # nothing hangs
        errored = [o for o in outs if o.get("cancelled")]
        for o in errored:
            assert o["error"] in ("pulled the plug", "server stopped")
        assert cluster.nodes["n0"].state == DEAD
        # the class survives on n1
        out = cluster.submit("api", x).get(timeout=30)
        assert not out.get("cancelled")
    finally:
        cluster.stop()


def test_kill_payloads_marked_failed():
    """Fail-stop resolutions carry failed=True so live accounting can
    split node failures from ordinary cancels (stop/drain/shed)."""
    server = tiny_server()
    x = np.zeros((16, 16, 3), "float32")
    futs = [server.submit(x) for _ in range(3)]   # queued, never started
    server.kill("node failed")
    for f in futs:
        out = f.get(timeout=5)
        assert out["cancelled"] and out["failed"]
        assert out["error"] == "node failed"
    other = tiny_server()
    fut = other.submit(x)
    other.stop()                                  # ordinary stop: no failure
    out = fut.get(timeout=5)
    assert out["cancelled"] and not out["failed"]


def test_live_fail_last_node_errors_new_submits():
    cluster = live_cluster(n=1)
    cluster.start()
    try:
        cluster.fail("n0")
        out = cluster.submit("api", np.zeros((16, 16, 3), "float32")
                             ).get(timeout=5)
        # the last node died and re-admission found nowhere to go: the
        # payload says `no placement` explicitly (PR-6 satellite), and
        # summary() reports the class instead of silently retrying
        assert out["cancelled"] and "no placement" in out["error"]
        assert "api" in cluster.summary()["unplaceable"]
    finally:
        cluster.stop()


# --- satellite: record/replay of live traces ---------------------------------

def test_save_load_multi_stream_roundtrip(tmp_path):
    path = str(tmp_path / "multi.json")
    streams = {"a": [0.1, 0.25, 0.9], "b": [0.2]}
    save_schedule(path, streams, meta={"kind": "test"})
    back = load_schedule(path)
    assert set(back) == {"a", "b"}
    assert np.array_equal(back["a"], np.asarray(streams["a"]))
    from repro.traffic import replay
    with pytest.raises(ValueError):
        replay(path)                             # must pick one stream


def test_drive_live_records_replayable_trace(tmp_path):
    """drive_live(record_path=) writes the ACTUAL arrivals; feeding them
    back into simulate is bit-identical run-to-run (acceptance)."""
    from repro.traffic import drive_live
    path = str(tmp_path / "rec.json")
    server = tiny_server()
    arb = ResourceArbiter(interval_s=0.05)
    cls = SLOClass("api", deadline_ms=500.0, priority=1)
    arb.register("api", live_lut(), cls.service_target_ms, priority=1,
                 server=server)
    x = np.zeros((16, 16, 3), "float32")
    rep = drive_live([cls], {"api": server}, arb,
                     {"api": poisson(40.0, 0.5, seed=0)},
                     lambda name: x,
                     g_fn=lambda: GlobalConstraints(total_chips=2),
                     record_path=path)
    rec = load_schedule(path)
    assert rep.classes["api"].submitted == len(rec["api"]) > 0
    # recorded arrivals differ from the planned schedule (real clock)
    # but replay through the simulator exactly reproduces itself
    lut = make_lut()
    g_fn = lambda t: GlobalConstraints(total_chips=256)
    cls2 = SLOClass("api", deadline_ms=60.0, priority=1)
    a = simulate([cls2], {"api": lut}, {"api": rec["api"]}, g_fn).summary()
    b = simulate([cls2], {"api": lut}, {"api": rec["api"]}, g_fn).summary()
    assert a == b
    # and a second load is bit-identical (JSON floats round-trip exactly)
    again = load_schedule(path)
    assert np.array_equal(again["api"], rec["api"])


# --- satellite: adaptive batching window -------------------------------------

def test_adaptive_window_shrinks_with_arrival_rate():
    """The collector window tracks the expected inter-arrival time: it
    shrinks as the arbiter-reported EWMA rises and recovers when traffic
    goes sparse."""
    server = tiny_server()
    server.adaptive_window = True
    base = server.timeout_s
    assert server.effective_timeout_s() == base   # no signal yet
    windows = []
    for rate in (10.0, 500.0, 2000.0, 20000.0):
        server.note_arrival_rate(rate)
        windows.append(server.effective_timeout_s())
    assert windows[0] == base                     # sparse: full window
    assert windows[1] == pytest.approx(1 / 500.0)
    assert all(a >= b for a, b in zip(windows, windows[1:]))
    assert windows[-1] == server.min_window_s     # floored, never zero
    server.note_arrival_rate(0.0)
    assert server.effective_timeout_s() == base   # sparse again: recovers


def test_adaptive_window_off_by_default():
    server = tiny_server()
    server.note_arrival_rate(1e6)
    assert server.effective_timeout_s() == server.timeout_s


def test_arbiter_pushes_ewma_into_server():
    """tick() refreshes the workload EWMA from real submits and pushes it
    to the server, sizing the live window."""
    server = tiny_server()
    server.adaptive_window = True
    arb = ResourceArbiter(interval_s=0.05)
    arb.register("api", live_lut(), target_latency_ms=500.0, server=server)
    x = np.zeros((16, 16, 3), "float32")
    futs = [server.submit(x) for _ in range(64)]
    arb.tick(GlobalConstraints(total_chips=2))
    assert server._arrival_rate_rps > 0
    assert server.effective_timeout_s() < server.timeout_s
    server.start()
    try:
        for f in futs:
            f.get(timeout=60)
    finally:
        server.stop()


# --- satellite: unregister clears stats (bugfix) -----------------------------

def test_unregister_clears_stats_row():
    """Re-registering a tenant under the same name must start fresh
    accounting — the old bug leaked cycles/meet-rate/energy into the new
    tenant's summary (breaks cluster tenant migration, which re-registers
    by name)."""
    arb = ResourceArbiter()
    g = GlobalConstraints(total_chips=256)
    arb.register("t", make_lut(), target_latency_ms=40.0)
    for _ in range(5):
        arb.tick(g)
    assert arb.summary()["t"]["cycles"] == 5
    arb.unregister("t")
    arb.register("t", make_lut(), target_latency_ms=40.0)
    assert arb.summary()["t"].get("cycles", 0) == 0   # fresh row
    arb.tick(g)
    assert arb.summary()["t"]["cycles"] == 1          # not 6


def test_export_tenant_keeps_server_and_clears_stats():
    server = tiny_server()
    arb = ResourceArbiter()
    arb.register("t", live_lut(), target_latency_ms=500.0, server=server)
    arb.tick(GlobalConstraints(total_chips=2))
    w = arb.export_tenant("t")
    assert w.name == "t" and w.server is server
    assert "t" not in arb.tenants()
    assert "t" not in arb.summary()
    # unlike unregister, the server was NOT stopped (migration keeps it)
    assert not server._stop.is_set()


# --- stall-based health checking (PR 5 tentpole) -----------------------------

def test_wedged_node_auto_failed_over_in_sim():
    """A node wedged mid-trace (completions stalled, backlog non-zero)
    is auto-detected and failed over within K health epochs with zero
    lost futures — operator fail_at scripting not required."""
    rep = _sim([64, 64], router=ROUND_ROBIN, wedge_at={"n1": 2.0},
               health_epochs=3)
    assert rep.health_failed, rep.summary()
    t_fail, nn = rep.health_failed[0]
    assert nn == "n1"
    # flagged within K+1 epochs of the wedge landing (0.1 s epochs)
    assert t_fail <= 2.0 + 0.1 * (3 + 1) + 1e-9
    assert rep.nodes["n1"]["state"] == DEAD
    s = rep.classes["api"]
    # zero lost futures: every request ends in exactly one bucket
    assert s.submitted == s.rejected + s.dropped + s.failed + s.completed
    assert s.failed > 0        # the wedged backlog resolved as failed
    # after auto-failover the survivor carries the traffic
    assert rep.routed["api"]["n0"] > rep.routed["api"]["n1"]


def test_wedged_sim_deterministic():
    a = _sim([64, 64], router=ROUND_ROBIN, wedge_at={"n1": 2.0},
             health_epochs=3)
    b = _sim([64, 64], router=ROUND_ROBIN, wedge_at={"n1": 2.0},
             health_epochs=3)
    assert a.decisions == b.decisions
    assert a.summary() == b.summary()


def test_healthy_overloaded_node_is_not_false_positived():
    """Heavy backlog on a node that IS completing must not trip the
    stall detector."""
    rep = _sim([64], health_epochs=3)
    assert not rep.health_failed
    assert rep.nodes["n0"]["state"] != DEAD


def test_stall_detector_resets_on_progress():
    from repro.cluster import StallDetector
    det = StallDetector(epochs=2)
    assert not det.observe(0, 5)       # baseline
    assert not det.observe(0, 5)       # stalled x1
    assert not det.observe(3, 5)       # progress: streak resets
    assert not det.observe(3, 0)       # flat but NO backlog: not a stall
    assert not det.observe(3, 4)       # stalled x1
    assert det.observe(3, 4)           # stalled x2 -> wedged


def test_live_health_check_auto_fails_wedged_node():
    """Live cluster: a node whose worker hangs (completions flat,
    futures outstanding) is failed over by the health thread — every
    stuck future resolves with a failed payload and the survivor keeps
    serving."""
    import time as _time
    nodes = [ClusterNode(name=f"n{i}",
                         g_fn=lambda t: GlobalConstraints(total_chips=2))
             for i in range(2)]
    cluster = Cluster(nodes, router=P2C, health_interval_s=0.05,
                      health_epochs=3)
    cluster.register("api", live_lut(), target_latency_ms=500.0,
                     priority=1, make_server=tiny_server)
    # warm every replica: a cold compile stalls completions longer than
    # K x health_interval and would (correctly!) look like a wedge —
    # the operator contract is that K x interval exceeds the worst-case
    # batch time, which for a warmed server is milliseconds
    x = np.zeros((16, 16, 3), "float32")
    from repro.core.types import SubnetSpec
    for nd in nodes:
        nd.servers["api"].warm([SubnetSpec()], example_input=x)
    cluster.start()
    try:
        out = cluster.submit("api", x).get(timeout=30)
        assert not out.get("cancelled")
        # wedge n0: park its worker and defeat the arbiter's resume —
        # the hung-worker failure mode fail-stop scripting can't see
        n0 = cluster.nodes["n0"]
        srv = n0.servers["api"]
        srv.resume = lambda: None
        srv.pause()
        futs = [srv.submit(x) for _ in range(4)]
        deadline = _time.time() + 15.0
        while n0.state != DEAD and _time.time() < deadline:
            _time.sleep(0.02)
        assert n0.state == DEAD, "health check never failed the node"
        assert "n0" in cluster.health_log
        outs = [f.get(timeout=10) for f in futs]      # zero lost futures
        assert all(o.get("cancelled") and o.get("failed") for o in outs)
        assert "wedged" in outs[0]["error"]
        # the survivor still serves the class
        out = cluster.submit("api", x).get(timeout=30)
        assert not out.get("cancelled")
        assert cluster.placements["api"] == ["n1"]
    finally:
        cluster.stop()


def test_starved_node_not_flagged_wedged():
    """A node whose arbiter parked EVERY tenant (no point fits the
    machine) shows the wedge signature — flat completions, futures
    outstanding — but it is deliberate starvation and must not trip the
    health check; it recovers when conditions improve."""
    server = tiny_server()
    node = ClusterNode(name="n0",
                       g_fn=lambda t: GlobalConstraints(total_chips=2))
    node.servers["api"] = server
    # make_lut()'s smallest point needs 16 chips: nothing fits 2 chips
    node.arbiter.register("api", make_lut(), target_latency_ms=40.0,
                          server=server)
    x = np.zeros((16, 16, 3), "float32")
    futs = [server.submit(x) for _ in range(3)]
    node.arbiter.tick(node.g(0.0))
    assert node.arbiter.last_alloc["api"].point is None
    assert node.starved()
    assert node.outstanding() > 0
    for _ in range(6):                   # > health_epochs flat epochs
        assert not node.check_health()   # starved, not wedged
    server.stop()
    for f in futs:
        assert f.get(timeout=5)["cancelled"]
