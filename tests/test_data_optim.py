"""Data pipeline determinism + optimizer behaviour + grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (Prefetcher, memmap_token_batches,
                        synthetic_image_batches, synthetic_lm_batches)
from repro.optim import clip_by_global_norm, make_optimizer
from repro.optim.compress import init_errors, tree_compress


def test_lm_batches_deterministic_skip_ahead():
    it1 = synthetic_lm_batches(global_batch=4, seq_len=8, vocab=100)
    batches = [next(it1) for _ in range(5)]
    it2 = synthetic_lm_batches(global_batch=4, seq_len=8, vocab=100,
                               start_step=3)
    np.testing.assert_array_equal(batches[3]["tokens"], next(it2)["tokens"])


def test_image_batches_learnable_structure():
    it = synthetic_image_batches(global_batch=32, img_res=16, n_classes=4)
    b = next(it)
    # class-conditional quadrants differ in mean
    m0 = b["images"][b["labels"] == 0].mean()
    assert b["images"].shape == (32, 16, 16, 3)
    assert np.isfinite(m0)


def test_memmap_reader(tmp_path):
    data = np.arange(4 * 2 * 9, dtype=np.int32)
    path = tmp_path / "toks.bin"
    data.tofile(path)
    it = memmap_token_batches(str(path), global_batch=2, seq_len=8)
    b = next(it)
    assert b["tokens"].shape == (2, 8)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_prefetcher_propagates_errors():
    def bad():
        yield {"x": 1}
        raise ValueError("stream died")
    it = Prefetcher(bad())
    assert next(it)["x"] == 1
    with pytest.raises(ValueError):
        next(it)


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgdm"])
def test_optimizers_descend_quadratic(name):
    init_fn, update_fn = make_optimizer(
        name, **({"lr": 0.1} if name != "sgdm" else {"lr": 0.05,
                                                     "weight_decay": 0.0}))
    params = {"w": jnp.asarray([3.0, -2.0, 1.5]),
              "kernel": jnp.full((4, 4), 2.0)}
    state = init_fn(params)
    loss = lambda p: (jnp.sum(p["w"] ** 2) + jnp.sum(p["kernel"] ** 2))
    l0 = float(loss(params))
    for step in range(50):
        grads = jax.grad(loss)(params)
        params, state = update_fn(params, grads, state,
                                  jnp.asarray(step))
    assert float(loss(params)) < 0.25 * l0


def test_adafactor_factored_state_shapes():
    init_fn, _ = make_optimizer("adafactor")
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((8, 8))}
    st = init_fn(params)
    assert st["s"]["big"]["vr"].shape == (256,)
    assert st["s"]["big"]["vc"].shape == (512,)
    assert st["s"]["small"]["v"].shape == (8, 8)


def test_weight_decay_mask():
    from repro.optim.api import _wd_ok
    assert _wd_ok("layers/attn/q/kernel")
    assert not _wd_ok("layers/ln1/scale")
    assert not _wd_ok("layers/mlp/wi/bias")
    assert not _wd_ok("bn_stem/mean")


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert float(gn) > 1.0
    norm = float(jnp.linalg.norm(clipped["a"]))
    assert abs(norm - 1.0) < 1e-4


def test_grad_compression_error_feedback_converges():
    """With error feedback, compressed SGD still reaches the optimum."""
    w = jnp.asarray([5.0, -3.0, 2.0, -1.0])
    errors = init_errors({"w": w})
    lr = 0.1
    for _ in range(200):
        g = {"w": 2 * w}
        gq, errors = tree_compress(g, errors)
        w = w - lr * gq["w"]
    assert float(jnp.max(jnp.abs(w))) < 1e-2


def test_compression_quantisation_bound():
    from repro.optim.compress import dequantize_int8, quantize_int8
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = quantize_int8(g)
    err = jnp.abs(dequantize_int8(q, s) - g)
    assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-6
