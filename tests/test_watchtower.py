"""PR 9: SLO watchtower (`repro.obs.health` + streaming + profiling).

* **burn window math** — burn = bad_fraction / (1 - objective) over the
  exact ``(t - window, t]`` slice of the cumulative series, with the
  min-traffic guard and the sub-interval fallback;
* **multi-window gating** — an alert needs BOTH the short and the long
  window over threshold, fires on the rising edge only, and holds
  (hysteresis) so one good sample cannot flap the actuation it drove;
* **attribution** — for every chaos kind the regressed component and
  the top-ranked cause name the injected fault, chaos outranks the
  control plane's own reaction, and long-expired transients are not
  suspects;
* **exemplars** — alert exemplars come from histogram buckets and
  resolve to RETAINED traces only;
* **parity + determinism** — the same watchtower fed by the virtual
  cluster sim and the wall-clock live driver fires the same
  (class, window, severity) alerts; the sim day is bit-identical on
  replay;
* **actuation plumbing** — arbiter demand boost under alert pressure
  and the cluster frontend fan-out;
* **span links** — preempted/migrated work links back to its first
  attempt's retained (truncated) tree, in the sim and through
  ``abort_request(retain=True)``, and the links survive Perfetto
  export and live streaming;
* **Prometheus escaping** — hostile label values round-trip.
"""
import json

import numpy as np
import pytest

from repro.core.types import ElasticSpace
from repro.obs import (FAST, PAGE, SLOW, BurnWindow, MetricsRegistry,
                       TraceStreamer, Tracer, Watchtower, default_windows,
                       format_alerts, iter_trace_events, to_chrome_trace)
from repro.obs import trace as obs
from repro.obs.health import EXPECTED_COMPONENT
from repro.runtime import GlobalConstraints, model_lut
from repro.runtime import hwmodel as hm
from repro.traffic import DEGRADE, SHED, SLOClass, poisson

TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008,
                         t_collective=0.004)
SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))


def make_lut(full_chips=256):
    return model_lut(SPACE.enumerate(), full_terms=TERMS,
                     full_chips=full_chips)


def vt():
    return Tracer(clock=lambda: 0.0)


# --- burn window math --------------------------------------------------------

def test_burn_is_bad_fraction_over_budget():
    wt = Watchtower({"api": 0.99}, min_total=1)     # budget = 0.01
    for i in range(1, 11):
        wt.observe(float(i), "api", good=90, bad=10)
    # any window covering whole samples sees bad_frac 0.1 -> burn 10x
    assert wt.burn("api", 10.0, 5.0) == pytest.approx(10.0)
    assert wt.burn("api", 10.0, 100.0) == pytest.approx(10.0)
    # a clean stretch dilutes the windowed burn
    for i in range(11, 21):
        wt.observe(float(i), "api", good=100, bad=0)
    assert wt.burn("api", 20.0, 5.0) == 0.0
    assert wt.burn("api", 20.0, 20.0) == pytest.approx(5.0)  # half clean
    # budget_remaining uses the slowest long window
    assert 0.0 <= wt.budget_remaining("api", 20.0) <= 1.0
    # unknown class never burns
    assert wt.burn("ghost", 20.0, 5.0) == 0.0


def test_burn_window_slice_is_exact():
    wt = Watchtower({"api": 0.9}, min_total=1)      # budget = 0.1
    wt.observe(1.0, "api", good=10, bad=0)
    wt.observe(2.0, "api", good=0, bad=10)
    wt.observe(3.0, "api", good=10, bad=0)
    # (2, 3]: only the good sample at t=3 -> no burn
    assert wt.burn("api", 3.0, 1.0) == 0.0
    # (1, 3]: 10 bad of 20 -> 0.5 / 0.1 = 5x
    assert wt.burn("api", 3.0, 2.0) == pytest.approx(5.0)
    # sub-interval window falls back to the latest sample delta
    assert wt.burn("api", 2.0, 0.01) == pytest.approx(10.0)


def test_min_total_guard_squelches_cold_start():
    wt = Watchtower({"api": 0.999})                 # default min_total=8
    wt.observe(0.1, "api", good=0, bad=2)
    # 2/2 bad at cold start is NOT an 800x burn — below min traffic
    assert wt.burn("api", 0.1, 1.0) == 0.0
    assert wt.evaluate(0.1) == []
    wt.observe(0.2, "api", good=0, bad=6)           # now 8 samples
    assert wt.burn("api", 0.2, 1.0) > 100.0


def test_observe_rejects_out_of_order_samples():
    wt = Watchtower({"api": 0.99})
    wt.observe(2.0, "api", good=1)
    with pytest.raises(ValueError):
        wt.observe(1.0, "api", good=1)


# --- multi-window gating + hysteresis ----------------------------------------

def burny():
    """One fast-style window: short 2s / long 10s, 5x threshold."""
    return Watchtower({"api": 0.9}, min_total=1, windows=(
        BurnWindow(FAST, 2.0, 10.0, 5.0, PAGE),))


def test_alert_needs_both_windows_over_threshold():
    wt = burny()
    # long history of good traffic, then a short burst of bad: the
    # short window burns 10x but the long window stays diluted
    for i in range(1, 10):
        wt.observe(float(i), "api", good=100, bad=0)
    wt.observe(10.0, "api", good=0, bad=100)
    bs = wt.burn("api", 10.0, 2.0)
    bl = wt.burn("api", 10.0, 10.0)
    assert bs >= 5.0 > bl
    assert wt.evaluate(10.0) == [] and not wt.active("api")
    # keep burning: the long window catches up -> rising edge fires once
    fired = []
    for i in range(11, 20):
        wt.observe(float(i), "api", good=0, bad=100)
        fired += wt.evaluate(float(i))
    assert len(fired) == 1
    a = fired[0]
    assert (a.cls, a.window, a.severity) == ("api", FAST, PAGE)
    assert a.burn_short >= 5.0 and a.burn_long >= 5.0
    assert wt.active("api")
    assert wt.pressure("api") > 0.0
    assert "PAGE" in format_alerts([a])


def test_alert_hold_hysteresis_prevents_flapping():
    wt = burny()                                    # hold = short_s = 2.0
    for i in range(1, 12):
        wt.observe(float(i), "api", good=0, bad=100)
        wt.evaluate(float(i))
    assert wt.active("api")
    # condition clears, but the alert HOLDS for short_s: the actuation
    # it triggered is not withdrawn by one good sample
    wt.observe(12.0, "api", good=1000, bad=0)
    wt.evaluate(12.0)
    assert wt.active("api")
    assert wt.pressure("api") < 1.0     # burn itself already subsided
    # ... and clears once the condition has been false for the hold
    for i in range(13, 17):
        wt.observe(float(i), "api", good=1000, bad=0)
        wt.evaluate(float(i))
    assert not wt.active("api")
    # hold_s=0 disables the hysteresis entirely
    wt2 = Watchtower({"api": 0.9}, min_total=1, hold_s=0.0, windows=(
        BurnWindow(FAST, 2.0, 10.0, 5.0, PAGE),))
    for i in range(1, 12):
        wt2.observe(float(i), "api", good=0, bad=100)
        wt2.evaluate(float(i))
    assert wt2.active("api")
    wt2.observe(12.0, "api", good=10000, bad=0)
    wt2.evaluate(12.0)
    assert not wt2.active("api")
    # time_in_slo counted the unhealthy ticks
    assert wt2.time_in_slo("api") < 1.0


def test_default_windows_scale_to_virtual_day():
    ws = default_windows(10.0 / 86400.0)            # 10s virtual day
    fast = next(w for w in ws if w.name == FAST)
    slow = next(w for w in ws if w.name == SLOW)
    assert fast.short_s == pytest.approx(300.0 * 10.0 / 86400.0)
    assert slow.long_s == pytest.approx(259200.0 * 10.0 / 86400.0)
    assert fast.burn == 14.4 and slow.burn == 1.0


# --- attribution -------------------------------------------------------------

def feed_component_regression(tr, cls, component, t_bad=10.0):
    """Baseline traces (small queue+device), then a window where one
    component inflates 10x."""
    for i in range(20):
        t0 = 0.1 * i
        tr.request(cls, t0, t0 + 0.002, spans=[
            (obs.QUEUE, t0, t0 + 0.001, None),
            (obs.DEVICE, t0 + 0.001, t0 + 0.002,
             {"bucket": 1, "subnet": "s", "n": 1})])
    for i in range(10):
        t0 = t_bad + 0.1 * i
        q_ms, d_ms = (0.050, 0.001) if component == "queue" \
            else (0.001, 0.050)
        tr.request(cls, t0, t0 + q_ms + d_ms, spans=[
            (obs.QUEUE, t0, t0 + q_ms, None),
            (obs.DEVICE, t0 + q_ms, t0 + q_ms + d_ms,
             {"bucket": 1, "subnet": "s", "n": 1})])


@pytest.mark.parametrize("kind", sorted(EXPECTED_COMPONENT))
def test_attribution_names_injected_cause_per_kind(kind):
    tr = vt()
    comp = EXPECTED_COMPONENT[kind]
    feed_component_regression(tr, "api", comp)
    wt = Watchtower({"api": 0.999}, tracer=tr, min_total=1)
    wt.note_injection(10.0, kind, node="n0", duration_s=5.0)
    attr = wt.attribute(11.0, "api", window_s=2.0)
    assert attr.component == comp
    assert attr.cause == f"chaos:{kind}"
    assert attr.delta_ms > 10.0 and attr.baseline_ms < 5.0


def test_attribution_chaos_outranks_decision_reaction():
    tr = vt()
    feed_component_regression(tr, "api", "queue")
    # the control plane REACTED inside the window too: a scale decision
    # whose expected component also matches
    tr.decision(obs.SCALE, 10.5, 10.5, direction="up")
    wt = Watchtower({"api": 0.999}, tracer=tr, min_total=1)
    wt.note_injection(10.0, "rack_fail", node="r0", duration_s=0.0)
    attr = wt.attribute(11.0, "api", window_s=2.0)
    labels = [c.label for c in attr.causes]
    assert labels[0] == "chaos:rack_fail"
    assert "decision:scale" in labels
    assert labels.index("chaos:rack_fail") < labels.index("decision:scale")


def test_attribution_expired_transient_is_not_a_suspect():
    tr = vt()
    feed_component_regression(tr, "api", "device")
    wt = Watchtower({"api": 0.999}, tracer=tr, min_total=1)
    # thermal throttle that ended LONG before the firing window
    wt.note_injection(0.5, "thermal", node="n0", duration_s=1.0)
    attr = wt.attribute(11.0, "api", window_s=2.0)
    assert all(c.label != "chaos:thermal" for c in attr.causes)
    # a fail-stop never expires on its own: still a suspect hours later
    wt.note_injection(0.5, "fail_stop", node="n0", duration_s=0.0)
    attr = wt.attribute(11.0, "api", window_s=2.0)
    assert any(c.label == "chaos:fail_stop" for c in attr.causes)


# --- exemplars ---------------------------------------------------------------

def test_exemplars_come_from_histogram_and_resolve_to_retained():
    tr = vt()
    rids = []
    for i in range(10):
        rids.append(tr.request("api", 0.1 * i, 0.1 * i + 0.01, spans=[
            (obs.QUEUE, 0.1 * i, 0.1 * i, None),
            (obs.DEVICE, 0.1 * i, 0.1 * i + 0.01,
             {"bucket": 1, "subnet": "s", "n": 1})]))
    m = MetricsRegistry()
    h = m.histogram("cluster_request_ms", buckets=(1.0, 100.0), cls="api")
    h.observe(0.5, exemplar=rids[0])
    h.observe(50.0, exemplar=rids[1])
    h.observe(500.0, exemplar=999999)      # stale id: evicted trace
    wt = Watchtower({"api": 0.9}, min_total=1, tracer=tr, registry=m,
                    windows=(BurnWindow(FAST, 2.0, 10.0, 1.0, PAGE),))
    for i in range(1, 12):
        wt.observe(float(i), "api", good=0, bad=10)
        fired = wt.evaluate(float(i))
        if fired:
            break
    assert fired
    ex = fired[0].exemplars
    assert ex, "alert carried no exemplars"
    retained = {t.trace_id for t in tr.requests()}
    assert set(ex) <= retained             # every link resolves
    assert 999999 not in ex                # the stale one was filtered
    # slowest buckets first: the 50ms exemplar outranks the 0.5ms one
    assert ex.index(rids[1]) < ex.index(rids[0])


# --- sim + live parity, determinism ------------------------------------------

def throttle_sim(actuate, horizon_s=7.0):
    from repro.chaos import THERMAL, Injection, Scenario
    from repro.cluster import P2C, ClusterNode, simulate_cluster
    from repro.cluster.node import STANDBY
    nodes = [ClusterNode(name=f"n{i}",
                         g_fn=lambda t: GlobalConstraints(total_chips=16),
                         state=(STANDBY if i >= 2 else "up"))
             for i in range(4)]
    classes = [SLOClass("rt", deadline_ms=600.0, priority=3,
                        drop_policy=SHED, degrade_factor=1.5),
               SLOClass("batch", deadline_ms=2500.0, priority=1,
                        drop_policy=DEGRADE)]
    tracer = vt()
    wt = Watchtower({"rt": 0.999, "batch": 0.99},
                    time_scale=horizon_s / 86400.0, tracer=tracer,
                    actuate=actuate, rebalance_on_alert=actuate)
    chaos = Scenario(name="hot", seed=0, injections=(
        Injection(t=2.0, kind=THERMAL, node="n0",
                  duration_s=horizon_s - 3.0, ladder=(0.2, 0.12, 0.08)),
        Injection(t=2.0, kind=THERMAL, node="n1",
                  duration_s=horizon_s - 3.0, ladder=(0.2, 0.12, 0.08))))
    lut = make_lut()
    rep = simulate_cluster(
        classes, {"rt": lut, "batch": lut},
        {"rt": poisson(200.0, horizon_s, seed=7),
         "batch": poisson(100.0, horizon_s, seed=8)},
        nodes, router=P2C, chaos=chaos, tracer=tracer, watchtower=wt,
        scale_at=(0.8 * horizon_s,), min_nodes=2)
    return rep, wt


def alert_sig(alerts):
    return [(round(a.t, 6), a.cls, a.window, a.severity,
             round(a.burn_short, 9), a.attribution.cause
             if a.attribution else None) for a in alerts]


def test_sim_alerts_are_deterministic_and_attributed():
    rep1, wt1 = throttle_sim(actuate=True)
    rep2, wt2 = throttle_sim(actuate=True)
    assert rep1.alerts, "throttle day fired no alerts"
    assert alert_sig(rep1.alerts) == alert_sig(rep2.alerts)
    assert rep1.summary() == rep2.summary()
    # the injected fault is named for >=80% of fired alerts (the PR's
    # acceptance floor — a cold-start blip may page before any fault
    # exists to blame), and every exemplar resolves to a retained trace
    retained = {t.trace_id for t in rep1.tracer.requests()}
    named = sum(1 for a in rep1.alerts if a.attribution is not None
                and a.attribution.cause == "chaos:thermal")
    assert named / len(rep1.alerts) >= 0.8
    for a in rep1.alerts:
        assert set(a.exemplars) <= retained
    # report carries the watchtower's view
    assert [row[1:] for row in rep1.summary()["alerts"]] == [
        [a.cls, a.window, a.severity] for a in rep1.alerts]


def test_actuating_watchtower_degrades_and_scales_early():
    rep, wt = throttle_sim(actuate=True)
    # alert-driven brownout entered (the arbiter target was relaxed)
    assert any(k == "enter" for _, _, k in rep.brownouts)
    # the rising edge moved the autoscaler's clock: standby capacity
    # came up BEFORE the scheduled scale_at instant (0.8 * horizon)
    t_up = min((t for t, d, _ in rep.scale_events if d == "up"),
               default=float("inf"))
    assert t_up < 0.8 * 7.0
    assert wt.time_in_slo("rt") < 1.0     # the day really paged


def tiny_server(**kw):
    import jax
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2,
                    d_model=32, n_heads=4, d_ff=64, n_classes=4,
                    compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    return DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                         params, dims, **kw)


def test_live_driver_fires_same_alert_as_sim():
    """Parity: a class whose every completion is late (impossible
    deadline) fires the same (class, window, severity) alert through
    the wall-clock driver as through the virtual-time simulator."""
    from repro.runtime import ResourceArbiter
    from repro.traffic import drive_live, simulate
    windows = (BurnWindow(FAST, 0.5, 1.0, 1.0, PAGE),)
    cls = SLOClass("api", deadline_ms=1e-3, priority=1,
                   drop_policy=DEGRADE)
    streams = {"api": list(poisson(150.0, 1.5, seed=3))}

    server = tiny_server(max_batch=8, timeout_ms=2.0)
    arb = ResourceArbiter(interval_s=0.05)
    arb.register("api", make_lut(2), cls.service_target_ms, priority=1,
                 server=server)
    wt_live = Watchtower({"api": 0.99}, windows=windows)
    live = drive_live([cls], {"api": server}, arb, streams,
                      lambda n: np.zeros((16, 16, 3), "float32"),
                      g_fn=lambda: GlobalConstraints(total_chips=2),
                      watchtower=wt_live)
    assert live.classes["api"].completed > 0

    wt_sim = Watchtower({"api": 0.99}, windows=windows)
    tr = vt()
    rep = simulate([cls], {"api": make_lut()}, streams,
                   lambda t: GlobalConstraints(total_chips=256),
                   tracer=tr)
    wt_sim.ingest(rep, t=1.5)

    sig_live = {(a.cls, a.window, a.severity) for a in wt_live.alerts}
    sig_sim = {(a.cls, a.window, a.severity) for a in wt_sim.alerts}
    assert sig_live == sig_sim == {("api", FAST, PAGE)}


# --- actuation plumbing ------------------------------------------------------

def test_arbiter_alert_pressure_boosts_demand():
    from repro.runtime import ResourceArbiter
    arb = ResourceArbiter()
    arb.register("hot", make_lut(), target_latency_ms=20.0, priority=1)
    arb.register("cold", make_lut(), target_latency_ms=20.0, priority=1)
    g = GlobalConstraints(total_chips=64)
    base = arb.tick(g)["hot"].chips
    arb.set_alert_pressure("hot", 3.0)
    assert arb.metrics.value("arbiter_alert_pressure",
                             tenant="hot") == 3.0
    boosted = arb.tick(g)
    assert boosted["hot"].chips >= base
    assert boosted["hot"].chips >= boosted["cold"].chips
    assert "alert_pressure" in arb.summary()["hot"]
    # clears back to neutral (and clamps negatives)
    arb.set_alert_pressure("hot", -1.0)
    assert arb.metrics.value("arbiter_alert_pressure",
                             tenant="hot") == 0.0


def test_cluster_frontend_fans_out_alert_pressure():
    from repro.cluster import Cluster, ClusterNode, P2C
    nodes = [ClusterNode(name=f"n{i}",
                         g_fn=lambda t: GlobalConstraints(total_chips=2))
             for i in range(2)]
    cluster = Cluster(nodes, router=P2C)
    placed = cluster.register("api", make_lut(2), target_latency_ms=500.0,
                              priority=1)
    assert placed
    cluster.set_alert_pressure("api", 1.5)
    for nn in placed:
        node = cluster.nodes[nn]
        assert node.arbiter.metrics.value("arbiter_alert_pressure",
                                          tenant="api") == 1.5
    # unknown class is a no-op, not a crash
    cluster.set_alert_pressure("ghost", 1.0)


# --- span links across preemptions -------------------------------------------

def test_sim_migration_links_back_to_truncated_first_attempt():
    """A request whose queue was re-homed by a migration completes with
    a link to its first attempt's retained TRUNCATED tree."""
    from repro.cluster import (FIRST_FIT, LEAST_LOADED, ClusterNode,
                               simulate_cluster)
    # n1's capacity appears at t=0.5: first-fit lands the class on the
    # small n0, the rebalance moves it to n1 while n0's queue is deep —
    # that backlog is re-homed, which is the preemption link source
    nodes = [ClusterNode(name="n0",
                         g_fn=lambda t: GlobalConstraints(total_chips=8)),
             ClusterNode(name="n1",
                         g_fn=lambda t: GlobalConstraints(
                             total_chips=256 if t >= 0.5 else 2))]
    cls = SLOClass("api", deadline_ms=2000.0, priority=2,
                   drop_policy=DEGRADE)
    tr = vt()
    simulate_cluster(
        [cls], {"api": make_lut()},
        {"api": poisson(800.0, 2.0, seed=5)}, nodes,
        router=LEAST_LOADED, placement_mode=FIRST_FIT,
        rebalance_at=[1.0], replicas=1, hysteresis=0.05, tracer=tr)
    retained = {t.trace_id: t for t in tr.requests()}
    linked = [t for t in retained.values() if t.links]
    assert linked, "no migration re-homed queued work"
    for t2 in linked:
        for first in t2.links:
            assert first in retained, "link target was not retained"
            ft = retained[first]
            # the truncated first attempt: routed + queued, never served
            assert [s.name for s in ft.spans] == [obs.ROUTE, obs.QUEUE]
    # the links survive Perfetto export on the complete events
    doc = to_chrome_trace(tr)
    ev_links = {ev["args"]["links"][0] for ev in doc["traceEvents"]
                if ev["ph"] == "X" and "links" in ev.get("args", {})}
    assert ev_links and ev_links <= set(retained)


def test_abort_retain_keeps_resolvable_link_target():
    tr = vt()
    rid = tr.begin_request("api", t=0.0, node="n0")
    tr.add_span(rid, obs.QUEUE, 0.0, 0.5)
    tr.abort_request(rid, t=1.0, retain=True)
    kept = {t.trace_id: t for t in tr.requests()}
    assert rid in kept                       # retained despite the abort
    ft = kept[rid]
    assert ft.t1 == 1.0
    assert ft.spans[-1].attrs.get("aborted") is True
    assert tr.aborted == 1
    # the second attempt links back and exports with the link
    rid2 = tr.request("api", 1.0, 2.0, links=[rid], spans=[
        (obs.QUEUE, 1.0, 1.5, None),
        (obs.DEVICE, 1.5, 2.0, {"bucket": 1, "subnet": "s", "n": 1})])
    doc = to_chrome_trace(tr)
    linked = [ev for ev in doc["traceEvents"]
              if ev.get("args", {}).get("links") == [rid]]
    assert linked and all(ev["ph"] == "X" for ev in linked)
    assert rid2 in {t.trace_id for t in tr.requests()}
    # plain abort (no retain) stays invisible
    rid3 = tr.begin_request("api", t=3.0)
    tr.abort_request(rid3)
    assert rid3 not in {t.trace_id for t in tr.requests()}


# --- streaming export --------------------------------------------------------

def test_streamer_appends_as_requests_retire(tmp_path):
    path = str(tmp_path / "stream.json")
    tr = vt()
    streamer = TraceStreamer(path).attach(tr)
    rid1 = tr.request("api", 0.0, 0.1, spans=[
        (obs.QUEUE, 0.0, 0.05, None),
        (obs.DEVICE, 0.05, 0.1, {"bucket": 1, "subnet": "s", "n": 1})])
    mid_run = list(iter_trace_events(path))
    assert mid_run, "nothing streamed before close (not incremental)"
    tr.request("api", 0.1, 0.2, links=[rid1], spans=[
        (obs.DEVICE, 0.1, 0.2, {"bucket": 1, "subnet": "s", "n": 1})])
    tr.decision(obs.SCALE, 0.2, 0.2, direction="up")
    n = streamer.close(tr)
    assert tr.on_retire is None              # detached at close
    evs = list(iter_trace_events(path))
    assert len(evs) == n > len(mid_run)
    names = {ev["name"] for ev in evs if ev["ph"] == "X"}
    assert {"queue", "device", "scale"} <= names   # decisions flushed
    assert any(ev.get("args", {}).get("links") == [rid1] for ev in evs)
    # one-shot export of the same tracer names identical track metadata
    one_shot = to_chrome_trace(tr)
    assert ({json.dumps(e, sort_keys=True) for e in evs
             if e["ph"] == "M"}
            == {json.dumps(e, sort_keys=True)
                for e in one_shot["traceEvents"] if e["ph"] == "M"})


# --- Prometheus escaping -----------------------------------------------------

def test_prometheus_hostile_labels_roundtrip():
    m = MetricsRegistry()
    hostile = 'a\\b"c\nd'
    m.counter("served_total", tenant=hostile).inc(3)
    m.gauge("weird.name-2", node="n0").set(1.0)
    text = m.to_prometheus()
    # the exposition stays one-record-per-line (newline was escaped)
    line = next(ln for ln in text.splitlines()
                if ln.startswith("served_total{"))
    assert '\\\\' in line and '\\"' in line and "\\n" in line
    # round-trip: unescape the label value -> the original bytes
    start = line.index('tenant="') + len('tenant="')
    end = line.rindex('"')
    unescaped = (line[start:end].replace("\\n", "\n")
                 .replace('\\"', '"').replace("\\\\", "\\"))
    assert unescaped == hostile
    assert line.rstrip().endswith(" 3")
    # metric names are sanitized to the exposition charset
    assert "weird_name_2" in text and "weird.name-2" not in text
