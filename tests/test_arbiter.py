"""Multi-workload arbiter + the PR's bugfix regressions (throttled
fallback, thread-safe executable cache, mesh/hypothesis compat)."""
import threading

import numpy as np
import pytest

from repro.core.types import ElasticSpace, SubnetSpec
from repro.runtime import (Constraints, GlobalConstraints, JointGovernor,
                           ResourceArbiter, model_lut)
from repro.runtime import hwmodel as hm

TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008, t_collective=0.004)
SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))
HW_STATES = [hm.HwState(chips=c, freq=f) for c in (256, 128, 64, 32)
             for f in hm.FREQ_LADDER]


def make_lut(scale=1.0):
    terms = hm.RooflineTerms(TERMS.t_compute * scale, TERMS.t_memory * scale,
                             TERMS.t_collective * scale)
    return model_lut(SPACE.enumerate(), full_terms=terms, full_chips=256,
                     hw_states=HW_STATES)


# --- bugfix regressions -------------------------------------------------------

def test_infeasible_fallback_respects_throttle():
    """JointGovernor's degraded pick must not exceed the thermal cap."""
    lut = make_lut()
    gov = JointGovernor(lut)
    # impossible target => fallback path; throttle must still bind
    point = gov.select(Constraints(target_latency_ms=1e-6,
                                   chips_available=256,
                                   temperature_throttle=0.7))
    assert point.hw_state.freq <= 0.7
    capped = [p for p in lut.points if p.hw_state.chips <= 256
              and p.hw_state.freq <= 0.7]
    assert point.latency_ms == min(p.latency_ms for p in capped)


def test_infeasible_fallback_respects_power_grant():
    """The degraded pick must also stay inside an arbiter power grant."""
    lut = make_lut()
    gov = JointGovernor(lut)
    budget = 15000.0
    point = gov.select(Constraints(target_latency_ms=1e-6,
                                   chips_available=256,
                                   power_budget_w=budget))
    assert hm.slice_power_w(point.hw_state) <= budget


def test_lut_fastest_freq_cap_relaxed_only_when_empty():
    lut = make_lut()
    p = lut.fastest(256, max_freq=0.55)
    assert p.hw_state.freq <= 0.55
    # a cap below the whole ladder relaxes rather than erroring
    p = lut.fastest(256, max_freq=0.1)
    assert p is not None


def test_executable_cache_thread_safe():
    """Concurrent executable() calls (worker + sync callers + arbiter
    clock) must build each spec exactly once and never race."""
    import jax
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2, d_model=32,
                    n_heads=4, d_ff=64, n_classes=4, compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    server = DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                           params, dims)
    specs = [SubnetSpec(), SubnetSpec(width_mult=0.5),
             SubnetSpec(ffn_mult=0.5), SubnetSpec(depth_mult=0.5)]
    got = []
    errors = []

    def hammer():
        try:
            for _ in range(20):
                for s in specs:
                    got.append((s, id(server.executable(s))))
        except Exception as e:  # pragma: no cover - the regression
            errors.append(e)

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(server._cache) == len(specs)
    by_spec = {}
    for s, fid in got:
        by_spec.setdefault(s, set()).add(fid)
    assert all(len(ids) == 1 for ids in by_spec.values())


def test_mesh_compat_no_axis_type():
    """make_mesh works on JAX versions without jax.sharding.AxisType."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_hypothesis_importable_everywhere():
    """Real package or the conftest shim — @given must run the test body."""
    from hypothesis import given, strategies as st
    ran = []

    @given(x=st.integers(1, 5), y=st.sampled_from(["a", "b"]))
    def prop(x, y):
        ran.append((x, y))
        assert 1 <= x <= 5 and y in ("a", "b")

    prop()
    assert ran


# --- arbiter unit tests -------------------------------------------------------

def test_two_workloads_ample_budget_both_meet():
    arb = ResourceArbiter()
    arb.register("a", make_lut(), target_latency_ms=40.0, priority=1)
    arb.register("b", make_lut(0.5), target_latency_ms=25.0, priority=0)
    allocs = arb.arbitrate(GlobalConstraints(total_chips=512))
    assert all(a.feasible for a in allocs.values())
    assert all(a.point.latency_ms <= t for a, t in
               [(allocs["a"], 40.0), (allocs["b"], 25.0)])
    # never oversubscribes
    assert sum(a.chips for a in allocs.values()) <= 512


def test_shrinking_budget_degrades_by_priority():
    """As the pool shrinks, the low-priority workload loses its target
    first; the high-priority one keeps meeting it as long as possible."""
    arb = ResourceArbiter()
    arb.register("hi", make_lut(), target_latency_ms=40.0, priority=2)
    arb.register("lo", make_lut(), target_latency_ms=40.0, priority=0)
    prev_hi = True
    for total in (512, 256, 128, 64, 32):
        allocs = arb.arbitrate(GlobalConstraints(total_chips=total))
        hi, lo = allocs["hi"], allocs["lo"]
        assert sum(a.chips for a in allocs.values()) <= total
        # priority order: lo never feasible while hi is not
        assert hi.feasible or not lo.feasible
        # monotone: hi doesn't regain feasibility as the pool shrinks
        assert prev_hi or not hi.feasible
        prev_hi = hi.feasible
    # at 64 chips the high-priority workload still meets; low starves
    allocs = arb.arbitrate(GlobalConstraints(total_chips=64))
    assert allocs["hi"].feasible and not allocs["lo"].feasible


def test_surplus_buys_accuracy_for_high_priority():
    arb = ResourceArbiter()
    arb.register("hi", make_lut(), target_latency_ms=40.0, priority=2)
    arb.register("lo", make_lut(), target_latency_ms=40.0, priority=0)
    tight = arb.arbitrate(GlobalConstraints(total_chips=128))
    roomy = arb.arbitrate(GlobalConstraints(total_chips=512))
    assert roomy["hi"].point.accuracy >= tight["hi"].point.accuracy
    # with surplus, hi runs a higher-accuracy point than its minimal share
    assert roomy["hi"].chips >= tight["hi"].chips


def test_power_budget_and_throttle_respected():
    arb = ResourceArbiter()
    arb.register("a", make_lut(), target_latency_ms=60.0, priority=1)
    arb.register("b", make_lut(), target_latency_ms=60.0, priority=0)
    g = GlobalConstraints(total_chips=512, power_budget_w=40000.0,
                          temperature_throttle=0.7)
    allocs = arb.arbitrate(g)
    assert sum(a.power_w for a in allocs.values()) <= 40000.0
    for a in allocs.values():
        if a.point is not None:
            assert a.point.hw_state.freq <= 0.7


def test_backlogged_tenant_gets_surplus_first():
    """Queue-depth-aware water-filling (ROADMAP item): with equal
    priorities, the surplus goes to the backlogged tenant as SPEED — it
    ends up on a faster point (and at least as many chips) than its
    backlog-free peer, instead of everyone buying accuracy."""
    arb = ResourceArbiter()
    arb.register("a", make_lut(), target_latency_ms=40.0, priority=1)
    arb.register("b", make_lut(), target_latency_ms=40.0, priority=1)
    g = GlobalConstraints(total_chips=512)
    base = arb.arbitrate(g)
    assert base["a"].feasible and base["b"].feasible
    arb.set_active("a", True, queue_depth=64, arrival_rate_rps=200.0)
    arb.set_active("b", True, queue_depth=0)
    allocs = arb.arbitrate(g)
    assert allocs["a"].feasible and allocs["b"].feasible
    assert allocs["a"].chips >= allocs["b"].chips
    # the backlogged tenant runs strictly faster than the accuracy-first
    # pick it got when no backlog was reported
    assert allocs["a"].point.latency_ms < base["a"].point.latency_ms
    # never oversubscribes
    assert sum(x.chips for x in allocs.values()) <= 512


def test_backlog_ewma_smooths_arrival_rate():
    arb = ResourceArbiter()
    w = arb.register("a", make_lut(), target_latency_ms=40.0)
    arb.set_active("a", True, arrival_rate_rps=100.0)
    first = w.arrival_ewma
    assert 0.0 < first < 100.0              # smoothed, not raw
    arb.set_active("a", True, arrival_rate_rps=100.0)
    assert first < w.arrival_ewma < 100.0   # converging toward the rate


def test_server_queue_depth_feeds_arbiter():
    """A live tenant's backlog is read off its server automatically."""
    arb = ResourceArbiter()
    server = tiny_server()
    w = arb.register("a", make_lut(), target_latency_ms=40.0, server=server)
    x = np.zeros((16, 16, 3), "float32")
    futs = [server.submit(x) for _ in range(5)]   # queued: never started
    arb.arbitrate(GlobalConstraints(total_chips=256))
    assert w.queue_depth == 5
    server.stop()                                 # drains the futures
    for f in futs:
        assert f.get(timeout=5)["cancelled"]


def test_constraints_carry_priority_and_share():
    arb = ResourceArbiter()
    w = arb.register("a", make_lut(), target_latency_ms=40.0, priority=3)
    g = GlobalConstraints(total_chips=256)
    alloc = arb.arbitrate(g)["a"]
    c = arb.constraints_for(w, alloc, g)
    assert c.priority == 3
    assert c.share == pytest.approx(alloc.chips / 256)
    assert c.chips_available == alloc.chips


def test_duplicate_registration_rejected():
    arb = ResourceArbiter()
    arb.register("a", make_lut(), target_latency_ms=40.0)
    with pytest.raises(ValueError):
        arb.register("a", make_lut(), target_latency_ms=40.0)


def tiny_server():
    import jax
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2,
                    d_model=32, n_heads=4, d_ff=64, n_classes=4,
                    compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    return DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                         params, dims)


def test_tick_drives_governors_and_servers():
    """Multi-server mode: one tick arbitrates and switches each server's
    active sub-network via its own governor."""
    arb = ResourceArbiter()
    s1, s2 = tiny_server(), tiny_server()
    arb.register("hi", make_lut(), target_latency_ms=40.0, priority=2,
                 server=s1)
    arb.register("lo", make_lut(), target_latency_ms=40.0, priority=0,
                 server=s2)
    allocs = arb.tick(GlobalConstraints(total_chips=256))
    for name, server in (("hi", s1), ("lo", s2)):
        if allocs[name].point is not None:
            assert server.active_spec == allocs[name].point.subnet \
                or server.active_point is not None
    # servers answer correctly after the arbiter-driven switch
    x = np.zeros((2, 16, 16, 3), "float32")
    assert s1.infer(x).shape == (2, 4)
    assert s2.infer(x).shape == (2, 4)
    assert len(arb.alloc_log) == 1
    summ = arb.summary()
    assert summ["hi"]["cycles"] == 1
    # starvation parks the low-priority server; recovery resumes it
    allocs = arb.tick(GlobalConstraints(total_chips=64))
    assert not allocs["lo"].feasible
    assert s2._paused.is_set() and not s1._paused.is_set()
    arb.tick(GlobalConstraints(total_chips=256))
    assert not s2._paused.is_set()
    assert arb.summary()["hi"]["cycles"] == 3


def test_server_restart_clears_pause():
    """A server stopped while starved must not come back parked."""
    server = tiny_server()
    server.pause()
    server.start()
    try:
        x = np.zeros((16, 16, 3), "float32")
        fut = server.submit(x)
        assert fut.get(timeout=60)["y"].shape == (4,)
    finally:
        server.stop()


def test_late_registration_starts_server():
    """A workload registered after start() gets its server running."""
    arb = ResourceArbiter(interval_s=0.01)
    arb.register("first", make_lut(), target_latency_ms=40.0, priority=1)
    arb.start(lambda: GlobalConstraints(total_chips=256))
    try:
        s = tiny_server()
        arb.register("late", make_lut(), target_latency_ms=40.0,
                     server=s)
        assert s.is_running
        x = np.zeros((16, 16, 3), "float32")
        fut = s.submit(x)
        assert fut.get(timeout=60)["y"].shape == (4,)
    finally:
        arb.stop()
