"""Two-pass sharded decode attention == XLA decode path (multi-device)."""


def test_sharded_decode_matches_xla(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import layers as L
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
d_model, H, K, D = 32, 8, 4, 8
p = L.attention_init(key, d_model, H, K, D)
B, T = 16, 32
x = jax.random.normal(key, (B, 1, d_model))
cache = {"k": jax.random.normal(jax.random.fold_in(key, 1), (B, T, K, D)),
         "v": jax.random.normal(jax.random.fold_in(key, 2), (B, T, K, D)),
         "len": jnp.asarray(20)}
y_x, c_x = L.attention_apply(p, x, n_heads=H, n_kv=K, d_head=D,
                             kv_cache=dict(cache))
with mesh:
    y_s, c_s = L.attention_apply(p, x, n_heads=H, n_kv=K, d_head=D,
                                 kv_cache=dict(cache),
                                 decode_impl="sharded", mesh=mesh)
np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_s),
                           rtol=3e-3, atol=3e-3)
np.testing.assert_allclose(np.asarray(c_x["k"]), np.asarray(c_s["k"]),
                           rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(np.asarray(c_x["v"]), np.asarray(c_s["v"]),
                           rtol=1e-5, atol=1e-5)
print("OK")
""", n_devices=8)


def test_sharded_decode_sequence_of_steps(subproc):
    """Several decode steps in a row keep the cache consistent."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import layers as L
from repro.launch.mesh import make_mesh
mesh = make_mesh((1, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
d_model, H, K, D = 16, 4, 2, 4
p = L.attention_init(key, d_model, H, K, D)
B, T = 16, 16
xs = jax.random.normal(key, (B, 4, d_model))
def roll(decode_impl, mesh_):
    cache = {"k": jnp.zeros((B, T, K, D)), "v": jnp.zeros((B, T, K, D)),
             "len": jnp.asarray(0)}
    outs = []
    for t in range(4):
        y, cache = L.attention_apply(p, xs[:, t:t+1], n_heads=H, n_kv=K,
                                     d_head=D, kv_cache=cache,
                                     decode_impl=decode_impl, mesh=mesh_)
        outs.append(y)
    return jnp.concatenate(outs, 1)
y_ref = roll("xla", None)
with mesh:
    y_sh = roll("sharded", mesh)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sh),
                           rtol=3e-3, atol=3e-3)
print("OK")
""", n_devices=4)
