"""Measurement loop (PR 5): CalibrationStore, calibrated LUT columns,
energy-aware water-filling, exactly-once arrival smoothing, the
out-of-order completion clamp, and simulate-vs-live parity."""
import time

import numpy as np
import pytest

from repro.core.types import ElasticSpace, SubnetSpec
from repro.runtime import (CalibrationStore, GlobalConstraints,
                           ResourceArbiter, bucket_latency_ms, model_lut)
from repro.runtime import hwmodel as hm
from repro.runtime.engine import _InFlight
from repro.runtime.lut import BUCKET_OVERHEAD_FRAC

FULL = SubnetSpec()
HALF = SubnetSpec(width_mult=0.5)
SPACE = ElasticSpace(width_mults=(0.5, 1.0))
TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008, t_collective=0.004)


def make_lut(scale=1.0, chips=(256, 128, 64, 32)):
    terms = hm.RooflineTerms(TERMS.t_compute * scale, TERMS.t_memory * scale,
                             TERMS.t_collective * scale)
    hw = [hm.HwState(chips=c, freq=f) for c in chips for f in hm.FREQ_LADDER]
    return model_lut(SPACE.enumerate(), full_terms=terms, full_chips=256,
                     hw_states=hw)


def tiny_server(**kw):
    import jax
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2, d_model=32,
                    n_heads=4, d_ff=64, n_classes=4, compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    return DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                         params, dims, **kw)


# --- CalibrationStore unit behaviour -----------------------------------------

def test_store_blends_measured_over_prior_by_confidence():
    store = CalibrationStore()
    prior = 100.0
    assert store.blended_latency_ms(FULL, 1, prior) == prior  # no samples
    store.note_latency(FULL, 1, 10.0, max_batch=8)
    one = store.blended_latency_ms(FULL, 1, prior)
    assert 10.0 < one < prior          # one sample only nudges the prior
    for _ in range(100):
        store.note_latency(FULL, 1, 10.0, max_batch=8)
    many = store.blended_latency_ms(FULL, 1, prior)
    assert many < one                  # confidence grows with samples
    # w = n/(n+K): at n=101, 93% measured / 7% prior
    expect = (101 / 109) * 10.0 + (8 / 109) * prior
    assert many == pytest.approx(expect, rel=1e-6)


def test_store_point_latency_projects_bucket_to_full():
    store = CalibrationStore()
    # a bucket-2 observation on an 8-ladder implies full-batch = ms / frac
    frac = BUCKET_OVERHEAD_FRAC + (1 - BUCKET_OVERHEAD_FRAC) * 2 / 8
    for _ in range(200):
        store.note_latency(FULL, 2, 5.0, max_batch=8)
    prior = 20.0
    w = 200 / 208
    est = store.point_latency_ms(FULL, prior_ms=prior)
    assert est == pytest.approx(w * (5.0 / frac) + (1 - w) * prior, rel=1e-6)


def test_store_power_scale_is_duty_cycle_ratio():
    store = CalibrationStore()
    assert store.power_scale("t") == 1.0          # prior
    for _ in range(100):
        store.note_power("t", measured_w=50.0, modelled_w=200.0)
    w = 100 / 108        # ratio blended with the 1.0 prior by confidence
    assert store.power_scale("t") == pytest.approx(
        w * 0.25 + (1 - w) * 1.0, rel=1e-6)
    # energy/busy bookkeeping
    store.note_energy("t", energy_mj=400.0, busy_s=2.0)
    assert store.busy_power_w("t") == pytest.approx(0.2)   # 0.4 J / 2 s
    store.note_energy("t", -5.0, 1.0)             # negative: ignored
    assert store.busy_power_w("t") == pytest.approx(0.2)


def test_store_save_load_roundtrip(tmp_path):
    store = CalibrationStore()
    for _ in range(10):
        store.note_latency(HALF, 4, 7.5, max_batch=8)
        store.note_power("api", 80.0, 160.0)
    store.note_energy("api", 100.0, 0.5)
    path = str(tmp_path / "cal.json")
    store.save(path)
    again = CalibrationStore.load(path)
    assert again.latency_ms(HALF, 4) == pytest.approx(store.latency_ms(HALF, 4))
    assert again.latency_samples(HALF, 4) == 10
    assert again.power_scale("api") == pytest.approx(store.power_scale("api"))
    assert again.busy_power_w("api") == pytest.approx(0.2)  # 0.1 J / 0.5 s


# --- satellite: isotonic bucket columns --------------------------------------

def test_bucket_column_isotonic_under_noisy_measurements():
    """A calibrated column must never report a larger bucket as faster
    than a smaller one — noisy EWMAs would otherwise break bucket_for
    selection and the bucketed service model."""
    store = CalibrationStore()
    # pathological measurements: bucket 4 "slower" than bucket 8
    for _ in range(200):
        store.note_latency(FULL, 4, 50.0, max_batch=8)
        store.note_latency(FULL, 8, 20.0, max_batch=8)
    lut = make_lut()
    point = next(p for p in lut.points if p.subnet == FULL)
    col = lut.bucket_latencies(point, 8, calibration=store)
    ladder = sorted(col)
    assert all(col[a] <= col[b] for a, b in zip(ladder, ladder[1:])), col
    # the direct hot-path call agrees with the column (same guard)
    for b in ladder:
        assert bucket_latency_ms(point.latency_ms, b, 8, calibration=store,
                                 spec=FULL) == pytest.approx(col[b])
    # bucket 8 was clamped UP to bucket 4's level, not 4 down to 8's
    assert col[8] >= col[4]


def test_bucket_column_analytic_unchanged_without_store():
    lut = make_lut()
    point = next(p for p in lut.points if p.subnet == FULL)
    col = lut.bucket_latencies(point, 8)
    assert col[8] == pytest.approx(point.latency_ms)
    frac1 = BUCKET_OVERHEAD_FRAC + (1 - BUCKET_OVERHEAD_FRAC) / 8
    assert col[1] == pytest.approx(point.latency_ms * frac1)


# --- satellite: out-of-order completion clamp --------------------------------

def test_out_of_order_completion_never_integrates_negative_energy():
    """dt = t_ready - max(t_dispatch, _last_ready) goes negative when a
    pipelined completion lands after a later batch already advanced
    _last_ready — it must clamp to 0, not subtract busy time/energy."""
    server = tiny_server()
    hw = hm.HwState(chips=1, freq=1.0)
    # a batch that "completed" before an earlier one: _last_ready is
    # already far in the future when this completion lands
    server._last_ready = time.perf_counter() + 100.0
    stale = _InFlight(out=np.zeros((1, 4), "float32"), reqs=[],
                      t_dispatch=time.perf_counter() - 1.0, hw=hw,
                      subnet="full", buf_key=(1, (), "f4"), buf=None,
                      spec=FULL, bucket=1)
    server._complete(stale)
    assert server.busy_s == 0.0                 # clamped, not negative
    assert server.measured_energy_mj == 0.0
    # _last_ready must not move backwards either
    assert server._last_ready >= time.perf_counter() + 50.0


def test_completion_records_latency_into_store():
    store = CalibrationStore()
    server = tiny_server(calibration=store, tenant="api")
    server.start()
    try:
        x = np.zeros((16, 16, 3), "float32")
        fut = server.submit(x)
        assert fut.get(timeout=60)["y"].shape == (4,)
    finally:
        server.stop()
    assert store.latency_samples(FULL, 1) >= 1
    assert store.latency_ms(FULL, 1) > 0
    # per-tenant energy/busy recorded under the tenant label
    assert store.busy_power_w("api") is not None


# --- satellite: exactly-once arrival-rate smoothing --------------------------

def test_step_change_converges_at_configured_beta():
    """After a rate step 0 -> R, the live-tenant EWMA must follow the
    single-smoothing trajectory R * (1 - beta^k) — the old path smoothed
    externally-reported rates AND the server counter (beta applied twice
    per observation), converging at beta^2 and corrupting the adaptive
    batching window pushed back via note_arrival_rate."""
    from repro.runtime.arbiter import _EWMA_BETA
    clock = [0.0]
    interval = 0.1
    arb = ResourceArbiter(interval_s=interval, time_fn=lambda: clock[0])
    server = tiny_server()
    lut = make_lut(chips=(1,))
    w = arb.register("a", lut, target_latency_ms=1e6, server=server)
    g = GlobalConstraints(total_chips=1)
    x = np.zeros((16, 16, 3), "float32")
    rate = 100.0
    futs = []
    expected = 0.0
    try:
        for k in range(6):
            for _ in range(int(rate * interval)):   # 10 arrivals/epoch
                futs.append(server.submit(x))
            # a driver also reporting the SAME arrivals via set_active
            # must not smooth them a second time (server is authoritative)
            arb.set_active("a", True, arrival_rate_rps=rate)
            clock[0] += interval
            arb.arbitrate(g)
            expected = _EWMA_BETA * expected + (1 - _EWMA_BETA) * rate
            assert w.arrival_ewma == pytest.approx(expected, rel=1e-6), (
                f"epoch {k}: EWMA {w.arrival_ewma} != single-smoothing "
                f"trajectory {expected}")
    finally:
        server.stop()
    for f in futs:
        f.get(timeout=5)


def test_mid_cycle_preempt_does_not_resmooth_partial_window():
    """preempt() re-arbitrates mid-cycle; the few arrivals since the last
    tick must fold into the NEXT window, not be divided by a full
    interval and EWMA'd again (double smoothing + rate inflation)."""
    from repro.runtime.arbiter import _EWMA_BETA
    clock = [0.0]
    interval = 0.1
    arb = ResourceArbiter(interval_s=interval, time_fn=lambda: clock[0])
    server = tiny_server()
    lut = make_lut(chips=(1,))
    w = arb.register("a", lut, target_latency_ms=1e6, server=server)
    g = GlobalConstraints(total_chips=1)
    x = np.zeros((16, 16, 3), "float32")
    futs = [server.submit(x) for _ in range(10)]
    clock[0] += interval
    arb.arbitrate(g)
    after_tick = w.arrival_ewma
    assert after_tick == pytest.approx((1 - _EWMA_BETA) * 100.0, rel=1e-6)
    # 2 arrivals land, then a preempt fires 10 ms into the cycle
    futs += [server.submit(x) for _ in range(2)]
    clock[0] += 0.01
    arb.preempt("a", g)
    assert w.arrival_ewma == after_tick          # no partial-window smooth
    assert w.rate_pending == 2                   # folded into the next one
    # the full tick later, those 2 arrivals count exactly once, over the
    # ACTUAL elapsed window (0.01 + 0.09 = one interval)
    clock[0] += 0.09
    arb.arbitrate(g)
    expected = _EWMA_BETA * after_tick + (1 - _EWMA_BETA) * (2 / interval)
    assert w.arrival_ewma == pytest.approx(expected, rel=1e-6)
    server.stop()
    for f in futs:
        f.get(timeout=5)


def test_set_active_still_smooths_simulated_tenants():
    """Tenants WITHOUT a server keep the set_active smoothing path (the
    discrete-event drivers report per-epoch rates there)."""
    from repro.runtime.arbiter import _EWMA_BETA
    arb = ResourceArbiter()
    w = arb.register("a", make_lut(), target_latency_ms=40.0)
    arb.set_active("a", True, arrival_rate_rps=100.0)
    assert w.arrival_ewma == pytest.approx((1 - _EWMA_BETA) * 100.0)


# --- calibrated planning (arbiter) -------------------------------------------

def test_measured_watts_let_second_tenant_under_power_budget():
    """Open-loop, the power budget fits ONE modelled slice; with measured
    duty cycles attached, priced watts halve and both tenants fit — the
    energy-aware water-filling headline behaviour."""
    lut = make_lut(chips=(1,))
    one_slice_w = hm.slice_power_w(hm.HwState(chips=1, freq=0.4))
    g = GlobalConstraints(total_chips=2, power_budget_w=1.5 * one_slice_w)
    target = max(p.latency_ms for p in lut.points) + 1.0   # any point meets

    open_loop = ResourceArbiter()
    open_loop.register("a", lut, target_latency_ms=target)
    open_loop.register("b", lut, target_latency_ms=target)
    allocs = open_loop.arbitrate(g)
    assert allocs["a"].feasible and not allocs["b"].feasible

    store = CalibrationStore()
    for _ in range(100):
        store.note_power("a", 0.5 * one_slice_w, one_slice_w)
        store.note_power("b", 0.5 * one_slice_w, one_slice_w)
    closed = ResourceArbiter(calibration=store)
    closed.register("a", lut, target_latency_ms=target)
    closed.register("b", lut, target_latency_ms=target)
    allocs = closed.arbitrate(g)
    assert allocs["a"].feasible and allocs["b"].feasible
    # priced watts (not raw modelled watts) respect the budget
    assert sum(a.priced_power_w for a in allocs.values()) \
        <= g.power_budget_w + 1e-9


# --- satellite: simulate-vs-drive_live parity --------------------------------

def test_calibrated_simulate_closer_to_live_p95_than_analytic():
    """After a calibration warm-up on a seeded trace, replaying it
    through simulate(calibration=store) must predict the live per-class
    p95 better than the analytic model does — the whole point of feeding
    measurement back into the planner."""
    from repro.traffic import DEGRADE, SLOClass, drive_live, poisson, simulate
    probe = tiny_server()
    x = np.zeros((8, 16, 16, 3), "float32")
    real_ms = probe.measure(FULL, x)     # true full-batch wall clock
    # open-loop failure mode: the analytic profile is ~96x pessimistic —
    # wildly enough that host-contention noise in the live p95 can never
    # bring it closer to the truth than the calibrated replay
    terms = hm.RooflineTerms(96.0 * real_ms / 1e3, 0.0, 0.0)
    lut = model_lut([FULL], full_terms=terms, full_chips=1,
                    hw_states=[hm.HwState(chips=1, freq=1.0)])
    # max_batch=1 mirrors the engine below: one request = one dispatch,
    # so the calibrated service model prices exactly what was measured
    cls = SLOClass("api", deadline_ms=300.0 * real_ms, priority=1,
                   drop_policy=DEGRADE, max_batch=1)
    streams = {"api": list(poisson(10.0, 2.0, seed=5))}

    store = CalibrationStore()
    server = tiny_server(calibration=store, tenant="api", timeout_ms=1.0,
                         max_batch=1)
    server.warm([FULL], example_input=x[0])
    arb = ResourceArbiter(interval_s=0.05)
    arb.register("api", lut, target_latency_ms=cls.service_target_ms,
                 priority=1, server=server)
    live = drive_live([cls], {"api": server}, arb, streams,
                      lambda n: x[0],
                      g_fn=lambda: GlobalConstraints(total_chips=1))
    p95_live = live.classes["api"].p(95)
    assert live.classes["api"].completed > 0
    assert store.latency_samples(FULL, 1) > 0    # warm-up really recorded

    g_fn = lambda t: GlobalConstraints(total_chips=1)
    analytic = simulate([cls], {"api": lut}, streams, g_fn,
                        interval_s=0.05)
    calibrated = simulate([cls], {"api": lut}, streams, g_fn,
                          interval_s=0.05, calibration=store)
    err_analytic = abs(analytic.classes["api"].p(95) - p95_live)
    err_cal = abs(calibrated.classes["api"].p(95) - p95_live)
    assert err_cal < err_analytic, (
        f"calibrated p95 {calibrated.classes['api'].p(95):.2f}ms vs "
        f"analytic {analytic.classes['api'].p(95):.2f}ms, live "
        f"{p95_live:.2f}ms")


def test_calibrated_latency_flips_feasibility():
    """Analytic says the target is impossible; measurement says it is
    met — the calibrated arbiter must plan off the measurement."""
    lut = make_lut(chips=(1,))
    fastest = min(p.latency_ms for p in lut.points)
    target = 0.5 * fastest          # analytically infeasible everywhere
    g = GlobalConstraints(total_chips=2)

    open_loop = ResourceArbiter()
    open_loop.register("a", lut, target_latency_ms=target)
    assert not open_loop.arbitrate(g)["a"].feasible

    store = CalibrationStore()
    for _ in range(200):            # measured: ~0.1 * target, well under
        store.note_latency(FULL, 8, 0.1 * target, max_batch=8)
        store.note_latency(HALF, 8, 0.1 * target, max_batch=8)
    closed = ResourceArbiter(calibration=store)
    closed.register("a", lut, target_latency_ms=target)
    alloc = closed.arbitrate(g)["a"]
    assert alloc.feasible
    assert alloc.point.latency_ms <= target   # the calibrated latency


# --- satellite: benchmark trajectory gate ------------------------------------

def test_bench_compare_flags_headline_regressions():
    """run.py --compare: deterministic headlines are gated >10% relative
    to the previous file; noisy live ratios are gated on their absolute
    ceiling (the bench's own invariant), not prev-relative."""
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    sys.path.insert(0, root)
    try:
        from benchmarks.run import compare_headlines
    finally:
        sys.path.remove(root)
    prev = {"s": [
        {"name": "calibration/energy_ratio", "value": 0.5, "derived": ""},
        {"name": "traffic/serving_bucketed_speedup", "value": 1.5,
         "derived": ""},
    ]}
    assert compare_headlines(prev, prev) == []
    worse = {"s": [
        {"name": "calibration/energy_ratio", "value": 1.07,
         "derived": ""},                        # above the 1.0 ceiling
        {"name": "traffic/serving_bucketed_speedup", "value": 1.3,
         "derived": ""},                        # -13% (higher is better)
    ]}
    flagged = {r[0] for r in compare_headlines(prev, worse)}
    assert flagged == {"calibration/energy_ratio",
                       "traffic/serving_bucketed_speedup"}
    # run-to-run live noise (several-fold, still under the ceiling) and
    # within-tolerance deterministic drift are NOT flagged
    near = {"s": [
        {"name": "calibration/energy_ratio", "value": 0.9, "derived": ""},
        {"name": "traffic/serving_bucketed_speedup", "value": 1.4,
         "derived": ""},
    ]}
    assert compare_headlines(prev, near) == []
