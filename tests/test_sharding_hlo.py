"""Sharding rules + HLO analysis correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import clean_spec, param_specs
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_mesh


def test_clean_spec_drops_missing_axes():
    mesh = make_mesh((1, 1), ("data", "model"))
    spec = P(("pod", "data"), "model", "pod")
    c = clean_spec(spec, mesh)
    assert c == P(("data",), "model", None)


def test_lm_param_specs_rules():
    shapes = {
        "embed": {"embedding": jax.ShapeDtypeStruct((163840, 7168),
                                                    jnp.float32)},
        "dense_layers": {"attn": {
            "q": {"kernel": jax.ShapeDtypeStruct((80, 8192, 8192),
                                                 jnp.float32)},
            "o": {"kernel": jax.ShapeDtypeStruct((80, 8192, 8192),
                                                 jnp.float32)}},
            "ln1": {"scale": jax.ShapeDtypeStruct((80, 8192), jnp.float32)}},
        "lm_head": {"kernel": jax.ShapeDtypeStruct((8192, 152064),
                                                   jnp.float32)},
    }
    specs = param_specs(shapes, "lm")
    assert specs["embed"]["embedding"] == P("model", ("pod", "data"))
    assert specs["dense_layers"]["attn"]["q"]["kernel"] == \
        P(None, ("pod", "data"), "model")
    assert specs["dense_layers"]["attn"]["o"]["kernel"] == \
        P(None, "model", ("pod", "data"))
    assert specs["dense_layers"]["ln1"]["scale"] == P(None, None)
    assert specs["lm_head"]["kernel"] == P(("pod", "data"), "model")


def test_param_specs_divisibility_guard():
    shapes = {"embed": {"embedding": jax.ShapeDtypeStruct((1001, 1024),
                                                          jnp.float32)}}
    specs = param_specs(shapes, "vision", fsdp_axes=())
    # 1001 % 16 != 0 -> vocab axis dropped
    assert specs["embed"]["embedding"][0] is None


def test_hlo_scan_trip_count_flops():
    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, ws)[0]
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
    comp = jax.jit(f).lower(x, ws).compile()
    res = analyze_hlo(comp.as_text())
    expect = 2 * 128 * 256 * 256 * 7
    assert abs(res["flops"] - expect) / expect < 0.01


def test_hlo_conv_flops():
    def g(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.ShapeDtypeStruct((4, 16, 16, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 3, 32, 64), jnp.float32)
    comp = jax.jit(g).lower(x, w).compile()
    res = analyze_hlo(comp.as_text())
    expect = 2 * 4 * 16 * 16 * 64 * 3 * 3 * 32
    assert abs(res["flops"] - expect) / expect < 0.01


def test_hlo_collective_bytes_counted(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("data",))
def f(x):
    return x.sum(0)   # cross-shard reduction -> all-reduce
x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
with mesh:
    comp = jax.jit(f, in_shardings=NamedSharding(mesh, P("data", None)),
                   out_shardings=NamedSharding(mesh, P(None))).lower(x).compile()
res = analyze_hlo(comp.as_text())
assert res["coll_bytes_total"] >= 128 * 4, res["coll_bytes"]
print("COLL", res["coll_bytes_total"])
""", n_devices=8)
    assert "COLL" in out


def test_production_mesh_shapes(subproc):
    out = subproc("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
m2 = make_production_mesh(multi_pod=True)
assert dict(m1.shape) == {"data": 16, "model": 16}
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
print("MESH OK", m1.size, m2.size)
""", n_devices=512)
    assert "MESH OK 256 512" in out
