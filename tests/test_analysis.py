"""Tests for repro.analysis: lint rules (RT001-RT006), the lock-order
detector, and guarded-by runtime assertions."""
import textwrap
import threading

import pytest

from repro.analysis import guards, locks
from repro.analysis.lint import (PRAGMA_ALIASES, RULES, format_findings,
                                 lint_file, lint_tree)

# ---------------------------------------------------------------------------
# Lint fixtures: one minimal positive + pragma'd negative per rule

FIXTURES = {
    "RT001": textwrap.dedent("""\
        import time
        def now():
            return time.time()
        """),
    "RT002": textwrap.dedent("""\
        class Node:
            def __init__(self):
                self.event_log = []
        """),
    "RT003": textwrap.dedent("""\
        import random
        def pick():
            return random.randint(0, 5)
        """),
    "RT004": textwrap.dedent("""\
        from repro.obs import trace as obs
        def emit(tracer, t0, t1):
            tracer.decision(obs.MIGRATE, t0, t1, node="n0", src="n1")
        """),
    "RT005": textwrap.dedent("""\
        import threading
        def spawn(fn):
            t = threading.Thread(target=fn)
            t.start()
        """),
    "RT006": textwrap.dedent("""\
        import threading
        class Stats:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0   # guarded-by: _lock
            def bump(self):
                self.count += 1
            def bump_locked(self):
                with self._lock:
                    self.count += 1
        """),
}

# the same violation with a reasoned pragma on the offending line
SUPPRESSED = {
    "RT001": FIXTURES["RT001"].replace(
        "time.time()", "time.time()  # repro: allow-wallclock(fixture)"),
    "RT002": FIXTURES["RT002"].replace(
        "self.event_log = []",
        "self.event_log = []  # repro: allow-unbounded(fixture)"),
    "RT003": FIXTURES["RT003"].replace(
        "random.randint(0, 5)",
        "random.randint(0, 5)  # repro: allow-unseeded(fixture)"),
    "RT004": FIXTURES["RT004"].replace(
        'src="n1")', 'src="n1")  # repro: allow-span(fixture)'),
    "RT005": FIXTURES["RT005"].replace(
        "threading.Thread(target=fn)",
        "threading.Thread(target=fn)  # repro: allow-thread(fixture)"),
    "RT006": FIXTURES["RT006"].replace(
        "self.count += 1\n    def bump_locked",
        "self.count += 1  # repro: allow-guard(fixture)\n"
        "    def bump_locked"),
}


def _lint_source(tmp_path, source, name="snippet.py"):
    p = tmp_path / name
    p.write_text(source)
    return lint_file(str(p), name)


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_fires_on_fixture(tmp_path, rule):
    findings = _lint_source(tmp_path, FIXTURES[rule])
    assert [f.rule for f in findings] == [rule], format_findings(findings)


@pytest.mark.parametrize("rule", sorted(SUPPRESSED))
def test_reasoned_pragma_suppresses(tmp_path, rule):
    findings = _lint_source(tmp_path, SUPPRESSED[rule])
    assert findings == [], format_findings(findings)


def test_fixture_tree_reports_exactly_one_per_rule(tmp_path):
    for rule, src in FIXTURES.items():
        (tmp_path / f"viol_{rule.lower()}.py").write_text(src)
    findings = lint_tree(str(tmp_path))
    assert sorted(f.rule for f in findings) == sorted(FIXTURES)


def test_pragma_without_reason_is_a_finding(tmp_path):
    src = FIXTURES["RT001"].replace(
        "time.time()", "time.time()  # repro: allow-wallclock()")
    findings = _lint_source(tmp_path, src)
    assert [f.rule for f in findings] == ["RT000"]
    assert "needs a reason" in findings[0].message


def test_unused_pragma_is_a_finding(tmp_path):
    findings = _lint_source(
        tmp_path, "x = 1  # repro: allow-wallclock(no violation here)\n")
    assert [f.rule for f in findings] == ["RT000"]
    assert "suppresses nothing" in findings[0].message


def test_unknown_pragma_alias_is_a_finding(tmp_path):
    findings = _lint_source(
        tmp_path, "x = 1  # repro: allow-everything(whatever)\n")
    assert [f.rule for f in findings] == ["RT000"]


def test_every_pragma_alias_maps_to_a_rule():
    assert set(PRAGMA_ALIASES.values()) <= set(RULES)


# -- rule edges -------------------------------------------------------------


def test_rt001_allows_perf_counter_and_injection(tmp_path):
    src = textwrap.dedent("""\
        import time
        def f(time_fn=time.monotonic):
            return time.perf_counter(), time_fn()
        """)
    assert _lint_source(tmp_path, src) == []


def test_rt001_allowlisted_module_is_exempt(tmp_path):
    sub = tmp_path / "launch"
    sub.mkdir()
    (sub / "runner.py").write_text(FIXTURES["RT001"])
    assert lint_tree(str(tmp_path)) == []


def test_rt002_bounded_deque_ok(tmp_path):
    src = "import collections\nq = collections.deque(maxlen=10)\n"
    assert _lint_source(tmp_path, src) == []


def test_rt003_seeded_rngs_ok(tmp_path):
    src = textwrap.dedent("""\
        import random
        import numpy as np
        import jax
        r = random.Random(7)
        g = np.random.default_rng(7)
        def f(key):
            return r.random(), g.random(), jax.random.uniform(key)
        """)
    assert _lint_source(tmp_path, src) == []


def test_rt003_np_global_rng_fires(tmp_path):
    src = "import numpy as np\nx = np.random.rand()\n"
    findings = _lint_source(tmp_path, src)
    assert [f.rule for f in findings] == ["RT003"]


def test_rt004_unknown_kind_fires(tmp_path):
    src = 'def emit(tracer):\n    tracer.decision("bogus_kind", 0, 1)\n'
    findings = _lint_source(tmp_path, src)
    assert [f.rule for f in findings] == ["RT004"]
    assert "unknown span kind" in findings[0].message


def test_rt004_spans_kwarg_literal_dict(tmp_path):
    src = textwrap.dedent("""\
        from repro.obs import trace as obs
        def emit(tracer):
            tracer.finish_request(1, "c", 0.0, 1.0, spans=[
                (obs.DEVICE, 0.0, 1.0, {"bucket": 1, "n": 2})])
        """)
    findings = _lint_source(tmp_path, src)
    assert [f.rule for f in findings] == ["RT004"]
    assert "subnet" in findings[0].message


def test_rt004_complete_emission_ok(tmp_path):
    src = textwrap.dedent("""\
        from repro.obs import trace as obs
        def emit(tracer, t0, t1):
            tracer.decision(obs.MIGRATE, t0, t1, src="n1", cost_s=0.2)
            attrs = {"bucket": 1, "subnet": "s", "n": 2}
            tracer.finish_request(1, "c", 0.0, 1.0, spans=[
                (obs.DEVICE, 0.0, 1.0, attrs),
                (obs.QUEUE, 0.0, 0.5, None)])
        """)
    assert _lint_source(tmp_path, src) == []


def test_rt005_wait_in_loop_and_bare_except(tmp_path):
    src = textwrap.dedent("""\
        def pump(ev):
            while True:
                ev.wait()
        def risky(f):
            try:
                f()
            except:
                pass
        """)
    findings = _lint_source(tmp_path, src)
    assert sorted(f.rule for f in findings) == ["RT005", "RT005"]


def test_rt005_daemon_thread_and_timed_wait_ok(tmp_path):
    src = textwrap.dedent("""\
        import threading
        def spawn(fn, ev):
            t = threading.Thread(target=fn, daemon=True)
            t.start()
            while not ev.wait(0.1):
                pass
        """)
    assert _lint_source(tmp_path, src) == []


def test_rt006_locked_write_ok(tmp_path):
    src = FIXTURES["RT006"].replace(
        "    def bump(self):\n        self.count += 1\n", "")
    assert _lint_source(tmp_path, src) == []


# -- the real tree must be clean --------------------------------------------


def test_repro_tree_is_clean():
    findings = lint_tree()
    assert findings == [], format_findings(findings)


# ---------------------------------------------------------------------------
# Lock-order detector


def test_lock_order_cycle_detected_with_stacks():
    mon = locks.LockMonitor()
    a, b = mon.lock("A"), mon.lock("B")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    cycles = mon.cycles()
    assert len(cycles) == 1
    assert set(cycles[0]) == {"A", "B"}
    report = mon.report()
    assert "POTENTIAL DEADLOCK" in report
    # both acquisition stacks are attached
    assert "A held while acquiring B" in report
    assert "B held while acquiring A" in report
    assert "test_analysis.py" in report


def test_consistent_order_is_acyclic():
    mon = locks.LockMonitor()
    a, b, c = mon.lock("A"), mon.lock("B"), mon.lock("C")
    for _ in range(3):
        with a, b, c:
            pass
        with a, c:
            pass
    assert mon.cycles() == []
    assert "OK" in mon.report()


def test_rlock_reentrancy_no_self_edge():
    mon = locks.LockMonitor()
    r = mon.rlock("R")
    with r:
        with r:
            pass
    assert mon.edges() == []
    assert mon._held() == []    # bookkeeping drained


def test_two_instances_same_class_not_an_edge():
    mon = locks.LockMonitor()
    a1, a2 = mon.lock("A"), mon.lock("A")
    with a1:
        with a2:
            pass
    assert mon.edges() == []


def test_cross_thread_edges_merge():
    mon = locks.LockMonitor()
    a, b = mon.lock("A"), mon.lock("B")

    def forward():
        with a:
            with b:
                pass

    def backward():
        with b:
            with a:
                pass

    t1 = threading.Thread(target=forward, daemon=True)
    t2 = threading.Thread(target=backward, daemon=True)
    t1.start(); t1.join()
    t2.start(); t2.join()
    assert len(mon.cycles()) == 1


def test_dispatch_note_flags_held_locks():
    mon = locks.LockMonitor()
    lk = mon.lock("ctl")
    mon.note_dispatch()                       # nothing held: clean
    assert mon.dispatch_violations == []
    with lk:
        mon.note_dispatch()
    assert len(mon.dispatch_violations) == 1
    thread, held, _stack = mon.dispatch_violations[0]
    assert held == ("ctl",)
    assert "DEVICE DISPATCH" in mon.report()


def test_tracked_lock_behaves_like_a_lock():
    mon = locks.LockMonitor()
    lk = mon.lock("L")
    assert lk.acquire()
    assert lk.locked()
    assert lk._is_owned()
    lk.release()
    assert not lk.locked()
    assert not lk._is_owned()
    assert lk.acquire(False)
    lk.release()


def test_monkeypatch_tracks_only_prefixed_modules(subproc):
    out = subproc(textwrap.dedent("""\
        import threading, types
        from repro.analysis import locks
        mon = locks.install()
        fake = types.ModuleType("repro.fakemod")
        exec("import threading\\n"
             "def make():\\n"
             "    return threading.Lock()\\n", fake.__dict__)
        tracked = fake.make()
        assert isinstance(tracked, locks.TrackedLock), type(tracked)
        assert "repro.fakemod" in tracked._key
        plain = threading.Lock()            # __main__: left native
        assert not isinstance(plain, locks.TrackedLock)
        import queue
        q = queue.Queue()                   # stdlib internals left native
        assert not isinstance(q.mutex, locks.TrackedLock)
        assert locks.uninstall() is mon
        assert not isinstance(threading.Lock(), locks.TrackedLock)
        print("MONKEYPATCH-OK")
        """), n_devices=1)
    assert "MONKEYPATCH-OK" in out


# ---------------------------------------------------------------------------
# Guarded-by runtime assertions


def _fresh_guarded_class():
    @guards.guarded_by("_lock", "x")
    class T:
        def __init__(self):
            self.x = 0              # first bind precedes the lock: allowed
            self._lock = threading.RLock()

        def locked_bump(self):
            with self._lock:
                self.x += 1
    return T


def test_guards_fire_when_enabled_and_free_when_off():
    guards.disable_guards()
    T = _fresh_guarded_class()
    t = T()
    t.x = 1                          # disabled: plain attribute
    assert "x" not in T.__dict__     # zero instrumentation installed
    guards.enable_guards()
    try:
        with pytest.raises(guards.GuardViolation):
            t.x = 2
        with pytest.raises(guards.GuardViolation):
            _ = t.x
        t.locked_bump()              # value handed off seamlessly
        with t._lock:
            assert t.x == 2
    finally:
        guards.disable_guards()
    assert "x" not in T.__dict__
    t.x = 5                          # free again
    assert t.x == 5


def test_guards_allow_construction_before_lock_exists():
    guards.enable_guards()
    try:
        T = _fresh_guarded_class()
        t = T()                      # must not raise mid-__init__
        with t._lock:
            assert t.x == 0
    finally:
        guards.disable_guards()


def test_guard_violation_names_field_lock_and_thread():
    guards.enable_guards()
    try:
        t = _fresh_guarded_class()()
        with pytest.raises(guards.GuardViolation) as exc:
            t.x = 9
        msg = str(exc.value)
        assert "T.x" in msg and "_lock" in msg and "thread" in msg
    finally:
        guards.disable_guards()


def test_registered_introspection_covers_hot_classes():
    import repro.cluster.frontend    # noqa: F401 — populate registry
    import repro.runtime.arbiter     # noqa: F401
    reg = guards.registered()
    assert "_outstanding" in reg["DynamicServer"]["_acct_lock"]
    assert "last_alloc" in reg["ResourceArbiter"]["_lock"]
    assert "placements" in reg["Cluster"]["_lock"]


def test_env_var_enables_guards_in_fresh_process(subproc, monkeypatch):
    monkeypatch.setenv(guards.ENV_VAR, "1")
    out = subproc(textwrap.dedent("""\
        import threading
        from repro.analysis import guards
        assert guards.guards_enabled()
        @guards.guarded_by("_lock", "x")
        class T:
            def __init__(self):
                self.x = 0
                self._lock = threading.RLock()
        t = T()
        try:
            t.x = 1
            raise SystemExit("guard did not fire")
        except guards.GuardViolation:
            print("GUARD-FIRED")
        """), n_devices=1)
    assert "GUARD-FIRED" in out


def test_live_arbiter_clean_under_guards():
    """A real arbiter exercised end to end with guards on: every internal
    access is lock-disciplined, and the locked accessor keeps external
    readers clean too."""
    from repro.core.types import ElasticSpace
    from repro.runtime import (GlobalConstraints, ResourceArbiter, model_lut)
    from repro.runtime import hwmodel as hm

    space = ElasticSpace(width_mults=(0.5, 1.0), ffn_mults=(1.0,),
                         depth_mults=(1.0,))
    terms = hm.RooflineTerms(t_compute=0.02, t_memory=0.008,
                             t_collective=0.004)
    lut = model_lut(space.enumerate(), full_terms=terms, full_chips=256)
    guards.enable_guards()
    try:
        arb = ResourceArbiter(interval_s=0.01)
        arb.register("api", lut, target_latency_ms=500.0, priority=1)
        g = GlobalConstraints(total_chips=4, power_budget_w=200.0)
        arb.arbitrate(g)
        assert "api" in arb.last_allocations()
        assert "api" in arb.summary()
    finally:
        guards.disable_guards()
