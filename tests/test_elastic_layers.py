"""Property tests for the masked == sliced duality (the core invariant)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import layers as L
from repro.core.elastic import mask_dim

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

KEY = jax.random.PRNGKey(0)


@given(d_in=st.integers(4, 48), d_out=st.integers(4, 48),
       fi=st.floats(0.25, 1.0), fo=st.floats(0.25, 1.0))
def test_dense_slice_eq_mask(d_in, d_out, fi, fo):
    a_in = max(1, int(d_in * fi))
    a_out = max(1, int(d_out * fo))
    p = L.dense_init(KEY, d_in, d_out)
    x = jax.random.normal(KEY, (3, d_in))
    y_slice = L.dense_apply(p, x[..., :a_in], a_in=a_in, a_out=a_out)
    y_mask = L.dense_apply(p, mask_dim(x, jnp.asarray(a_in), -1),
                           a_out=jnp.asarray(a_out))
    np.testing.assert_allclose(np.asarray(y_slice),
                               np.asarray(y_mask[..., :a_out]),
                               rtol=1e-5, atol=1e-5)
    assert np.all(np.asarray(y_mask[..., a_out:]) == 0)


@given(d=st.integers(4, 64), frac=st.floats(0.2, 1.0),
       norm=st.sampled_from(["layernorm", "rmsnorm"]))
def test_norm_slice_eq_mask(d, frac, norm):
    a = max(1, int(d * frac))
    init = getattr(L, f"{norm}_init")
    apply = getattr(L, f"{norm}_apply")
    p = init(d)
    x = jax.random.normal(KEY, (2, 5, d))
    y_slice = apply(p, x[..., :a], a=a)
    y_mask = apply(p, mask_dim(x, jnp.asarray(a), -1), a=jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(y_slice),
                               np.asarray(y_mask[..., :a]),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_heads,n_kv,a_heads", [
    (8, 4, 4), (8, 4, 8), (8, 8, 4), (8, 8, 2), (4, 1, 2), (6, 2, 4),
])
def test_attention_heads_slice_eq_mask(n_heads, n_kv, a_heads):
    d_model, d_head = 32, 8
    p = L.attention_init(KEY, d_model, n_heads, n_kv, d_head)
    x = jax.random.normal(KEY, (2, 6, d_model))
    y_s, _ = L.attention_apply(p, x, n_heads=n_heads, n_kv=n_kv,
                               d_head=d_head, a_heads=a_heads)
    y_m, _ = L.attention_apply(p, x, n_heads=n_heads, n_kv=n_kv,
                               d_head=d_head, a_heads=jnp.asarray(a_heads))
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_m),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("impl", ["blocked_scan", "blocked_causal"])
def test_blocked_attention_matches_ref(impl):
    d_model, H, K, D = 32, 8, 4, 8
    p = L.attention_init(KEY, d_model, H, K, D)
    x = jax.random.normal(KEY, (1, 1024, d_model))
    y_ref, _ = L.attention_apply(p, x, n_heads=H, n_kv=K, d_head=D, impl="ref")
    y, _ = L.attention_apply(p, x, n_heads=H, n_kv=K, d_head=D, impl=impl,
                             block_q=256, block_kv=256)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_prefill():
    d_model, H, K, D = 32, 8, 4, 8
    p = L.attention_init(KEY, d_model, H, K, D)
    x = jax.random.normal(KEY, (2, 5, d_model))
    y_pref, _ = L.attention_apply(p, x, n_heads=H, n_kv=K, d_head=D)
    cache = {"k": jnp.zeros((2, 8, K, D)), "v": jnp.zeros((2, 8, K, D)),
             "len": jnp.asarray(0)}
    ys = []
    for t in range(5):
        y_t, cache = L.attention_apply(p, x[:, t:t + 1], n_heads=H, n_kv=K,
                                       d_head=D, kv_cache=cache)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_pref),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=2e-3, atol=2e-3)


def test_conv_elastic_kernel_and_channels():
    p = L.conv_init(KEY, 5, 8, 16)
    x = jax.random.normal(KEY, (2, 8, 8, 8))
    y = L.conv_apply(p, x, a_kernel=3, a_out=8)
    assert y.shape == (2, 8, 8, 8)
    # centre crop: a 3x3 crop of the 5x5 kernel equals explicit slicing
    w = p["kernel"][1:4, 1:4, :, :8]
    y2 = jax.lax.conv_general_dilated(x, w, (1, 1), "SAME",
                                      dimension_numbers=("NHWC", "HWIO",
                                                         "NHWC"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


def test_switchable_bn_settings_independent():
    p = L.sbn_init(8, n_settings=2)
    p["scale"] = p["scale"].at[1].set(2.0)
    x = jax.random.normal(KEY, (4, 3, 3, 8))
    y0, _ = L.sbn_apply(p, x, setting=0, train=True)
    y1, _ = L.sbn_apply(p, x, setting=1, train=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0 * 2.0),
                               rtol=1e-5, atol=1e-5)


def test_groupnorm_shapes():
    p = L.groupnorm_init(12)
    x = jax.random.normal(KEY, (2, 4, 4, 12))
    y = L.groupnorm_apply(p, x, groups=4)
    assert y.shape == x.shape
    assert abs(float(jnp.mean(y))) < 0.2
