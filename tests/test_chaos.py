"""PR 8: chaos injection + request reliability.

* **scenario/timeline** — the seeded fault vocabulary, validation, the
  DVFS ladder, lifecycle expansion (rack → N fails, spot → drain+fail)
  and the flattened live event stream;
* **sim tentpole** — chaos fail-stop rides the exact ``fail_at``
  failover path; seeded scenario + seeded trace ⇒ bit-identical
  reports; retries recover failed work under budget/deadline caps;
  hedging and brownout keep the accounting invariant;
* **satellites** — make-before-break migrations (zero drops during a
  scripted move), retry span links through check_trace/Perfetto,
  re-armable Watchdog + capped StragglerMonitor, the live
  ChaosController replaying a scenario against a real cluster, and the
  live retry drain loop.
"""
import queue
import time

import pytest

from repro.chaos import (DEFAULT_LADDER, FAIL_STOP, PARTITION, RACK_FAIL,
                         SPOT_PREEMPT, STRAGGLER, THERMAL, WEDGE,
                         BrownoutPolicy, ChaosTimeline, Injection,
                         Reliability, RetryBudget, RetryPolicy, Scenario,
                         generate)
from repro.chaos import engine as ce
from repro.cluster import (DEAD, FIRST_FIT, P2C, ClusterNode,
                           simulate_cluster)
from repro.core.types import ElasticSpace
from repro.distributed.fault import StragglerMonitor, Watchdog
from repro.obs import Tracer, to_chrome_trace
from repro.obs.analyze import check_trace
from repro.runtime import GlobalConstraints, ResourceArbiter, model_lut
from repro.runtime import hwmodel as hm
from repro.traffic import DEGRADE, SHED, SLOClass, poisson

TERMS = hm.RooflineTerms(t_compute=0.02, t_memory=0.008, t_collective=0.004)
SPACE = ElasticSpace(width_mults=(0.5, 0.75, 1.0), ffn_mults=(0.5, 1.0),
                     depth_mults=(0.5, 1.0))


def make_lut(scale=1.0, full_chips=256):
    terms = hm.RooflineTerms(TERMS.t_compute * scale, TERMS.t_memory * scale,
                             TERMS.t_collective * scale)
    return model_lut(SPACE.enumerate(), full_terms=terms,
                     full_chips=full_chips)


def make_nodes(capacities):
    return [ClusterNode(name=f"n{i}",
                        g_fn=lambda t, c=cap: GlobalConstraints(total_chips=c))
            for i, cap in enumerate(capacities)]


def invariant(report):
    for st in report.classes.values():
        assert st.submitted == (st.rejected + st.dropped + st.failed
                                + st.completed)


# --- scenario vocabulary -----------------------------------------------------

def test_injection_validation():
    with pytest.raises(ValueError):
        Injection(t=0.0, kind="meteor", node="n0")
    with pytest.raises(ValueError):
        Injection(t=0.0, kind=RACK_FAIL)            # needs `nodes`
    with pytest.raises(ValueError):
        Injection(t=0.0, kind=STRAGGLER)            # needs `node`
    inj = Injection(t=1.0, kind=RACK_FAIL, nodes=("n0", "n1"))
    assert inj.targets() == ("n0", "n1")
    assert Injection(t=0.0, kind=WEDGE, node="n2").targets() == ("n2",)


def test_scenario_sorts_and_summarises():
    sc = Scenario(name="s", injections=(
        Injection(t=2.0, kind=FAIL_STOP, node="n1"),
        Injection(t=1.0, kind=RACK_FAIL, nodes=("n0", "n2"))))
    assert [i.t for i in sc.injections] == [1.0, 2.0]
    assert sc.summary() == [(1.0, RACK_FAIL, "n0"), (1.0, RACK_FAIL, "n2"),
                            (2.0, FAIL_STOP, "n1")]


def test_generate_is_seeded():
    names = ["n0", "n1", "n2"]
    racks = {"r0": ["n0", "n1"]}
    a = generate(11, 10.0, names, racks=racks, n_faults=6)
    b = generate(11, 10.0, names, racks=racks, n_faults=6)
    assert a == b
    c = generate(12, 10.0, names, racks=racks, n_faults=6)
    assert a != c
    for inj in a.injections:
        assert inj.kind in (STRAGGLER, THERMAL, WEDGE, SPOT_PREEMPT,
                            PARTITION, RACK_FAIL, FAIL_STOP)


# --- timeline compilation ----------------------------------------------------

def test_timeline_rejects_unknown_nodes():
    sc = Scenario(injections=(Injection(t=0.0, kind=WEDGE, node="ghost"),))
    with pytest.raises(ValueError):
        ChaosTimeline(sc, ["n0", "n1"])


def test_straggler_and_partition_windows():
    sc = Scenario(injections=(
        Injection(t=1.0, kind=STRAGGLER, node="n0", factor=2.0,
                  duration_s=2.0),
        Injection(t=2.0, kind=STRAGGLER, node="n0", factor=3.0,
                  duration_s=2.0),
        Injection(t=1.0, kind=PARTITION, node="n1", duration_s=1.0)))
    tl = ChaosTimeline(sc, ["n0", "n1"])
    assert tl.latency_mult("n0", 0.5) == 1.0
    assert tl.latency_mult("n0", 1.5) == 2.0
    assert tl.latency_mult("n0", 2.5) == 6.0     # overlapping windows stack
    assert tl.latency_mult("n0", 3.5) == 3.0
    assert tl.latency_mult("n0", 4.5) == 1.0
    assert not tl.partitioned("n1", 0.5)
    assert tl.partitioned("n1", 1.5)
    assert not tl.partitioned("n1", 2.0)         # half-open window


def test_thermal_ladder_steps_then_recovers():
    sc = Scenario(injections=(
        Injection(t=0.0, kind=THERMAL, node="n0", duration_s=4.0),))
    tl = ChaosTimeline(sc, ["n0"])
    seen = [tl.throttle("n0", 0.5 + i) for i in range(4)]
    assert seen == list(DEFAULT_LADDER)          # walks the whole ladder
    assert tl.throttle("n0", 4.0) == 1.0         # instant recovery


def test_lifecycle_expansion():
    sc = Scenario(injections=(
        Injection(t=1.0, kind=RACK_FAIL, nodes=("n0", "n1")),
        Injection(t=2.0, kind=SPOT_PREEMPT, node="n2", notice_s=0.5),
        Injection(t=3.0, kind=WEDGE, node="n0")))
    tl = ChaosTimeline(sc, ["n0", "n1", "n2"])
    assert tl.lifecycle() == [
        (1.0, ce.FAIL, "n0"), (1.0, ce.FAIL, "n1"),
        (2.0, ce.DRAIN, "n2"), (2.5, ce.FAIL, "n2"),
        (3.0, ce.WEDGE_ON, "n0")]
    # the flattened live stream includes window ENDS and ladder steps
    evs = ChaosTimeline(Scenario(injections=(
        Injection(t=0.0, kind=STRAGGLER, node="n0", factor=2.0,
                  duration_s=1.0),
        Injection(t=0.0, kind=THERMAL, node="n0", duration_s=2.0),)),
        ["n0"]).events()
    assert evs == sorted(evs)
    actions = [a for _, a, _, _ in evs]
    assert actions.count(ce.THROTTLE) == len(DEFAULT_LADDER) + 1
    assert ce.STRAGGLE_OFF in actions


def test_node_chaos_overlay_on_constraints():
    node = make_nodes([64])[0]
    assert node.g(0.0).total_chips == 64
    node.chaos_throttle = 0.5
    node.chaos_capacity = 0.5
    g = node.g(0.0)
    assert g.total_chips == 32
    assert g.temperature_throttle == 0.5
    node.chaos_throttle = node.chaos_capacity = 1.0
    assert node.g(0.0).total_chips == 64


# --- sim: chaos rides the scripted failover machinery ------------------------

def _cls(name="api", deadline_ms=800.0, drop=SHED, priority=2):
    return SLOClass(name, deadline_ms=deadline_ms, priority=priority,
                    drop_policy=drop)


def _run(chaos=None, reliability=None, caps=(64, 64), rate=300.0,
         horizon=3.0, seed=1, **kw):
    cls = [_cls()]
    return simulate_cluster(cls, {"api": make_lut()},
                            {"api": poisson(rate, horizon, seed=seed)},
                            make_nodes(list(caps)), router=P2C,
                            chaos=chaos, reliability=reliability, **kw)


def test_chaos_fail_stop_matches_fail_at_scripting():
    sc = Scenario(injections=(Injection(t=1.0, kind=FAIL_STOP, node="n0"),))
    a = _run(chaos=sc)
    b = _run(fail_at={"n0": 1.0})
    assert a.decisions == b.decisions
    assert {n: s.summary() for n, s in a.classes.items()} == \
           {n: s.summary() for n, s in b.classes.items()}
    assert a.injections == [(1.0, FAIL_STOP, "n0")]
    assert b.injections == []


def test_chaos_determinism_bit_identical():
    names = ["n0", "n1", "n2"]
    sc = generate(5, 2.5, names, racks={"r0": ["n1", "n2"]}, n_faults=5)
    rel = Reliability()
    runs = [_run(chaos=sc, reliability=rel, caps=(64, 64, 64))
            for _ in range(2)]
    assert runs[0].summary() == runs[1].summary()
    assert runs[0].decisions == runs[1].decisions
    assert runs[0].injections == sorted(sc.summary())
    for r in runs:
        invariant(r)


def test_retry_recovers_failed_work():
    sc = Scenario(injections=(Injection(t=1.0, kind=FAIL_STOP, node="n0"),))
    off = _run(chaos=sc)
    assert off.total_failed > 0                   # queued work died with n0
    rel = Reliability(default=RetryPolicy(max_attempts=3, backoff_s=0.05),
                      budget=RetryBudget(burst=1000, fraction=1.0),
                      brownout=None)
    on = _run(chaos=sc, reliability=rel)
    st = on.classes["api"]
    assert st.retried > 0
    assert on.retry_granted == sum(s.retried for s in on.classes.values())
    assert on.total_failed < off.total_failed     # retries landed elsewhere
    invariant(on)


def test_never_retry_past_deadline():
    sc = Scenario(injections=(Injection(t=1.0, kind=FAIL_STOP, node="n0"),))
    # backoff alone blows the 800ms deadline: every retry is refused
    rel = Reliability(default=RetryPolicy(max_attempts=3, backoff_s=10.0),
                      brownout=None)
    r = _run(chaos=sc, reliability=rel)
    assert r.retry_denied["deadline"] > 0
    assert r.classes["api"].retried == 0
    assert r.retry_granted == 0
    invariant(r)


def test_retry_budget_exhaustion():
    sc = Scenario(injections=(Injection(t=1.0, kind=FAIL_STOP, node="n0"),))
    rel = Reliability(default=RetryPolicy(max_attempts=3, backoff_s=0.05),
                      budget=RetryBudget(burst=0, fraction=0.0),
                      brownout=None)
    r = _run(chaos=sc, reliability=rel)
    assert r.retry_denied["budget"] > 0
    assert r.classes["api"].retried == 0
    assert r.retry_granted == 0
    invariant(r)


def test_hedged_requests_first_completion_wins():
    rel = Reliability(policies={"api": RetryPolicy(hedge=True)},
                      brownout=None)
    r = _run(reliability=rel, rate=200.0)
    st = r.classes["api"]
    assert st.hedge_wasted > 0                    # losers are accounted...
    assert st.completed <= st.submitted           # ...never double-counted
    invariant(r)
    # the hedged run completes no fewer requests than the plain one
    plain = _run(rate=200.0)
    assert st.completed >= plain.classes["api"].completed - 1


def test_retry_span_links_flow_to_export():
    sc = Scenario(injections=(Injection(t=1.0, kind=FAIL_STOP, node="n0"),))
    rel = Reliability(default=RetryPolicy(max_attempts=3, backoff_s=0.05),
                      budget=RetryBudget(burst=1000, fraction=1.0),
                      brownout=None)
    tracer = Tracer()
    r = _run(chaos=sc, reliability=rel, tracer=tracer)
    assert r.classes["api"].retried > 0
    linked = [tr for tr in tracer.requests() if tr.links]
    assert linked                                 # second attempts link back
    by_id = {tr.trace_id: tr for tr in tracer.requests()}
    for tr in linked:
        for rid in tr.links:
            first = by_id[rid]
            assert first.cls == tr.cls
            assert first.t1 <= tr.t0 + 1e-9       # causally prior
    check_trace(linked[0])                        # components still partition
    doc = to_chrome_trace(tracer)
    ids = {tr.trace_id for tr in linked}
    ev_links = [e["args"]["links"] for e in doc["traceEvents"]
                if e.get("args", {}).get("trace_id") in ids]
    assert ev_links and all(l for l in ev_links)


def test_make_before_break_zero_drops():
    """A scripted move (replicas=1, first_fit start on the small node,
    rebalance onto the big one) keeps the SOURCE routable until the
    destination's priced warmup lands: no arrival is dropped mid-move."""
    nodes = [ClusterNode(name="n0", g_fn=lambda t: GlobalConstraints(
                 total_chips=128 if t < 0.9 else 2)),   # shrinks pre-move
             ClusterNode(name="n1", g_fn=lambda t: GlobalConstraints(
                 total_chips=256))]
    cls = [_cls(drop=DEGRADE, deadline_ms=2000.0)]
    r = simulate_cluster(cls, {"api": make_lut()},
                         {"api": poisson(400.0, 3.0, seed=2)},
                         nodes, router=P2C,
                         placement_mode=FIRST_FIT, replicas=1,
                         rebalance_at=[1.0], hysteresis=0.0)
    moves = [m for m in r.migrations if m[1] == "api"
             and m[2] is not None and m[3] is not None]
    assert moves                                  # a true src→dst move ran
    st = r.classes["api"]
    # before make-before-break the source retired at the move instant,
    # leaving only a weight-0 warming destination: arrivals during the
    # warmup window were dropped "placements exist but none routable"
    assert st.dropped == 0
    assert st.completed == st.submitted
    invariant(r)


def test_brownout_enters_and_exits_under_pressure():
    """Partitioning EVERY replica makes each arrival a failed route: the
    pressure EWMA crosses the enter threshold, the class browns out
    (arbiter pinned to the DEGRADE target), and once the partition
    heals and completions resume it exits again."""
    sc = Scenario(injections=(
        Injection(t=1.0, kind=PARTITION, node="n0", duration_s=1.0),
        Injection(t=1.0, kind=PARTITION, node="n1", duration_s=1.0)))
    rel = Reliability(default=RetryPolicy(max_attempts=2, backoff_s=0.05),
                      budget=RetryBudget(burst=10000, fraction=1.0),
                      brownout=BrownoutPolicy())
    r = _run(chaos=sc, reliability=rel, rate=200.0, horizon=4.0)
    directions = [d for _, _, d in r.brownouts]
    assert "enter" in directions
    assert "exit" in directions
    assert directions.index("enter") < directions.index("exit")
    ts = [t for t, _, _ in r.brownouts]
    assert ts == sorted(ts)
    invariant(r)


def test_arbiter_set_brownout_pins_and_restores():
    arb = ResourceArbiter()
    arb.register("api", make_lut(), 400.0, priority=2)
    arb.set_brownout("api", 1600.0)
    row = arb.summary()["api"]
    assert row["brownout"]
    arb.set_brownout("api", 1600.0)               # idempotent
    arb.set_brownout("api", None)
    row = arb.summary()["api"]
    assert "brownout" not in row or not row["brownout"]


# --- distributed/fault hardening ---------------------------------------------

def test_watchdog_rearms_after_recovery():
    fired = []
    wd = Watchdog(timeout_s=0.15, on_stall=lambda: fired.append(1)).start()
    try:
        time.sleep(0.5)
        assert wd.stalled and wd.stall_count == 1 and len(fired) == 1
        time.sleep(0.4)                   # same stall: no repeat firing
        assert wd.stall_count == 1
        wd.beat()                         # recovery re-arms
        assert not wd.stalled
        time.sleep(0.5)
        assert wd.stalled and wd.stall_count == 2 and len(fired) == 2
    finally:
        wd.stop()


def test_straggler_monitor_flag_log_is_bounded():
    mon = StragglerMonitor(window=50, threshold=2.0, log_cap=3)
    for step in range(10):
        assert not mon.record(step, 1.0)
    flagged = sum(mon.record(10 + i, 10.0) for i in range(6))
    assert flagged >= 4                   # slow steps really are outliers
    assert len(mon.flags) == 3            # capped deque...
    assert mon.flags_dropped >= 1         # ...with an eviction counter
    assert mon.flags[-1]["seconds"] == 10.0


# --- live: ChaosController + retry drain loop --------------------------------

def tiny_server(*_node):
    import jax
    from repro.models.vit import ViTConfig, vit_apply, vit_init
    from repro.runtime import DynamicServer
    cfg = ViTConfig(name="t", img_res=16, patch=8, n_layers=2,
                    d_model=32, n_heads=4, d_ff=64, n_classes=4,
                    compute_dtype="float32")
    params = vit_init(jax.random.PRNGKey(0), cfg)
    dims = {"d_model": 32, "d_ff": 64, "n_heads": 4, "n_layers": 2}
    return DynamicServer(lambda p, x, E: vit_apply(p, x, cfg, E=E)[0],
                         params, dims)


def live_lut():
    from repro.core.types import SubnetSpec
    return model_lut([SubnetSpec()], full_terms=TERMS, full_chips=2,
                     hw_states=[hm.HwState(chips=1, freq=1.0)])


def test_live_chaos_controller_replays_scenario():
    import numpy as np
    from repro.chaos import ChaosController
    from repro.cluster import Cluster
    nodes = [ClusterNode(name=f"n{i}",
                         g_fn=lambda t: GlobalConstraints(total_chips=2))
             for i in range(2)]
    cluster = Cluster(nodes, router=P2C)
    cluster.register("api", live_lut(), target_latency_ms=500.0,
                     priority=1, make_server=tiny_server)
    sc = Scenario(name="live-day", injections=(
        Injection(t=0.0, kind=STRAGGLER, node="n0", factor=2.0,
                  duration_s=0.2),
        Injection(t=0.05, kind=PARTITION, node="n0", duration_s=0.1),
        Injection(t=0.3, kind=FAIL_STOP, node="n0")))
    cluster.start()
    try:
        ctl = ChaosController(cluster, sc).start()
        deadline = time.time() + 10.0
        while not ctl.done and time.time() < deadline:
            time.sleep(0.02)
        assert ctl.done
        # every flattened primitive event was applied, in order
        assert [a for _, a, _ in ctl.applied] == \
               [a for _, a, _, _ in ctl.timeline.events()]
        assert cluster.nodes["n0"].state == DEAD
        assert cluster.nodes["n0"].chaos_capacity == 1.0  # window closed
        # the survivor still serves after the whole chaos day
        x = np.zeros((16, 16, 3), "float32")
        outs = [cluster.submit("api", x).get(timeout=30) for _ in range(4)]
        assert all(not o.get("cancelled") for o in outs)
    finally:
        cluster.stop()


class _FakeServer:
    """submit() succeeds immediately; records the span links passed."""

    def __init__(self):
        self.links_seen = []

    def submit(self, x, links=()):
        self.links_seen.append(list(links))
        fut = queue.Queue(maxsize=1)
        fut.put({"y": 1, "cancelled": False, "failed": False,
                 "latency_ms": 1.0, "subnet": None})
        fut.trace_id = 99
        return fut


def _failed_fut(trace_id=7):
    fut = queue.Queue(maxsize=1)
    fut.put({"y": None, "cancelled": True, "failed": True,
             "error": "node failed", "latency_ms": 0.0, "subnet": None})
    fut.trace_id = trace_id
    return fut


def test_drain_reliable_retries_failed_attempt_with_links():
    from repro.traffic.driver import ClassStats, _drain_reliable
    srv = _FakeServer()
    stats = {"api": ClassStats()}
    rel = Reliability(default=RetryPolicy(max_attempts=3, backoff_s=0.01),
                      brownout=None)
    t0 = time.perf_counter()
    final, budget = _drain_reliable(
        [("api", _failed_fut(trace_id=7), 0.0)],
        {"api": _cls(deadline_ms=5000.0)}, {"api": srv}, lambda n: None,
        stats, rel, t0, timeout_s=5.0)
    assert stats["api"].retried == 1
    assert budget.granted == 1
    assert srv.links_seen == [[7]]        # retry linked to first attempt
    assert len(final) == 1
    out = final[0][1].get()
    assert not out.get("cancelled")       # the retry's answer wins


def test_drain_reliable_respects_deadline():
    from repro.traffic.driver import ClassStats, _drain_reliable
    srv = _FakeServer()
    stats = {"api": ClassStats()}
    rel = Reliability(default=RetryPolicy(max_attempts=3, backoff_s=10.0),
                      brownout=None)
    t0 = time.perf_counter()
    final, budget = _drain_reliable(
        [("api", _failed_fut(), 0.0)],
        {"api": _cls(deadline_ms=100.0)}, {"api": srv}, lambda n: None,
        stats, rel, t0, timeout_s=5.0)
    assert stats["api"].retried == 0      # backoff blows the deadline
    assert budget.granted == 0
    assert srv.links_seen == []           # never resubmitted
    out = final[0][1].get()
    assert out["cancelled"] and out["failed"]
