"""Expert-gated grouped matmul vs oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.expert_matmul import expert_matmul, expert_matmul_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("counts", [
    [128, 128, 128, 128],          # full
    [128, 0, 64, 5],               # ragged + empty expert
    [0, 0, 0, 0],                  # all empty
    [1, 127, 128, 3],
])
def test_expert_matmul_ragged(counts):
    E, C, d, F = 4, 128, 64, 128
    x = jax.random.normal(KEY, (E, C, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (E, d, F))
    cnt = jnp.asarray(counts, jnp.int32)
    y = expert_matmul(x, w, cnt, interpret=True)
    yr = expert_matmul_ref(x, w, cnt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)


def test_expert_matmul_elastic_experts_one_executable():
    """Traced counts: one jit covers every elastic-expert setting (the
    paper's expert-count knob with zero switch cost)."""
    E, C, d, F = 8, 128, 32, 128
    x = jax.random.normal(KEY, (E, C, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (E, d, F))
    f = jax.jit(lambda cnt: expert_matmul(x, w, cnt, interpret=True))
    for a_experts in (8, 4, 1):
        cnt = jnp.where(jnp.arange(E) < a_experts, 128, 0).astype(jnp.int32)
        np.testing.assert_allclose(
            np.asarray(f(cnt)),
            np.asarray(expert_matmul_ref(x, w, cnt)),
            rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_matmul_dtypes(dtype):
    E, C, d, F = 2, 256, 64, 256
    x = (jax.random.normal(KEY, (E, C, d)) * 0.5).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(KEY, 3), (E, d, F)) * 0.5
         ).astype(dtype)
    cnt = jnp.asarray([200, 31], jnp.int32)
    y = expert_matmul(x, w, cnt, bc=128, bf=128, interpret=True)
    yr = expert_matmul_ref(x, w, cnt)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=tol, atol=tol)
