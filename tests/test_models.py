"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs — one test per assigned (arch x shape) cell.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.launch.steps import build_cell
from repro.optim import make_optimizer

CELLS = [(a, s) for a in list_archs() for s in get_arch(a).shapes]


def _init_params(arch, cfg):
    key = jax.random.PRNGKey(1)
    if arch.family == "lm":
        from repro.models.transformer import lm_init
        return lm_init(key, cfg)
    if arch.family == "diffusion":
        if arch.arch_id.startswith("dit"):
            from repro.models.dit import dit_init
            return dit_init(key, cfg)
        from repro.models.unet import unet_init
        return unet_init(key, cfg)
    if arch.arch_id.startswith(("deit", "vit", "dynamic-ofa")):
        from repro.models.vit import vit_init
        return vit_init(key, cfg)
    if arch.arch_id.startswith("resnet"):
        from repro.models.resnet import resnet_init
        return resnet_init(key, cfg)
    from repro.models.efficientnet import effnet_init
    return effnet_init(key, cfg)


def _real_args(cell, arch):
    key = jax.random.PRNGKey(2)
    params = _init_params(arch, cell.cfg)
    out = [params]
    rest = cell.args[1:]
    if cell.kind == "train":
        init_fn, _ = make_optimizer(arch.optimizer)
        out.append(init_fn(params))
        rest = cell.args[2:]

    def mk(s):
        if s.dtype == jnp.int32:
            return jnp.ones(s.shape, s.dtype)
        return (jax.random.normal(key, s.shape, jnp.float32) * 0.5
                ).astype(s.dtype)

    out += [jax.tree_util.tree_map(mk, a) for a in rest]
    return tuple(out)


@pytest.mark.parametrize("arch_id,shape", CELLS,
                         ids=[f"{a}-{s}" for a, s in CELLS])
def test_cell_smoke(arch_id, shape):
    arch = get_arch(arch_id)
    cell = build_cell(arch, shape, smoke=True)
    args = _real_args(cell, arch)
    out = cell.fn(*args)
    leaves = [l for l in jax.tree_util.tree_leaves(out)
              if hasattr(l, "dtype") and l.dtype in (jnp.float32,
                                                     jnp.bfloat16)]
    assert leaves, "step produced no float outputs"
    for l in leaves:
        assert not np.any(np.isnan(np.asarray(l, dtype=np.float32)))
    if cell.kind == "train":
        loss = float(out[2]["loss"])
        assert 0.0 < loss < 100.0


@pytest.mark.parametrize("arch_id", ["qwen1.5-110b", "deepseek-moe-16b",
                                     "deit-b", "dit-l2"])
def test_elastic_subnets_slice_eq_mask(arch_id):
    """The paper's knob works on the assigned archs: sliced == masked."""
    arch = get_arch(arch_id)
    cfg = arch.make_smoke()
    key = jax.random.PRNGKey(3)
    if arch.family == "lm":
        from repro.models.transformer import lm_apply, lm_init
        p = lm_init(key, cfg)
        toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
        E_s = {"a_ff": max(1, cfg.d_ff // 2), "a_heads": cfg.n_kv_heads,
               "a_layers": max(1, cfg.n_layers // 2)}
        if cfg.moe:
            E_s["a_experts"] = cfg.moe.n_experts // 2
            E_s["top_k"] = 1
        E_m = {k: (jnp.asarray(v) if k != "top_k" else v)
               for k, v in E_s.items()}
        a, _, _ = lm_apply(p, toks, cfg, E=E_s)
        b, _, _ = lm_apply(p, toks, cfg, E=E_m)
    elif arch.arch_id.startswith("dit"):
        from repro.models.dit import dit_apply, dit_init
        p = dit_init(key, cfg)
        lat = jax.random.normal(key, (2, cfg.latent_res, cfg.latent_res, 4))
        t = jnp.array([5.0, 100.0])
        y = jnp.array([1, 2])
        E_s = {"a_model": cfg.d_model // 2, "a_ff": cfg.d_ff // 2,
               "a_heads": cfg.n_heads // 2, "a_layers": cfg.n_layers // 2}
        E_m = {k: jnp.asarray(v) for k, v in E_s.items()}
        a = dit_apply(p, lat, t, y, cfg, E=E_s)
        b = dit_apply(p, lat, t, y, cfg, E=E_m)
    else:
        from repro.models.vit import vit_apply, vit_init
        p = vit_init(key, cfg)
        x = jax.random.normal(key, (2, cfg.img_res, cfg.img_res, 3))
        E_s = {"a_model": cfg.d_model // 2, "a_ff": cfg.d_ff // 2,
               "a_heads": cfg.n_heads // 2, "a_layers": cfg.n_layers // 2}
        E_m = {k: jnp.asarray(v) for k, v in E_s.items()}
        a, _ = vit_apply(p, x, cfg, E=E_s)
        b, _ = vit_apply(p, x, cfg, E=E_m)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=5e-3, atol=5e-3)


def test_lm_decode_matches_prefill_end_to_end():
    from repro.models.transformer import (lm_apply, lm_init,
                                          make_decode_caches)
    arch = get_arch("granite-20b")
    cfg = arch.make_smoke()
    p = lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 10), 0,
                              cfg.vocab_size)
    full, _, _ = lm_apply(p, toks, cfg)
    logits_p, _, kv = lm_apply(p, toks[:, :6], cfg, return_kv=True)
    caches = make_decode_caches(cfg, 2, 10, dtype=jnp.float32, filled=6)
    for kk in ("k", "v"):
        caches["dense"][kk] = caches["dense"][kk].at[:, :, :6].set(
            kv["dense"][kk])
    outs = [logits_p[:, -1:]]
    c = caches
    for t in range(6, 10):
        lg, _, c = lm_apply(p, toks[:, t:t + 1], cfg, caches=c)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(full[:, 5:10]), np.asarray(dec[:, :5]),
                               rtol=5e-3, atol=5e-3)


def test_diffusion_sampler_runs():
    from repro.models.diffusion import ddim_sample, make_schedule
    sched = make_schedule()
    denoise = lambda x, t: x * 0.1
    out = ddim_sample(denoise, sched, (2, 8, 8, 4), jax.random.PRNGKey(0),
                      steps=4)
    assert out.shape == (2, 8, 8, 4)
    assert not np.any(np.isnan(np.asarray(out)))
